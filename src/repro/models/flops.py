"""Analytic model FLOPs (the MODEL_FLOPS term of §Roofline).

6*N_active*tokens for training matmuls (2 fwd + 4 bwd) plus the
sequence-mixing quadratic terms; 2*N_active per token for inference.
Deliberately *useful*-work-only: no remat, no padding, no dropped-token
waste — the MODEL_FLOPS/HLO_FLOPs ratio then exposes exactly that waste.
"""

from __future__ import annotations


def _attn_layers(cfg) -> int:
    return sum(1 for k in cfg.pattern if k in ("attn", "local_attn", "moe")) * cfg.repeats


def _attention_fwd_flops(cfg, batch: int, seq: int) -> float:
    """Scores + AV einsums, honoring causality and sliding windows."""
    total = 0.0
    hq, hd = cfg.n_heads, cfg.head_dim_
    for kind in cfg.pattern:
        if kind not in ("attn", "local_attn", "moe"):
            continue
        if kind == "local_attn" and cfg.window:
            eff = min(cfg.window, seq)
            pairs = batch * seq * eff  # each query sees <= window keys
        else:
            pairs = batch * seq * seq * (0.5 if not cfg.encoder_only else 1.0)
        total += 4.0 * pairs * hq * hd  # qk + av, 2 flops per MAC
    return total * cfg.repeats


def _recurrent_fwd_flops(cfg, batch: int, seq: int) -> float:
    total = 0.0
    for kind in cfg.pattern:
        if kind == "mamba2" and cfg.ssm:
            s = cfg.ssm
            h = s.n_heads(cfg.d_model)
            p, n, L = s.head_dim, s.d_state, min(s.chunk, seq)
            # intra-chunk quadratic + state outer products/contractions
            total += 4.0 * batch * seq * L * h * 0.5 * (p + n)
            total += 4.0 * batch * seq * h * p * n
        elif kind == "mlstm" and cfg.xlstm:
            di = cfg.xlstm.d_inner(cfg.d_model)
            h = cfg.n_heads
            p = di // h
            L = min(cfg.xlstm.chunk, seq)
            total += 4.0 * batch * seq * L * h * 0.5 * p  # intra-chunk qk/av
            total += 4.0 * batch * seq * h * p * p        # state update/query
        elif kind == "slstm":
            total += 8.0 * batch * seq * cfg.d_model      # recurrent matvecs
    return total * cfg.repeats


def train_step_model_flops(cfg, labels_shape) -> float:
    """labels_shape: (A, B, S) or (B, S)."""
    if len(labels_shape) == 3:
        A, B, S = labels_shape
    else:
        A, B, S = 1, *labels_shape
    tokens = A * B * S
    n_active = cfg.active_param_count()
    matmul = 6.0 * n_active * tokens
    mixing = 3.0 * (_attention_fwd_flops(cfg, A * B, S) + _recurrent_fwd_flops(cfg, A * B, S))
    return matmul + mixing


def prefill_model_flops(cfg, batch: int, seq: int) -> float:
    n_active = cfg.active_param_count()
    return 2.0 * n_active * batch * seq + _attention_fwd_flops(cfg, batch, seq) + _recurrent_fwd_flops(cfg, batch, seq)


def decode_model_flops(cfg, batch: int, cache_len: int) -> float:
    """One new token per sequence against a cache of ``cache_len``."""
    n_active = cfg.active_param_count()
    flops = 2.0 * n_active * batch
    hq, hd = cfg.n_heads, cfg.head_dim_
    for kind in cfg.pattern:
        if kind in ("attn", "local_attn", "moe"):
            eff = min(cfg.window, cache_len) if (kind == "local_attn" and cfg.window) else cache_len
            flops += 4.0 * batch * eff * hq * hd * cfg.repeats
        elif kind == "mamba2" and cfg.ssm:
            s = cfg.ssm
            flops += 4.0 * batch * s.n_heads(cfg.d_model) * s.head_dim * s.d_state * cfg.repeats
        elif kind == "mlstm" and cfg.xlstm:
            di = cfg.xlstm.d_inner(cfg.d_model)
            p = di // cfg.n_heads
            flops += 4.0 * batch * cfg.n_heads * p * p * cfg.repeats
    return flops


def decode_model_bytes(cfg, batch: int, cache_len: int) -> float:
    """Minimal HBM traffic for one decode step: read active params once +
    read the visible KV/state cache once (the bandwidth roofline for
    decode cells; activations are negligible at S=1)."""
    param_bytes = 2.0 * cfg.active_param_count()  # bf16
    cache_bytes = 0.0
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    for kind in cfg.pattern:
        if kind in ("attn", "local_attn", "moe"):
            eff = min(cfg.window, cache_len) if (kind == "local_attn" and cfg.window) else cache_len
            cache_bytes += 2.0 * batch * eff * hkv * hd * 2  # k+v bf16
        elif kind == "mamba2" and cfg.ssm:
            ssm = cfg.ssm
            cache_bytes += 4.0 * batch * ssm.n_heads(cfg.d_model) * ssm.head_dim * ssm.d_state
        elif kind == "mlstm" and cfg.xlstm:
            di = cfg.xlstm.d_inner(cfg.d_model)
            p = di // cfg.n_heads
            cache_bytes += 4.0 * batch * cfg.n_heads * p * p
        elif kind == "slstm":
            cache_bytes += 4.0 * 4 * batch * cfg.d_model
    cache_bytes *= cfg.repeats
    return param_bytes + cache_bytes
