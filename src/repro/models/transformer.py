"""Model assembly: pattern-scanned transformer covering all 10 assigned
architectures (dense / MoE / hybrid-SSM / xLSTM / encoder / VLM backbone).

The layer stack is ``cfg.pattern`` repeated ``cfg.repeats`` times and scanned
over repeats (compact HLO, correct trip-count accounting in the HLO cost
analyzer). Per-slot params/caches are stacked over repeats.

Entry points:
  model_params(cfg)                  ParamSpec tree
  forward(params, batch, cfg, ...)   logits / loss+aux (train)
  init_cache(cfg, batch, max_len)    decode cache pytree
  prefill(params, batch, cfg, ...)   cache fill + last-position logits
  prefill_chunk(params, batch, ...)  incremental prefill at per-slot offsets
  decode_step(params, batch, ...)    one-token step (per-slot positions)
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.layers import attention as A
from repro.layers import ffn as FFN
from repro.layers import recurrent as R
from repro.layers.common import LogicalConstraints, NULL_CONSTRAINTS, ParamSpec
from repro.layers.norms import rmsnorm, rmsnorm_params


# ---------------------------------------------------------------------------
# parameter declaration
# ---------------------------------------------------------------------------


def _slot_params(cfg, kind: str) -> dict:
    p: dict[str, Any] = {"norm_in": rmsnorm_params(cfg.d_model)}
    if kind in ("attn", "local_attn"):
        p["attn"] = A.attention_params(cfg)
        p["norm_mlp"] = rmsnorm_params(cfg.d_model)
        p["mlp"] = FFN.mlp_params(cfg)
    elif kind == "moe":
        p["attn"] = A.attention_params(cfg)
        p["norm_mlp"] = rmsnorm_params(cfg.d_model)
        p["moe"] = FFN.moe_params(cfg)
    elif kind == "mamba2":
        p["mamba"] = R.mamba2_params(cfg)
    elif kind == "mlstm":
        p["mlstm"] = R.mlstm_params(cfg)
    elif kind == "slstm":
        p["slstm"] = R.slstm_params(cfg)
        p["norm_mlp"] = rmsnorm_params(cfg.d_model)
        p["mlp"] = FFN.mlp_params(
            cfg, d_ff=int(cfg.d_model * cfg.xlstm.slstm_ff_factor) if cfg.xlstm else cfg.d_ff
        )
    else:
        raise ValueError(f"unknown block kind {kind}")
    return p


def _stack(tree, n: int):
    def f(spec: ParamSpec) -> ParamSpec:
        return ParamSpec(
            (n,) + spec.shape, ("layers",) + spec.logical, spec.init, spec.scale,
            spec.dtype,
        )

    return jax.tree_util.tree_map(f, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def model_params(cfg) -> dict:
    d, v = cfg.d_model, cfg.vocab_padded
    params: dict[str, Any] = {}
    if cfg.frontend != "audio":  # audio stub feeds embeddings directly
        params["embed"] = ParamSpec((v, d), ("vocab", "embed"), scale=0.02)
    params["slots"] = {
        f"slot{i}_{kind}": _stack(_slot_params(cfg, kind), cfg.repeats)
        for i, kind in enumerate(cfg.pattern)
    }
    params["norm_f"] = rmsnorm_params(d)
    if not cfg.tie_embeddings and not cfg.encoder_only:
        params["head"] = ParamSpec((d, v), ("embed", "vocab"), scale=1.0 / math.sqrt(d))
    if cfg.encoder_only:
        params["head"] = ParamSpec((d, v), ("embed", "vocab"), scale=1.0 / math.sqrt(d))
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_slot(
    slot_params, kind: str, x, cfg, *, positions, lc, cache=None, cache_len=None,
    seq_mask=None, cache_attend=False, block_tables=None,
):
    """One block of the pattern. Returns (x, new_cache, aux).

    ``seq_mask`` (B,S) marks valid positions: masked positions neither write
    the KV cache nor advance recurrent state (continuous batching: chunk
    padding and inactive decode slots). ``cache_attend`` routes S>1 attention
    against the written cache (chunked prefill) instead of in-chunk."""
    aux: dict[str, Any] = {}
    h = rmsnorm(
        x, slot_params["norm_in"]["scale"], cfg.norm_eps, cfg.zero_centered_norm
    )
    new_cache = None
    if kind in ("attn", "local_attn", "moe"):
        window = cfg.window if kind == "local_attn" else None
        att_cache = cache.get("attn") if cache else None
        o, att_new = A.attention_block(
            slot_params["attn"], h, cfg, positions=positions, lc=lc,
            causal=not cfg.encoder_only, window=window,
            cache=att_cache, cache_len=cache_len,
            seq_mask=seq_mask, cache_attend=cache_attend,
            block_tables=block_tables,
        )
        # constrain BEFORE the residual add: the TP partial sums then lower
        # to reduce-scatter onto the seq-sharded residual instead of a full
        # f32 all-reduce (16x the bytes, measured on dbrx train_4k)
        o = lc(o, "batch", "seq", None)
        x = x + o
        h2 = rmsnorm(
            x, slot_params["norm_mlp"]["scale"], cfg.norm_eps, cfg.zero_centered_norm
        )
        if kind == "moe":
            o2, moe_aux = FFN.moe_block(slot_params["moe"], h2, cfg, lc=lc)
            aux.update(moe_aux)
        else:
            o2 = FFN.mlp_block(slot_params["mlp"], h2, cfg, lc=lc)
        o2 = lc(o2, "batch", "seq", None)
        x = x + o2
        if att_new is not None:
            new_cache = {"attn": att_new}
    elif kind == "mamba2":
        o, mcache = R.mamba2_block(
            slot_params["mamba"], h, cfg, lc=lc,
            cache=cache.get("mamba") if cache else None, seq_mask=seq_mask,
        )
        o = lc(o, "batch", "seq", None)
        x = x + o
        if mcache is not None:
            new_cache = {"mamba": mcache}
    elif kind == "mlstm":
        o, mcache = R.mlstm_block(
            slot_params["mlstm"], h, cfg, lc=lc,
            cache=cache.get("mlstm") if cache else None, seq_mask=seq_mask,
        )
        x = x + o
        if mcache is not None:
            new_cache = {"mlstm": mcache}
    elif kind == "slstm":
        o, scache = R.slstm_block(
            slot_params["slstm"], h, cfg, lc=lc,
            cache=cache.get("slstm") if cache else None, seq_mask=seq_mask,
        )
        x = x + o
        h2 = rmsnorm(
            x, slot_params["norm_mlp"]["scale"], cfg.norm_eps, cfg.zero_centered_norm
        )
        x = x + FFN.mlp_block(slot_params["mlp"], h2, cfg, lc=lc)
        if scache is not None:
            new_cache = {"slstm": scache}
    else:
        raise ValueError(kind)
    x = lc(x, "batch", "seq", None)
    return x, new_cache, aux


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


def _run_stack(params, x, cfg, *, positions, lc, caches=None, cache_len=None,
               seq_mask=None, cache_attend=False, block_tables=None):
    """Scan pattern x repeats. caches: {slot_name: stacked cache} or None.
    ``block_tables`` (B, n_logical) selects the paged attention-cache
    layout (shared across layers — allocation is per token position).
    Returns (x, new_caches, aux_totals)."""
    slot_names = list(params["slots"].keys())

    def body(carry, layer_inp):
        x = carry
        slot_rows, cache_rows = layer_inp
        new_cache_rows = {}
        aux_tot = None
        for name in slot_names:
            kind = name.split("_", 1)[1]
            x, nc, aux = _apply_slot(
                slot_rows[name], kind, x, cfg, positions=positions, lc=lc,
                cache=cache_rows.get(name) if cache_rows else None,
                cache_len=cache_len,
                seq_mask=seq_mask, cache_attend=cache_attend,
                block_tables=block_tables,
            )
            if nc is not None:
                new_cache_rows[name] = nc
            if aux:
                aux_tot = aux if aux_tot is None else jax.tree_util.tree_map(
                    jnp.add, aux_tot, aux
                )
        if aux_tot is None:
            aux_tot = {}
        return x, (new_cache_rows, aux_tot)

    body = _remat(body, cfg)

    if cfg.scan_layers and cfg.repeats > 1:
        xs = (params["slots"], caches if caches else {})
        x, (new_caches, auxs) = jax.lax.scan(body, x, xs)
        aux = jax.tree_util.tree_map(jnp.sum, auxs) if auxs else {}
        # expert_load should stay per-expert: re-reduce over layers only
        if auxs and "expert_load" in auxs:
            aux["expert_load"] = jnp.sum(auxs["expert_load"], axis=0)
        return x, (new_caches if caches else None), aux
    else:
        # unrolled path (small models / remat experiments)
        new_caches_acc = []
        aux_acc: dict[str, Any] = {}
        for r in range(cfg.repeats):
            slot_rows = jax.tree_util.tree_map(lambda p: p[r], params["slots"])
            cache_rows = (
                jax.tree_util.tree_map(lambda c: c[r], caches) if caches else {}
            )
            x, (ncr, aux) = body(x, (slot_rows, cache_rows))
            new_caches_acc.append(ncr)
            for k, v in aux.items():
                aux_acc[k] = aux_acc.get(k, 0) + v
        new_caches = None
        if caches:
            new_caches = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_caches_acc
            )
        return x, new_caches, aux_acc


# ---------------------------------------------------------------------------
# embedding + head
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch, cfg, lc):
    """batch: dict with optional "tokens" (B,S) and "frontend" (B,P,d)."""
    parts = []
    if batch.get("frontend") is not None:
        parts.append(batch["frontend"].astype(cfg.compute_dtype))
    if batch.get("tokens") is not None:
        emb = params["embed"].astype(cfg.compute_dtype)
        parts.append(emb[batch["tokens"]])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.compute_dtype)
    return lc(x, "batch", "seq", None)


def _logits(params, x, cfg, lc):
    if cfg.tie_embeddings:
        head = params["embed"].T
    else:
        head = params["head"]
    logits = x @ head.astype(cfg.compute_dtype)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return lc(logits, "batch", None, "vocab")


def cross_entropy(
    params, x, labels, cfg, lc, *, seq_chunk: int = 512, z_loss: float | None = None
):
    """Chunked CE over the sequence — never materializes (B,S,V) logits.
    labels: (B,S) int32; negative labels are masked out.
    Returns (loss_sum, weight_sum, token_count_per_data_shard_proxy)."""
    B, S, _ = x.shape
    V = cfg.vocab_padded
    z_coef = cfg.z_loss if z_loss is None else z_loss
    seq_chunk = min(seq_chunk, S)
    n = -(-S // seq_chunk)
    xpad = A._pad_axis(x, 1, n * seq_chunk)
    lpad = A._pad_axis(labels, 1, n * seq_chunk, value=-1)
    xc = xpad.reshape(B, n, seq_chunk, -1).transpose(1, 0, 2, 3)
    lck = lpad.reshape(B, n, seq_chunk).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        xi, li = inp
        logits = _logits(params, xi, cfg, lc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(jnp.maximum(li, 0), V, dtype=jnp.float32)
        gold = jnp.sum(logits * onehot, axis=-1)
        w = (li >= 0).astype(jnp.float32)
        nll = (lse - gold) * w
        zl = z_coef * (lse**2) * w if z_coef else 0.0
        loss_sum, w_sum = carry
        return (loss_sum + jnp.sum(nll + zl), w_sum + jnp.sum(w)), None

    chunk_loss = jax.checkpoint(chunk_loss)
    (loss_sum, w_sum), _ = jax.lax.scan(chunk_loss, (0.0, 0.0), (xc, lck))
    return loss_sum, w_sum


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def forward(params, batch, cfg, lc: LogicalConstraints = NULL_CONSTRAINTS):
    """Training/eval forward: returns (loss, aux)."""
    x = _embed_inputs(params, batch, cfg, lc)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x, _, aux = _run_stack(params, x, cfg, positions=positions, lc=lc)
    x = rmsnorm(x, params["norm_f"]["scale"], cfg.norm_eps, cfg.zero_centered_norm)
    x = lc(x, "batch", None, None)
    loss_sum, w_sum = cross_entropy(params, x, batch["labels"], cfg, lc)
    loss = loss_sum / jnp.maximum(w_sum, 1.0)
    if "moe_lb_loss" in aux:
        loss = loss + cfg.moe_lb_coef * aux["moe_lb_loss"] / cfg.n_layers
        loss = loss + cfg.moe_z_coef * aux["moe_z_loss"] / cfg.n_layers
    aux["tokens"] = w_sum
    return loss, aux


def apply_logits(params, batch, cfg, lc: LogicalConstraints = NULL_CONSTRAINTS):
    """Full-sequence logits (small-model/eval path; materializes (B,S,V))."""
    x = _embed_inputs(params, batch, cfg, lc)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x, _, aux = _run_stack(params, x, cfg, positions=positions, lc=lc)
    x = rmsnorm(x, params["norm_f"]["scale"], cfg.norm_eps, cfg.zero_centered_norm)
    return _logits(params, x, cfg, lc), aux


def init_cache(cfg, batch: int, max_len: int, dtype=None, *,
               paged: bool = False, page_size: int = 16,
               num_pages: int | None = None) -> dict:
    """Stacked decode caches per slot.

    ``paged=True`` swaps each attention cache's dense per-slot
    ``(R, B, max_len, Hkv, hd)`` buffers for a shared pool of
    ``num_pages`` fixed-size pages ``(R, num_pages, page_size, Hkv, hd)``
    addressed through a per-slot block table (see ``decode_step``) — HBM
    then scales with live tokens, not ``batch x max_len``. ``num_pages``
    defaults to dense-equivalent capacity; serving sizes it to the
    workload. Recurrent (conv/ssm/xLSTM) state stays dense per slot —
    it is O(batch), not O(batch x seq)."""
    dtype = dtype or cfg.compute_dtype
    if paged and num_pages is None:
        num_pages = -(-batch * max_len // page_size)
    caches: dict[str, Any] = {}
    for i, kind in enumerate(cfg.pattern):
        name = f"slot{i}_{kind}"
        if kind in ("attn", "local_attn", "moe"):
            hkv, hd = cfg.n_kv_heads, cfg.head_dim_
            if paged:
                c = {
                    "attn": {
                        "k_pages": jnp.zeros(
                            (cfg.repeats, num_pages, page_size, hkv, hd), dtype
                        ),
                        "v_pages": jnp.zeros(
                            (cfg.repeats, num_pages, page_size, hkv, hd), dtype
                        ),
                    }
                }
                caches[name] = c
                continue
            c = {
                "attn": {
                    "k": jnp.zeros((cfg.repeats, batch, max_len, hkv, hd), dtype),
                    "v": jnp.zeros((cfg.repeats, batch, max_len, hkv, hd), dtype),
                }
            }
        elif kind == "mamba2":
            c = {"mamba": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (cfg.repeats,) + x.shape),
                R.mamba2_cache(cfg, batch, dtype),
            )}
        elif kind == "mlstm":
            c = {"mlstm": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (cfg.repeats,) + x.shape),
                R.mlstm_cache(cfg, batch, dtype),
            )}
        elif kind == "slstm":
            c = {"slstm": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (cfg.repeats,) + x.shape),
                R.slstm_cache(cfg, batch),
            )}
        else:
            raise ValueError(kind)
        caches[name] = c
    return caches


def prefill(params, batch, cfg, caches, lc: LogicalConstraints = NULL_CONSTRAINTS):
    """Run the prompt through the stack filling caches.
    Returns (last_logits (B,V), new_caches)."""
    x = _embed_inputs(params, batch, cfg, lc)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x, new_caches, _ = _run_stack(
        params, x, cfg, positions=positions, lc=lc, caches=caches, cache_len=S
    )
    x = rmsnorm(x, params["norm_f"]["scale"], cfg.norm_eps, cfg.zero_centered_norm)
    logits = _logits(params, x[:, -1:, :], cfg, lc)
    return logits[:, 0], new_caches


def prefill_chunk(
    params, batch, cfg, caches, start, length,
    lc: LogicalConstraints = NULL_CONSTRAINTS, block_tables=None,
    all_logits: bool = False,
):
    """One chunk of an incremental prefill: run ``batch["tokens"]`` (B,C)
    through the stack as positions ``start .. start+length``, writing the
    caches at each row's own offsets and attending against everything
    written so far (earlier chunks included).

    ``start``: () or (B,) position of the chunk's first token; ``length``:
    () or (B,) valid tokens in the chunk — the rest is padding, which
    neither writes the cache nor advances recurrent state, so a padded
    chunk leaves exactly the state a tight chunk would have.
    Returns (logits (B,V) at each row's LAST VALID position, new_caches) —
    on the final chunk of a prompt those logits sample the first generated
    token. ``block_tables`` (B, n_logical) routes attention-cache writes
    and reads through the paged pool layout (see ``init_cache``) — reads
    go through ``kernels.paged_attention.paged_prefill_attention``, the
    multi-token paged read that attends the block table directly instead
    of gathering a slot's pages into a dense view per chunk.

    ``all_logits=True`` returns logits at EVERY chunk position, (B,C,V) —
    the multi-token scoring path for speculative decoding: each row ``r``
    attends through its own position, so ``logits[:, r]`` is bitwise
    identical to what a sequential ``decode_step`` at ``start + r`` would
    produce after consuming the same tokens. Positions past ``length``
    hold garbage (their cache writes and state advance are masked, their
    logits are not)."""
    x = _embed_inputs(params, batch, cfg, lc)
    B, C, _ = x.shape
    start = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (B,))
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32).reshape(-1), (B,))
    offs = jnp.arange(C, dtype=jnp.int32)[None, :]
    seq_mask = offs < length[:, None]
    positions = start[:, None] + offs
    x, new_caches, _ = _run_stack(
        params, x, cfg, positions=positions, lc=lc, caches=caches,
        cache_len=start + length, seq_mask=seq_mask, cache_attend=True,
        block_tables=block_tables,
    )
    x = rmsnorm(x, params["norm_f"]["scale"], cfg.norm_eps, cfg.zero_centered_norm)
    if all_logits:
        return _logits(params, x, cfg, lc), new_caches
    x_last = jnp.take_along_axis(
        x, jnp.maximum(length - 1, 0)[:, None, None], axis=1
    )  # (B,1,d)
    logits = _logits(params, x_last, cfg, lc)
    return logits[:, 0], new_caches


def decode_step(
    params, tokens, pos, cfg, caches, lc: LogicalConstraints = NULL_CONSTRAINTS,
    frontend=None, active=None, block_tables=None,
):
    """One decode step. tokens: (B,1) int32; pos: () scalar or (B,) vector of
    per-slot positions — continuous batching attaches requests mid-flight, so
    every slot carries its own position (RoPE, cache write offset, visible
    cache length all follow it). ``active``: optional (B,) bool; inactive
    slots neither write the KV cache nor advance recurrent state.
    ``block_tables``: optional (B, n_logical) int32 — paged attention-cache
    layout (``init_cache(..., paged=True)``); the slot's token writes and
    the decode attention both address the shared pool through it.
    Returns (logits (B,V), new_caches)."""
    batch = {"tokens": tokens, "frontend": frontend}
    x = _embed_inputs(params, batch, cfg, lc)
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos.reshape(-1, 1), (B, 1))
    seq_mask = None if active is None else jnp.asarray(active).reshape(B, 1)
    x, new_caches, _ = _run_stack(
        params, x, cfg, positions=positions, lc=lc, caches=caches,
        cache_len=pos + 1, seq_mask=seq_mask, block_tables=block_tables,
    )
    x = rmsnorm(x, params["norm_f"]["scale"], cfg.norm_eps, cfg.zero_centered_norm)
    logits = _logits(params, x, cfg, lc)
    return logits[:, 0], new_caches
