from repro.models import transformer
from repro.models.flops import (
    decode_model_flops,
    prefill_model_flops,
    train_step_model_flops,
)

__all__ = ["transformer", "train_step_model_flops", "prefill_model_flops", "decode_model_flops"]
