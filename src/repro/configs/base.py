"""Model / run configuration schema.

One ``ModelConfig`` describes any of the 10 assigned architectures through a
*block pattern*: the layer stack is ``pattern`` (a short period of block
kinds) repeated ``repeats`` times — scanned over ``repeats`` so the HLO stays
compact (period blocks are materialized once, stacked over repeats).

Block kinds: "attn" (global attention + FFN), "local_attn" (sliding-window +
FFN), "moe" (attention + MoE FFN), "mamba2", "mlstm", "slstm".
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax.numpy as jnp

BLOCK_KINDS = ("attn", "local_attn", "moe", "mamba2", "mlstm", "slstm")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    normalize_topk: bool = True
    gated: bool = True

    def capacity(self, n_tokens: int) -> int:
        c = math.ceil(n_tokens * self.top_k * self.capacity_factor / self.n_experts)
        # MXU-friendly multiple of 128, never above total routed pairs
        c = min(max(128, -(-c // 128) * 128), n_tokens)
        return c


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor: float = 2.0
    d_conv: int = 4
    chunk: int = 256
    slstm_ff_factor: float = 4.0 / 3.0

    def d_inner(self, d_model: int) -> int:
        return int(self.proj_factor * d_model)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: tuple[str, ...]
    repeats: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention details
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0
    window: int | None = None          # sliding window for "local_attn"
    attn_softcap: float | None = None
    final_softcap: float | None = None
    attn_bias: bool = False
    qk_norm: bool = False

    # families
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    xlstm: XLSTMConfig | None = None

    # model shape/behaviour
    encoder_only: bool = False
    frontend: str | None = None        # None | "audio" | "vlm" (stub embeddings)
    n_frontend_tokens: int = 0         # patches/frames provided by the stub
    tie_embeddings: bool = False
    embed_scale: bool = False          # gemma-style sqrt(d) embedding scale
    zero_centered_norm: bool = False   # gemma-style (1+scale) rmsnorm
    act: str = "swiglu"
    norm_eps: float = 1e-6

    # numerics
    param_dtype_name: str = "bfloat16"
    compute_dtype_name: str = "bfloat16"

    # attention chunking (flash-style scan) + perf knobs
    q_chunk: int = 512
    kv_chunk: int = 1024
    causal_skip: bool = False
    # paged decode-attention kernel implementation (serving):
    # auto (Pallas on TPU, reference elsewhere) | pallas | interpret | reference
    paged_attn_impl: str = "auto"

    # distribution
    sharding: str = "megatron"         # megatron | fsdp  (auto-checked)
    remat: str = "full"                # none | full | dots
    scan_layers: bool = True

    # which input shapes are skipped, mapping shape-name -> reason
    skips: tuple[tuple[str, str], ...] = ()

    # training details
    z_loss: float = 1e-4
    moe_lb_coef: float = 0.01
    moe_z_coef: float = 1e-3

    # -- derived --

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def compute_dtype(self):
        return jnp.dtype(self.compute_dtype_name)

    @property
    def param_dtype(self):
        return jnp.dtype(self.param_dtype_name)

    @property
    def vocab_padded(self) -> int:
        """vocab rounded up so the logits dim shards over 256 devices."""
        return -(-self.vocab // 256) * 256

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter count estimate (for 6ND model-FLOPs and logging)
    def param_count(self) -> int:
        from repro.models.transformer import model_params
        from repro.layers.common import count_params

        return count_params(model_params(self))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        total = self.param_count()
        if self.moe is None:
            return total
        m = self.moe
        per_expert = 3 * self.d_model * m.d_ff if m.gated else 2 * self.d_model * m.d_ff
        n_moe_layers = sum(1 for k in self.pattern if k == "moe") * self.repeats
        inactive = n_moe_layers * per_expert * (m.n_experts - m.top_k)
        return total - inactive


# registry filled by configs/__init__.py
_REGISTRY: dict[str, Any] = {}


def register(name: str, fn) -> None:
    _REGISTRY[name] = fn


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    return sorted(_REGISTRY)
