"""Assigned input shapes (4 per LM arch => 40 cells) + skip rules."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def skip_reason(cfg, shape: InputShape) -> str | None:
    """Why this (arch x shape) cell is skipped, or None if it runs."""
    for name, reason in cfg.skips:
        if name == shape.name:
            return reason
    if cfg.encoder_only and shape.mode == "decode":
        return "encoder-only architecture has no decode step"
    if shape.name == "long_500k":
        sub_quadratic = any(k in ("mamba2", "mlstm", "slstm") for k in cfg.pattern)
        if not sub_quadratic:
            return (
                "pure full-attention arch: O(L^2) prefill and 500k-token KV "
                "scores exceed the memory budget; run only for SSM/hybrid"
            )
    return None


def effective_mode(cfg, shape: InputShape) -> str:
    """Encoder archs lower prefill as a full encoder forward."""
    if cfg.encoder_only and shape.mode == "prefill":
        return "encoder"
    return shape.mode
