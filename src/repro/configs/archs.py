"""The 10 assigned architectures (exact configs from the assignment table)
plus reduced smoke variants. ``[source; tier]`` noted per arch.

Deviations from upstream checkpoints (documented in DESIGN.md §5):
  * hubert uses RoPE instead of its conv relative positional embedding
    (frontend is a stub per the assignment; pos-emb choice does not change
    the backbone's compute/communication shape);
  * zamba2's shared attention blocks are materialized per repeat (no
    cross-layer weight tying) — same compute, slightly more parameters;
  * vocab sizes are padded up to multiples of 256 for sharding (e.g.
    hubert 504 -> 512); loss masks the padded ids.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, XLSTMConfig

FULL_ATTN_SKIP = (
    ("long_500k",
     "pure full-attention arch: O(L^2) attention at 524288 tokens"),
)


def hubert_xlarge() -> ModelConfig:
    # [arXiv:2106.07447; unverified] encoder-only audio (w2v2 arch)
    return ModelConfig(
        name="hubert-xlarge",
        d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120, vocab=504,
        pattern=("attn",), repeats=48,
        act="gelu", encoder_only=True, frontend="audio",
        rope_theta=10000.0, attn_bias=True,
        norm_eps=1e-5,
    )


def dbrx_132b() -> ModelConfig:
    # [hf:databricks/dbrx-base; unverified] 16 experts top-4, fine-grained
    return ModelConfig(
        name="dbrx-132b",
        d_model=6144, n_heads=48, n_kv_heads=8, d_ff=0, vocab=100352,
        pattern=("moe",), repeats=40,
        moe=MoEConfig(n_experts=16, top_k=4, d_ff=10752, normalize_topk=True),
        rope_theta=500000.0,
        skips=FULL_ATTN_SKIP,
    )


def qwen3_moe_30b() -> ModelConfig:
    # [hf:Qwen/Qwen3-30B-A3B; hf] 128 experts top-8
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        d_model=2048, n_heads=32, n_kv_heads=4, d_ff=0, vocab=151936,
        head_dim=128,
        pattern=("moe",), repeats=48,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff=768, normalize_topk=True),
        rope_theta=1000000.0, qk_norm=True,
        skips=FULL_ATTN_SKIP,
    )


def zamba2_2p7b() -> ModelConfig:
    # [arXiv:2411.15242; hf] Mamba2 backbone + (shared) attention blocks
    return ModelConfig(
        name="zamba2-2.7b",
        d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000,
        pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2", "attn"),
        repeats=9,  # 54 layers
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1),
        act="gelu",
        rope_theta=10000.0,
    )


def gemma2_2b() -> ModelConfig:
    # [arXiv:2408.00118; hf] local+global alternating, logit softcaps
    return ModelConfig(
        name="gemma2-2b",
        d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216, vocab=256000,
        head_dim=256,
        pattern=("local_attn", "attn"), repeats=13,  # 26 layers
        window=4096, attn_softcap=50.0, final_softcap=30.0,
        act="geglu", tie_embeddings=True, embed_scale=True,
        zero_centered_norm=True, rope_theta=10000.0,
        sharding="fsdp",  # 8 heads cannot TP over 16-way model axis
        skips=FULL_ATTN_SKIP,
    )


def tinyllama_1b() -> ModelConfig:
    # [arXiv:2401.02385; hf] llama2-arch small
    return ModelConfig(
        name="tinyllama-1.1b",
        d_model=2048, n_heads=32, n_kv_heads=4, d_ff=5632, vocab=32000,
        pattern=("attn",), repeats=22,
        rope_theta=10000.0,
        skips=FULL_ATTN_SKIP,
    )


def glm4_9b() -> ModelConfig:
    # [hf:THUDM/glm-4-9b; hf] GQA kv=2, partial RoPE, qkv bias
    return ModelConfig(
        name="glm4-9b",
        d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696, vocab=151552,
        pattern=("attn",), repeats=40,
        rope_theta=10000.0, partial_rotary=0.5, attn_bias=True,
        norm_eps=1.5625e-7,
        skips=FULL_ATTN_SKIP,
    )


def command_r_35b() -> ModelConfig:
    # [hf:CohereForAI/c4ai-command-r-v01; unverified] no-bias, tied embeds
    return ModelConfig(
        name="command-r-35b",
        d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22528, vocab=256000,
        pattern=("attn",), repeats=40,
        rope_theta=8000000.0, tie_embeddings=True,
        norm_eps=1e-5,
        skips=FULL_ATTN_SKIP,
    )


def llava_next_mistral_7b() -> ModelConfig:
    # [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] anyres tiling stub
    return ModelConfig(
        name="llava-next-mistral-7b",
        d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000,
        pattern=("attn",), repeats=32,
        rope_theta=1000000.0,
        frontend="vlm", n_frontend_tokens=1152,  # anyres patches (stub)
        skips=FULL_ATTN_SKIP,
    )


def xlstm_350m() -> ModelConfig:
    # [arXiv:2405.04517; unverified] 7:1 mLSTM:sLSTM blocks; d_ff=0 ->
    # projections live inside the cells (xLSTM pre-up-projection blocks)
    return ModelConfig(
        name="xlstm-350m",
        d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
        pattern=("mlstm",) * 7 + ("slstm",), repeats=3,  # 24 layers
        xlstm=XLSTMConfig(proj_factor=2.0, d_conv=4),
        act="geglu",
        rope_theta=0.0,
        sharding="fsdp",  # 4 heads cannot TP over 16-way model axis
    )


ARCHS = {
    "hubert-xlarge": hubert_xlarge,
    "dbrx-132b": dbrx_132b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b,
    "zamba2-2.7b": zamba2_2p7b,
    "gemma2-2b": gemma2_2b,
    "tinyllama-1.1b": tinyllama_1b,
    "glm4-9b": glm4_9b,
    "command-r-35b": command_r_35b,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "xlstm-350m": xlstm_350m,
}


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: runs a forward/train step on CPU."""
    cfg = ARCHS[name]()
    kw: dict = dict(
        d_model=128,
        n_heads=4,
        n_kv_heads=min(4, cfg.n_kv_heads),
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab=512,
        repeats=2,
        q_chunk=64,
        kv_chunk=64,
        remat="none",
        n_frontend_tokens=16 if cfg.frontend == "vlm" else 0,
    )
    if cfg.moe:
        kw["moe"] = MoEConfig(
            n_experts=8, top_k=2, d_ff=64,
            normalize_topk=cfg.moe.normalize_topk,
            n_shared_experts=cfg.moe.n_shared_experts,
        )
    if cfg.ssm:
        kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32)
    if cfg.xlstm:
        kw["xlstm"] = XLSTMConfig(proj_factor=2.0, d_conv=4, chunk=32)
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
# Optimized presets — the §Perf hillclimbing outcomes (EXPERIMENTS.md).
# Baselines stay paper-faithful; these are the beyond-paper configurations,
# selectable via ``--optimized`` in repro.launch.dryrun / benchmarks.hillclimb.
# ---------------------------------------------------------------------------

def optimized_config(name: str) -> ModelConfig:
    import dataclasses as _dc

    cfg = ARCHS[name]()
    small_active = cfg.active_param_count() < 5e9
    kw: dict = {"causal_skip": not cfg.encoder_only,
                "q_chunk": 1024, "kv_chunk": 1024}
    if small_active and cfg.sharding == "megatron":
        # <5B active: activation gathers dominate param gathers (cell A/B)
        kw["sharding"] = "fsdp"
    if cfg.moe:
        kw["moe"] = _dc.replace(cfg.moe, capacity_factor=1.0)
    return cfg.replace(**kw)
