from repro.configs.archs import ARCHS, optimized_config, smoke_config
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, XLSTMConfig
from repro.configs.shapes import (
    SHAPE_BY_NAME,
    SHAPES,
    InputShape,
    effective_mode,
    skip_reason,
)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]()


def list_archs() -> list[str]:
    return sorted(ARCHS)


__all__ = [
    "ARCHS", "ModelConfig", "MoEConfig", "SSMConfig", "XLSTMConfig",
    "InputShape", "SHAPES", "SHAPE_BY_NAME", "get_config", "list_archs",
    "smoke_config", "optimized_config", "skip_reason", "effective_mode",
]
