"""Sharded checkpointing with atomic commit, async save, and elastic
resharding on restore.

Format: one directory per step:
    step_000123.tmp/            (written)
      manifest.json             flat-key -> {shape, dtype, file}
      arr_00000.npy ...
    step_000123/                (atomic rename = commit)

Fault-tolerance contract:
  * a crash mid-save never corrupts the latest checkpoint (tmp dir + rename);
  * restore accepts ANY target mesh/sharding (elastic scaling): arrays are
    loaded on host and re-placed with jax.device_put against the new
    sharding — a 256-chip checkpoint restores onto 8 chips and vice versa;
  * an optional background thread makes saves async (device->host copy is
    synchronous, file IO is not — the training loop continues).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import numpy as np

import jax
import ml_dtypes

from repro import compat

# numpy cannot natively (de)serialize ml_dtypes types; store them as
# same-width integer views and restore from the manifest dtype
_VIEW_AS = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
    "float8_e4m3b11fnuz": np.uint8,
}


def _flatten(tree) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def key(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return "/".join(parts)

    return {key(path): leaf for path, leaf in flat}


def save_checkpoint(tree, directory: str, step: int, async_: bool = False):
    """Save; returns a join() callable (no-op when synchronous)."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    # device -> host happens now (so training can mutate buffers after)
    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def write():
        manifest = {}
        for i, (k, arr) in enumerate(sorted(host.items())):
            fname = f"arr_{i:05d}.npy"
            dt = str(arr.dtype)
            to_disk = arr.view(_VIEW_AS[dt]) if dt in _VIEW_AS else arr
            np.save(os.path.join(tmp, fname), to_disk)
            manifest[k] = {
                "shape": list(arr.shape),
                "dtype": dt,
                "file": fname,
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "arrays": manifest}, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t.join
    write()
    return lambda: None


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(name.split("_")[1])
        for name in os.listdir(directory)
        if name.startswith("step_") and not name.endswith(".tmp")
        and os.path.exists(os.path.join(directory, name, "manifest.json"))
    ]
    return max(steps) if steps else None


def load_checkpoint(
    template, directory: str, step: int | None = None, shardings=None
):
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree of NamedSharding for the TARGET mesh —
    this is the elastic-resharding path (checkpoint written on any mesh
    restores onto any other).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["arrays"]

    flat_template = _flatten(template)
    flat_shardings = _flatten(shardings) if shardings is not None else {}
    out = {}
    for k, tmpl in flat_template.items():
        if k not in manifest:
            raise KeyError(f"checkpoint missing array {k!r}")
        arr = np.load(os.path.join(d, manifest[k]["file"]))
        stored = manifest[k]["dtype"]
        if stored in _VIEW_AS:
            arr = arr.view(ml_dtypes.bfloat16 if stored == "bfloat16"
                           else getattr(ml_dtypes, stored))
        want_dtype = getattr(tmpl, "dtype", arr.dtype)
        if str(arr.dtype) != str(want_dtype):
            arr = arr.astype(want_dtype)
        out[k] = compat.device_put(arr, flat_shardings.get(k))

    # unflatten back through the template treedef
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)

    def key(path) -> str:
        parts = []
        for p in path:
            parts.append(str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)))
        return "/".join(parts)

    leaves = [out[key(path)] for path, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class CheckpointManager:
    """Rolling checkpoints + restart + straggler-tolerant async saves."""

    def __init__(self, directory: str, keep: int = 3, async_: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_ = async_
        self._pending: list = []

    def save(self, tree, step: int) -> None:
        self._pending.append(save_checkpoint(tree, self.directory, step, self.async_))
        self._gc()

    def wait(self) -> None:
        for join in self._pending:
            join()
        self._pending.clear()

    def restore(self, template, shardings=None, step: int | None = None):
        return load_checkpoint(template, self.directory, step, shardings)

    def latest(self) -> int | None:
        return latest_step(self.directory)

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
