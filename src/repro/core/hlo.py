"""HLO-text cost analyzer — the PAPI-counter analogue (DESIGN.md §3).

TALP reads hardware counters (instructions, cycles) through PAPI. On
TPU/XLA the equivalent ground truth is the *optimized HLO module*: executed
FLOPs, HBM traffic and collective bytes. XLA's built-in
``compiled.cost_analysis()`` visits every instruction **once**, so anything
inside a ``while`` loop (every ``lax.scan``-over-layers model — i.e. all of
ours) is undercounted by the trip count. This module re-derives costs from
``compiled.as_text()`` with a call-graph-correct cost engine:

  * parses the computation graph once per distinct module text (results are
    cached on a content hash — ``StepProfile``/``monitor.attach_static``
    re-analyze identical modules for free),
  * propagates execution multiplicity **topologically** through the call
    graph: a computation executed from several call sites accumulates the
    *sum* of its call-site multiplicities, and ``while`` ops multiply their
    body/condition by the ``known_trip_count`` backend config,
  * treats ``call``/``while``/``conditional`` bodies as top-level code —
    their instructions contribute HBM traffic at their propagated
    multiplicity; only true ``fusion`` bodies are rolled up into the fusion
    instruction's operand/result traffic (un-fused ``call`` wrappers, which
    the CPU backend emits for parallel loops, previously zeroed
    ``hbm_bytes`` entirely),
  * counts dot FLOPs exactly (2 * result_elems * contracted_elems) via a
    per-computation symbol table (operand shapes),
  * extracts every collective with its replica groups, classifies ICI vs
    DCN by whether the group crosses a pod boundary, and reports both
    operand bytes (the roofline-spec convention) and ring wire bytes,
  * tags rematerialized dot FLOPs (op_name contains ``rematted``) so the
    FLOP-usefulness factor can attribute waste to remat,
  * emits a structured per-computation breakdown (``HloCost.per_computation``)
    consumed by core.profile / core.report.

This is deliberately a *text* analyzer: it needs nothing but what
``lowered.compile()`` already produced, works identically on the CPU
dry-run platform and real TPUs, and is unit-tested against hand-computed
modules plus cross-checked against ``cost_analysis()`` on loop-free graphs.
"""

from __future__ import annotations

import collections
import copy
import dataclasses
import hashlib
import re
from typing import Any

import numpy as np

from repro import compat as _compat

DTYPE_BYTES = {
    "pred": 1,
    "s2": 0.25, "u2": 0.25,
    "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2,
    "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e8m0fnu": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

# ops counted as 1 FLOP / element on the result
_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "sign",
    "cosine", "sine", "logistic", "expm1", "log1p", "atan2", "remainder",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "cbrt",
    "compare", "select", "clamp", "and", "or", "xor", "not", "erf",
}
# zero-cost / bookkeeping ops (no FLOPs, no modeled HBM traffic)
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id", "iota",
    "opt-barrier", "domain", "add-dependency",
}
COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)
# ops whose called computations run once per *caller* execution and whose
# bodies are therefore top-level code, NOT rolled-up kernels
_CONTROL_FLOW_OPS = ("while", "conditional", "call")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")


def _parse_instr_line(line: str):
    """Parse '%name = TYPE op(...), attrs' robustly.

    TYPE may be a tuple whose text embeds '/*index=N*/' comments (so no
    naive [^=] regex) — match balanced parens instead.
    """
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str = rest[: end + 1]
        rest2 = rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        rest2 = rest[sp + 1:].lstrip()
    m = _OP_RE.match(rest2)
    if not m:
        return None
    return Instruction(name, type_str, m.group(1), rest2[m.end():])
_COMP_NAME_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _comp_head(line: str) -> tuple[bool, str] | None:
    """Detect a computation definition header line.

    Headers look like ``%name (p: (s32[], ...)) -> (s32[], ...) {`` (params
    may nest parens, so this is not regex-parseable in one shot); instruction
    lines always contain ``=`` before the first ``(``.
    """
    s = line.rstrip()
    if not s.endswith("{") or "->" not in s:
        return None
    prefix = s.split("(", 1)[0]
    if "=" in prefix or prefix.strip().startswith("HloModule"):
        return None
    m = _COMP_NAME_RE.match(line)
    if not m:
        return None
    return bool(m.group(1)), m.group(2)


def _parse_shapes(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """All (dtype, dims) in a (possibly tuple) type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def shape_bytes(type_str: str) -> float:
    return sum(
        DTYPE_BYTES[dt] * float(np.prod(dims, dtype=np.float64)) if dims else DTYPE_BYTES[dt]
        for dt, dims in _parse_shapes(type_str)
    )


def shape_elems(type_str: str) -> float:
    return sum(
        float(np.prod(dims, dtype=np.float64)) if dims else 1.0
        for _, dims in _parse_shapes(type_str)
    )


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    op: str
    rest: str  # operand list + attributes (raw tail of the line)

    _operands: list[str] | None = None

    @property
    def operands(self) -> list[str]:
        if self._operands is None:
            # operand list = everything up to the matching close paren
            depth, end = 1, len(self.rest)
            for i, ch in enumerate(self.rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            ops = []
            for tok in self.rest[:end].split(","):
                tok = tok.strip()
                if tok.startswith("%"):
                    ops.append(tok[1:])
                else:
                    # typed operand "f32[2,3] %name"
                    m = re.search(r"%([\w\.\-]+)\s*$", tok)
                    if m:
                        ops.append(m.group(1))
            self._operands = ops
        return self._operands

    def attr(self, key: str) -> str | None:
        m = re.search(rf"{key}=(\{{[^=]*?\}}|\[[^\]]*\](?:<=\[[^\]]*\])?(?:T\([^)]*\))?|[\w\.\-\"%]+)", self.rest)
        return m.group(1) if m else None

    def int_list_attr(self, key: str) -> list[int]:
        m = re.search(rf"{key}={{([0-9,\s]*)}}", self.rest)
        if not m:
            return []
        return [int(t) for t in m.group(1).split(",") if t.strip()]


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instructions: dict[str, Instruction]


def parse_computations(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo_text.splitlines():
        head = _comp_head(line)
        if head is not None:
            cur = Computation(head[1], head[0], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        instr = _parse_instr_line(line)
        if instr is not None:
            cur.instructions[instr.name] = instr
    return comps


# ---------------------------------------------------------------------------
# replica groups
# ---------------------------------------------------------------------------


def parse_replica_groups(instr: Instruction) -> list[list[int]]:
    """Materialize replica groups from explicit or iota format."""
    # iota: replica_groups=[G,S]<=[d0,d1,...]T(p0,p1,...)
    m = re.search(
        r"replica_groups=\[([0-9,]+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?", instr.rest
    )
    if m:
        gshape = [int(x) for x in m.group(1).split(",")]
        dims = [int(x) for x in m.group(2).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(3):
            perm = [int(x) for x in m.group(3).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(gshape).tolist()
    # explicit: replica_groups={{0,1},{2,3}}
    m = re.search(r"replica_groups=\{(\{[^=]*?\})\}", instr.rest)
    if m:
        return [
            [int(x) for x in grp.split(",") if x.strip()]
            for grp in re.findall(r"\{([0-9,\s]*)\}", m.group(1))
        ]
    # collective-permute: source_target_pairs
    m = re.search(r"source_target_pairs=\{(.*?)\}\}", instr.rest)
    if m:
        return [
            [int(x) for x in pair.split(",")]
            for pair in re.findall(r"\{([0-9,\s]+)\}", m.group(0))
        ]
    return []


def groups_cross_pod(groups: list[list[int]], devices_per_pod: int | None) -> bool:
    if not devices_per_pod:
        return False
    for g in groups:
        pods = {d // devices_per_pod for d in g}
        if len(pods) > 1:
            return True
    return False


# ---------------------------------------------------------------------------
# call graph
# ---------------------------------------------------------------------------


def _trip_count(instr: Instruction) -> float:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"', instr.rest)
    if m:
        return float(m.group(1))
    return 1.0


_CALL_KEYS = ("body", "condition", "calls", "branch_computations",
              "true_computation", "false_computation")


def _called_comps(instr: Instruction) -> list[str]:
    """Computations invoked by this instruction.

    ``to_apply`` is only followed for ``call`` ops: on ``reduce``/
    ``all-reduce``/``scatter`` it names a per-element combiner (negligible,
    and counting its instructions at top level would be wrong), but on
    ``call`` it IS the body — skipping it silently dropped every un-fused
    call body from the cost model (the hbm_bytes=0.0 bug).
    """
    names: list[str] = []
    keys = _CALL_KEYS + (("to_apply",) if instr.op == "call" else ())
    for key in keys:
        m = re.search(rf"{key}=%?([\w\.\-]+)", instr.rest)
        if m:
            names.append(m.group(1))
        else:
            m = re.search(rf"{key}=\{{([^}}]*)\}}", instr.rest)
            if m:
                names += [t.strip().lstrip("%") for t in m.group(1).split(",") if t.strip()]
    return names


@dataclasses.dataclass
class ParsedModule:
    """One parsed + call-graph-analyzed HLO module (cacheable, immutable)."""

    computations: dict[str, Computation]
    entry: str | None
    multiplicity: dict[str, float]   # executions per module run, per comp
    comp_kind: dict[str, str]        # entry|fusion|while_body|while_cond|branch|called|unreachable
    fusion_bodies: frozenset[str]
    max_while_trip_count: int


def _build_module(hlo_text: str) -> ParsedModule:
    comps = parse_computations(hlo_text)

    # classify computations by how they are referenced + collect edges
    kind: dict[str, str] = {}
    fusion_bodies: set[str] = set()
    edges: dict[str, list[tuple[str, float]]] = {c: [] for c in comps}
    max_trips = 0
    for cname, comp in comps.items():
        for instr in comp.instructions.values():
            callees = [c for c in _called_comps(instr) if c in comps]
            if not callees:
                continue
            trips = _trip_count(instr) if instr.op == "while" else 1.0
            if instr.op == "while":
                max_trips = max(max_trips, int(trips))
            for callee in callees:
                edges[cname].append((callee, trips))
                if instr.op == "fusion":
                    fusion_bodies.add(callee)
                    kind.setdefault(callee, "fusion")
                elif instr.op == "while":
                    body = re.search(r"body=%?([\w\.\-]+)", instr.rest)
                    kind.setdefault(
                        callee,
                        "while_body" if body and body.group(1) == callee else "while_cond",
                    )
                elif instr.op == "conditional":
                    kind.setdefault(callee, "branch")
                else:
                    kind.setdefault(callee, "called")

    entry = next((c.name for c in comps.values() if c.is_entry), None)

    # multiplicity: topological accumulation over the call DAG. A computation
    # reached through several call sites executes the SUM of its call-site
    # multiplicities (cloned computations make this rare, but max — the old
    # behavior — undercounts when XLA does share one).
    mult: dict[str, float] = {}
    if entry is None:
        mult = {n: 1.0 for n in comps}  # fall back: every comp once
    else:
        kind[entry] = "entry"
        indeg: dict[str, int] = collections.Counter()
        for cname, out in edges.items():
            for callee, _ in out:
                indeg[callee] += 1
        mult[entry] = 1.0
        queue = collections.deque(
            [c for c in comps if indeg[c] == 0]
        )
        while queue:
            cname = queue.popleft()
            base = mult.get(cname)
            for callee, trips in edges[cname]:
                if base is not None:
                    mult[callee] = mult.get(callee, 0.0) + base * trips
                indeg[callee] -= 1
                if indeg[callee] == 0:
                    queue.append(callee)
        # comps never reached from ENTRY stay without multiplicity (dead code)
    for c in comps:
        kind.setdefault(c, "entry" if c == entry else "unreachable")

    return ParsedModule(
        computations=comps,
        entry=entry,
        multiplicity=mult,
        comp_kind=kind,
        fusion_bodies=frozenset(fusion_bodies),
        max_while_trip_count=max_trips,
    )


# ---------------------------------------------------------------------------
# parse / cost caches
# ---------------------------------------------------------------------------

_PARSE_CACHE: "collections.OrderedDict[str, ParsedModule]" = collections.OrderedDict()
_COST_CACHE: "collections.OrderedDict[tuple[str, int | None], HloCost]" = collections.OrderedDict()
_PARSE_CACHE_MAX = 64
_COST_CACHE_MAX = 128


def _text_key(hlo_text: str) -> str:
    return hashlib.blake2b(hlo_text.encode("utf-8", "surrogatepass"),
                           digest_size=16).hexdigest()


def parse_module(hlo_text: str) -> ParsedModule:
    """Parse + call-graph-analyze ``hlo_text`` (cached on a content hash).

    ``StepProfile.from_compiled`` / ``monitor.attach_static`` routinely see
    the same module text several times per process; re-parsing a multi-MB
    dump each time dominated attach time.
    """
    key = _text_key(hlo_text)
    mod = _PARSE_CACHE.get(key)
    if mod is not None:
        _PARSE_CACHE.move_to_end(key)
        return mod
    mod = _build_module(hlo_text)
    _PARSE_CACHE[key] = mod
    while len(_PARSE_CACHE) > _PARSE_CACHE_MAX:
        _PARSE_CACHE.popitem(last=False)
    return mod


def clear_caches() -> None:
    """Drop the parse/cost caches (tests, long-lived drivers)."""
    _PARSE_CACHE.clear()
    _COST_CACHE.clear()


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CollectiveCost:
    kind: str
    comp: str
    name: str
    result_bytes: float
    operand_bytes: float
    wire_bytes: float  # ring-algorithm bytes per participating device
    group_size: int
    multiplicity: float
    is_dcn: bool

    @property
    def total_operand_bytes(self) -> float:
        return self.operand_bytes * self.multiplicity

    @property
    def total_wire_bytes(self) -> float:
        return self.wire_bytes * self.multiplicity


@dataclasses.dataclass
class ComputationCost:
    """Per-computation slice of the module cost (per device, multiplicity
    already applied)."""

    name: str
    kind: str                 # entry|fusion|while_body|while_cond|branch|called|unreachable
    multiplicity: float
    num_instructions: int = 0
    flops: float = 0.0
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_operand_bytes: float = 0.0

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class HloCost:
    """Per-device costs of one compiled SPMD program execution."""

    flops: float = 0.0                 # all FLOPs (dots + elementwise + reduces)
    dot_flops: float = 0.0
    remat_dot_flops: float = 0.0       # dot FLOPs inside rematted computations
    hbm_bytes: float = 0.0             # modeled HBM traffic (fusion granularity)
    collective_operand_bytes_ici: float = 0.0
    collective_operand_bytes_dcn: float = 0.0
    collective_wire_bytes_ici: float = 0.0
    collective_wire_bytes_dcn: float = 0.0
    collectives: list[CollectiveCost] = dataclasses.field(default_factory=list)
    op_counts: dict[str, float] = dataclasses.field(default_factory=dict)
    per_computation: dict[str, ComputationCost] = dataclasses.field(default_factory=dict)
    max_while_trip_count: int = 0

    @property
    def collective_operand_bytes(self) -> float:
        return self.collective_operand_bytes_ici + self.collective_operand_bytes_dcn

    def collective_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for c in self.collectives:
            out[c.kind] = out.get(c.kind, 0) + 1
        return out

    def top_computations(self, n: int = 8, by: str = "hbm_bytes") -> list[ComputationCost]:
        """The n most expensive computations by ``by`` (hbm_bytes|flops)."""
        from repro.core.records import top_computations

        return top_computations(self.per_computation.values(), n, by)

    def to_json(self) -> dict[str, Any]:
        d = {
            k: getattr(self, k)
            for k in (
                "flops", "dot_flops", "remat_dot_flops", "hbm_bytes",
                "collective_operand_bytes_ici", "collective_operand_bytes_dcn",
                "collective_wire_bytes_ici", "collective_wire_bytes_dcn",
                "max_while_trip_count",
            )
        }
        d["op_counts"] = dict(self.op_counts)
        d["collectives"] = [
            {
                "kind": c.kind, "comp": c.comp, "name": c.name,
                "operand_bytes": c.operand_bytes, "wire_bytes": c.wire_bytes,
                "group_size": c.group_size, "multiplicity": c.multiplicity,
                "is_dcn": c.is_dcn,
            }
            for c in self.collectives
        ]
        d["per_computation"] = {
            name: cc.to_json() for name, cc in self.per_computation.items()
        }
        return d


def _dot_flops(instr: Instruction, symtab: dict[str, Instruction]) -> float:
    result_elems = shape_elems(instr.type_str)
    contract = instr.int_list_attr("lhs_contracting_dims")
    lhs_name = instr.operands[0] if instr.operands else None
    k = 1.0
    if lhs_name and lhs_name in symtab and contract:
        shapes = _parse_shapes(symtab[lhs_name].type_str)
        if shapes:
            _, dims = shapes[0]
            for d in contract:
                if d < len(dims):
                    k *= dims[d]
    return 2.0 * result_elems * k


def _compute_cost(mod: ParsedModule, devices_per_pod: int | None) -> HloCost:
    """Single pass over every live instruction, accumulating module totals
    and the per-computation breakdown together."""
    cost = HloCost(max_while_trip_count=mod.max_while_trip_count)

    for cname, comp in mod.computations.items():
        m = mod.multiplicity.get(cname)
        if m is None:
            continue
        inside_fusion = cname in mod.fusion_bodies
        breakdown = cost.per_computation[cname] = ComputationCost(
            name=cname, kind=mod.comp_kind.get(cname, "called"),
            multiplicity=m, num_instructions=len(comp.instructions),
        )
        symtab = comp.instructions
        for instr in comp.instructions.values():
            op = instr.op
            if op.endswith("-start"):
                base_kind = op[:-6]
            elif op.endswith("-done"):
                # the completion half of an async pair: the -start op carries
                # all modeled cost, so the -done contributes nothing (counting
                # it generically would double the pair's HBM traffic)
                if op[:-5] in COLLECTIVE_KINDS or op[:-5] in ("copy", "send", "recv"):
                    continue
                base_kind = op
            else:
                base_kind = op
            cost.op_counts[base_kind] = cost.op_counts.get(base_kind, 0.0) + m

            if base_kind in COLLECTIVE_KINDS:
                result_bytes = shape_bytes(instr.type_str)
                groups = parse_replica_groups(instr)
                if base_kind == "collective-permute":
                    g = 2
                else:
                    g = max((len(grp) for grp in groups), default=1)
                if base_kind == "all-gather":
                    operand_bytes = result_bytes / max(g, 1)
                    wire = result_bytes * (g - 1) / max(g, 1)
                elif base_kind == "reduce-scatter":
                    operand_bytes = result_bytes * g
                    wire = operand_bytes * (g - 1) / max(g, 1)
                elif base_kind == "all-reduce":
                    operand_bytes = result_bytes
                    wire = 2.0 * operand_bytes * (g - 1) / max(g, 1)
                elif base_kind in ("all-to-all", "ragged-all-to-all"):
                    operand_bytes = result_bytes
                    wire = operand_bytes * (g - 1) / max(g, 1)
                else:  # collective-permute / broadcast
                    operand_bytes = result_bytes
                    wire = result_bytes
                is_dcn = groups_cross_pod(groups, devices_per_pod)
                cost.collectives.append(
                    CollectiveCost(
                        kind=base_kind, comp=cname, name=instr.name,
                        result_bytes=result_bytes, operand_bytes=operand_bytes,
                        wire_bytes=wire, group_size=g, multiplicity=m,
                        is_dcn=is_dcn,
                    )
                )
                if is_dcn:
                    cost.collective_operand_bytes_dcn += operand_bytes * m
                    cost.collective_wire_bytes_dcn += wire * m
                else:
                    cost.collective_operand_bytes_ici += operand_bytes * m
                    cost.collective_wire_bytes_ici += wire * m
                breakdown.collective_operand_bytes += operand_bytes * m
                # collectives also touch HBM (read + write)
                cost.hbm_bytes += (operand_bytes + result_bytes) * m
                breakdown.hbm_bytes += (operand_bytes + result_bytes) * m
                continue

            if op in _FREE_OPS:
                continue

            if op == "dot":
                f = _dot_flops(instr, symtab) * m
                cost.flops += f
                cost.dot_flops += f
                breakdown.flops += f
                breakdown.dot_flops += f
                if "rematted" in instr.rest or "/checkpoint/" in instr.rest:
                    cost.remat_dot_flops += f
            elif op == "convolution":
                # rare here; approximate via result elems * window (unknown) -> count result
                f = 2.0 * shape_elems(instr.type_str) * m
                cost.flops += f
                breakdown.flops += f
            elif op in _ELEMENTWISE_FLOP_OPS:
                f = shape_elems(instr.type_str) * m
                cost.flops += f
                breakdown.flops += f
            elif op in ("reduce", "reduce-window"):
                # ~1 flop per input element
                for opn in instr.operands[: max(1, len(instr.operands) // 2)]:
                    if opn in symtab:
                        f = shape_elems(symtab[opn].type_str) * m
                        cost.flops += f
                        breakdown.flops += f

            # HBM traffic at fusion granularity. Fusion bodies are rolled up
            # into their fusion instruction's operand/result traffic;
            # call/while/conditional BODIES are top-level code and count in
            # full, while the call-site instructions themselves are skipped
            # (their operands/results are the body's parameters/root — the
            # body already accounts for that traffic).
            # Slicing ops read/write only the slice, not their operands.
            if not inside_fusion and op not in _CONTROL_FLOW_OPS:
                result_bytes = shape_bytes(instr.type_str)
                if op in ("dynamic-slice", "slice", "gather"):
                    traffic = 2.0 * result_bytes
                elif op == "dynamic-update-slice":
                    upd = (
                        shape_bytes(symtab[instr.operands[1]].type_str)
                        if len(instr.operands) > 1 and instr.operands[1] in symtab
                        else result_bytes
                    )
                    traffic = 2.0 * upd
                elif op == "scatter":
                    upd = (
                        shape_bytes(symtab[instr.operands[2]].type_str)
                        if len(instr.operands) > 2 and instr.operands[2] in symtab
                        else result_bytes
                    )
                    traffic = 2.0 * upd
                else:
                    traffic = result_bytes
                    for opn in instr.operands:
                        if opn in symtab:
                            traffic += shape_bytes(symtab[opn].type_str)
                cost.hbm_bytes += traffic * m
                breakdown.hbm_bytes += traffic * m

    return cost


def analyze_hlo(
    hlo_text: str,
    devices_per_pod: int | None = None,
) -> HloCost:
    """Analyze an optimized (post-SPMD-partitioning) HLO module dump.

    All numbers are **per device per execution** of the module;
    multiply by the device count for machine totals.

    Results are cached on (module-text hash, devices_per_pod); repeated
    analysis of an identical module is a dict hit plus a defensive copy.
    """
    key = (_text_key(hlo_text), devices_per_pod)
    cached = _COST_CACHE.get(key)
    if cached is not None:
        _COST_CACHE.move_to_end(key)
        return copy.deepcopy(cached)
    cost = _compute_cost(parse_module(hlo_text), devices_per_pod)
    _COST_CACHE[key] = cost
    while len(_COST_CACHE) > _COST_CACHE_MAX:
        _COST_CACHE.popitem(last=False)
    return copy.deepcopy(cost)


# ---------------------------------------------------------------------------
# integration with jax.stages (delegated to the version-compat layer)
# ---------------------------------------------------------------------------


def xla_cost_analysis(compiled) -> dict[str, float]:
    """Normalize compiled.cost_analysis() across jax versions."""
    return _compat.cost_analysis(compiled)


def memory_stats(compiled) -> dict[str, float]:
    return _compat.memory_stats(compiled)
