"""``talp`` CLI — the TALP-Pages command-line interface.

Mirrors the paper's commands:
  talp ci-report -i ./talp_folder -o output [--regions r1 r2]
                 [--region-for-badge r]
  talp metadata -i ./talp_folder [--extra k=v ...]
  talp merge-history --history old_talp --current talp
      (the ``talp download-gitlab`` + unzip + copy step, CI-agnostic:
       artifact download itself is one curl against the CI API; what the
       tool owns is the merge)
  talp badge -i ./talp_folder -o badge.svg [--region r]

Also usable as ``python -m repro.core.pages ...``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.core import folder as _folder
from repro.core import report as _report
from repro.core import scaling as _scaling
from repro.core.records import GLOBAL_REGION


def _cmd_ci_report(args: argparse.Namespace) -> int:
    experiments = _folder.scan(args.input)
    if not experiments:
        print(f"no run records found under {args.input}", file=sys.stderr)
        return 1
    index = _report.generate_report(
        experiments,
        args.output,
        regions=args.regions,
        region_for_badge=args.region_for_badge,
        overlap_fraction=args.overlap,
        title=args.title,
        top_computations=args.top_computations,
    )
    n_runs = sum(len(e.runs) for e in experiments)
    print(f"report: {index} ({len(experiments)} experiments, {n_runs} runs)")
    if args.print_tables:
        for exp in experiments:
            for region in [GLOBAL_REGION, *args.regions]:
                table = _scaling.build_table(exp.runs, region=region)
                if table:
                    print(f"\n== {exp.name} :: {region} ==")
                    print(_scaling.render_text(table))
    return 0


def _cmd_metadata(args: argparse.Namespace) -> int:
    meta = _folder.git_metadata(args.git_dir)
    for kv in args.extra:
        k, _, v = kv.partition("=")
        meta[k] = v
    n = _folder.add_metadata(args.input, meta)
    print(f"updated {n} run records with metadata {sorted(meta)}")
    return 0


def _cmd_merge_history(args: argparse.Namespace) -> int:
    if not os.path.isdir(args.history):
        print(f"no history at {args.history} (first pipeline run?) — nothing to merge")
        return 0
    n = _folder.merge_history(args.history, args.current)
    print(f"merged {n} historic run records into {args.current}")
    return 0


def _cmd_badge(args: argparse.Namespace) -> int:
    experiments = _folder.scan(args.input)
    value = None
    for exp in experiments:
        for run in _scaling.latest_per_config(exp.runs):
            reg = run.regions.get(args.region)
            if reg and "parallel_efficiency" in reg.pop:
                value = reg.pop["parallel_efficiency"]
    with open(args.output, "w") as f:
        f.write(_report.badge_svg(args.label, value))
    print(f"badge: {args.output} ({value})")
    return 0


def _cmd_validate(args: argparse.Namespace) -> int:
    """Validate the folder structure + every record's factor identities."""
    from repro.core import factors as F

    experiments = _folder.scan(args.input)
    bad = 0
    for exp in experiments:
        for run in exp.runs:
            for name, reg in run.regions.items():
                errs = F.validate_pop(reg.pop) if reg.pop else []
                for e in errs:
                    bad += 1
                    print(f"{exp.rel_path}: {run.timestamp} region {name}: {e}")
    print(f"{sum(len(e.runs) for e in experiments)} runs checked, {bad} violations")
    return 1 if bad else 0


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog="talp", description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)

    r = sub.add_parser("ci-report", help="generate the HTML report")
    r.add_argument("-i", "--input", required=True)
    r.add_argument("-o", "--output", required=True)
    r.add_argument("--regions", nargs="*", default=[])
    r.add_argument("--region-for-badge", default=None)
    r.add_argument("--overlap", type=float, default=0.0,
                   help="modeled compute/comm overlap fraction")
    r.add_argument("--top-computations", type=int, default=8, metavar="N",
                   help="rows in the per-computation drill-down tables/plots "
                        "(0 disables the breakdown)")
    r.add_argument("--title", default="TALP-Pages performance report")
    r.add_argument("--print-tables", action="store_true")
    r.set_defaults(fn=_cmd_ci_report)

    m = sub.add_parser("metadata", help="inject git metadata into run records")
    m.add_argument("-i", "--input", required=True)
    m.add_argument("--git-dir", default=".")
    m.add_argument("--extra", nargs="*", default=[], metavar="K=V")
    m.set_defaults(fn=_cmd_metadata)

    h = sub.add_parser("merge-history", help="merge previous pipeline artifacts")
    h.add_argument("--history", required=True)
    h.add_argument("--current", required=True)
    h.set_defaults(fn=_cmd_merge_history)

    b = sub.add_parser("badge", help="emit a parallel-efficiency badge")
    b.add_argument("-i", "--input", required=True)
    b.add_argument("-o", "--output", required=True)
    b.add_argument("--region", default=GLOBAL_REGION)
    b.add_argument("--label", default="parallel eff")
    b.set_defaults(fn=_cmd_badge)

    v = sub.add_parser("validate", help="check records + factor identities")
    v.add_argument("-i", "--input", required=True)
    v.set_defaults(fn=_cmd_validate)
    return p


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
