"""CI folder-structure handling (paper listing 2 + §CI Workflow).

Folder convention: a top-level folder contains experiment folders; any
folder that directly contains ``*.json`` run records is one experiment
(weak/strong scaling or resource comparison). Runs of the same experiment
accumulate in the same folder across CI pipelines (history arrives by
merging the previous pipeline's artifact, see ``merge_history``).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil

from repro.core.records import RunRecord


@dataclasses.dataclass
class Experiment:
    """One experiment folder: its relative path and loaded runs."""

    rel_path: str
    runs: list[RunRecord]

    @property
    def name(self) -> str:
        return self.rel_path.replace(os.sep, " / ")


def scan(root: str) -> list[Experiment]:
    """Find every experiment under ``root`` (depth-first, stable order)."""
    experiments: list[Experiment] = []
    root = os.fspath(root)
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        jsons = sorted(f for f in filenames if f.endswith(".json"))
        if not jsons:
            continue
        runs = []
        for f in jsons:
            path = os.path.join(dirpath, f)
            try:
                runs.append(RunRecord.load(path))
            except (json.JSONDecodeError, ValueError, KeyError) as e:
                # Tolerate foreign json artifacts in the tree; never die on
                # one bad file in CI (the report must still publish).
                print(f"[talp-pages] skipping unreadable run {path}: {e}")
        if runs:
            experiments.append(
                Experiment(rel_path=os.path.relpath(dirpath, root), runs=runs)
            )
    return experiments


def merge_history(history_root: str, current_root: str) -> int:
    """Copy historic run jsons into the current folder structure (the
    paper's "download previous pipeline artifacts and copy over" step).
    Existing files are never overwritten (current pipeline wins). Returns
    number of files merged."""
    merged = 0
    for dirpath, _, filenames in os.walk(history_root):
        rel = os.path.relpath(dirpath, history_root)
        for f in filenames:
            if not f.endswith(".json"):
                continue
            dst_dir = os.path.join(current_root, rel) if rel != "." else current_root
            dst = os.path.join(dst_dir, f)
            if os.path.exists(dst):
                continue
            os.makedirs(dst_dir, exist_ok=True)
            shutil.copy2(os.path.join(dirpath, f), dst)
            merged += 1
    return merged


def add_metadata(root: str, metadata: dict) -> int:
    """Inject (git) metadata into every run json under ``root`` that does
    not have it yet — the paper's ``talp metadata -i talp`` wrapper."""
    updated = 0
    for dirpath, _, filenames in os.walk(root):
        for f in filenames:
            if not f.endswith(".json"):
                continue
            path = os.path.join(dirpath, f)
            try:
                run = RunRecord.load(path)
            except (json.JSONDecodeError, ValueError, KeyError):
                continue
            changed = False
            for k, v in metadata.items():
                if k not in run.metadata:
                    run.metadata[k] = v
                    changed = True
            if changed:
                run.save(path)
                updated += 1
    return updated


def git_metadata(cwd: str = ".") -> dict:
    """Collect git metadata (commit, branch, commit timestamp) if available."""
    import subprocess

    def _git(*args: str) -> str | None:
        try:
            out = subprocess.run(
                ["git", *args], cwd=cwd, capture_output=True, text=True, timeout=10
            )
            return out.stdout.strip() if out.returncode == 0 else None
        except (OSError, subprocess.TimeoutExpired):
            return None

    meta = {}
    if commit := _git("rev-parse", "HEAD"):
        meta["git_commit"] = commit
        meta["git_commit_short"] = commit[:8]
    if branch := _git("rev-parse", "--abbrev-ref", "HEAD"):
        meta["git_branch"] = branch
    if ts := _git("show", "-s", "--format=%cI", "HEAD"):
        meta["git_commit_timestamp"] = ts
    return meta
