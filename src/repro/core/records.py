"""TALP JSON record schema.

One JSON file per run — the artifact TALP (the DLB module) writes after
execution and TALP-Pages consumes. This is the contract between the
*collection* side (``core.monitor`` running inside the training/serving
process) and the *reporting* side (``core.pages`` running later, possibly on
a different machine, from CI artifacts).

Layout mirrors DLB-TALP's pop-metrics JSON, adapted to the TPU/JAX setting
(DESIGN.md §3): MPI processes -> host processes, OpenMP threads -> local
devices, PAPI counters -> HLO-derived counters.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

SCHEMA_VERSION = 3

GLOBAL_REGION = "Global"


# --------------------------------------------------------------------------
# resource configuration
# --------------------------------------------------------------------------


@dataclasses.dataclass
class ResourceConfig:
    """Which resources a run used. The scaling table's column key.

    ``label`` renders like the paper's "2x56" (hosts x devices-per-host); the
    mesh dict carries the full axis split so factors can be attributed to
    ICI vs DCN domains.
    """

    num_hosts: int = 1
    devices_per_host: int = 1
    mesh: dict[str, int] = dataclasses.field(default_factory=dict)
    num_pods: int = 1

    @property
    def total_devices(self) -> int:
        return self.num_hosts * self.devices_per_host

    @property
    def label(self) -> str:
        return f"{self.num_hosts}x{self.devices_per_host}"

    def to_json(self) -> dict[str, Any]:
        return {
            "num_hosts": self.num_hosts,
            "devices_per_host": self.devices_per_host,
            "num_pods": self.num_pods,
            "mesh": dict(self.mesh),
        }

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "ResourceConfig":
        return cls(
            num_hosts=int(d.get("num_hosts", 1)),
            devices_per_host=int(d.get("devices_per_host", 1)),
            num_pods=int(d.get("num_pods", 1)),
            mesh=dict(d.get("mesh", {})),
        )


# --------------------------------------------------------------------------
# per-region data
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RegionCounters:
    """The PAPI-analogue counters for one region (DESIGN.md §3).

    useful_flops      -- executed HLO FLOPs attributed to this region (total,
                         all devices, whole region lifetime). The
                         "instructions" analogue.
    hlo_bytes         -- HBM bytes moved (total).
    collective_bytes  -- bytes through collectives, split by fabric domain.
    model_flops       -- 6*N*D-style useful model FLOPs (to expose
                         remat/redundancy waste as instruction inflation,
                         exactly what PAPI instruction counts catch on CPUs).
    """

    useful_flops: float = 0.0
    hlo_bytes: float = 0.0
    collective_bytes_ici: float = 0.0
    collective_bytes_dcn: float = 0.0
    model_flops: float = 0.0

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "RegionCounters":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: float(v) for k, v in d.items() if k in known})


@dataclasses.dataclass
class RegionMeasurements:
    """On-the-fly measured quantities for one region (O(1) memory).

    Times are host-wall seconds over the whole region lifetime (sum over
    visits). Load-balance inputs are dimensionless [0, 1] ratios
    (avg work / max work) accumulated as running step-weighted means; see
    monitor.LoadBalanceAccumulator.
    """

    elapsed_s: float = 0.0
    num_visits: int = 0
    num_steps: int = 0
    # measured device-work time (dispatch->block_until_ready), summed
    device_time_s: float = 0.0
    # data-parallel load balance from real token counts (padding skew)
    data_lb: float | None = None
    # expert-parallel load balance from router statistics (MoE only)
    expert_lb: float | None = None
    # host-level timing balance (multi-host; straggler indicator)
    host_lb: float | None = None
    in_pod_lb: float | None = None
    inter_pod_lb: float | None = None

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "RegionMeasurements":
        known = {f.name for f in dataclasses.fields(cls)}
        kw: dict[str, Any] = {}
        for k, v in d.items():
            if k not in known:
                continue
            if k in ("num_visits", "num_steps"):
                kw[k] = int(v)
            else:
                kw[k] = None if v is None else float(v)
        return cls(**kw)


# truncation knob shared by the collectors (MonitorConfig default, tracer):
# how many of the heaviest computations a region persists, ranked by
# RANK_METRIC. The regression side uses RANK_METRIC to decide whether a
# computation absent from one run's breakdown could merely sit below the cut.
DEFAULT_TOP_COMPUTATIONS = 16
RANK_METRIC = "hbm_bytes"


def top_computations(items, n: int = 8, by: str = RANK_METRIC) -> list:
    """The n heaviest per-computation cost entries by attribute ``by`` —
    the one ranking shared by HloCost, StepProfile and RegionRecord."""
    return sorted(items, key=lambda c: getattr(c, by), reverse=True)[: max(n, 0)]


def merge_computations(
    per_region, n: int = DEFAULT_TOP_COMPUTATIONS
) -> dict[str, "ComputationCounters"]:
    """Sum per-computation counters across regions and keep the heaviest n —
    the Global region's breakdown inheritance (monitor and tracer)."""
    agg: dict[str, ComputationCounters] = {}
    for comps in per_region:
        for cn, cc in comps.items():
            prev = agg.get(cn)
            if prev is None:
                agg[cn] = dataclasses.replace(cc)
            else:
                prev.flops += cc.flops
                prev.dot_flops += cc.dot_flops
                prev.hbm_bytes += cc.hbm_bytes
                prev.collective_operand_bytes += cc.collective_operand_bytes
    return {cc.name: cc for cc in top_computations(agg.values(), n)}


@dataclasses.dataclass
class ComputationCounters:
    """Counters for one HLO computation inside a region (schema v3).

    The per-computation slice of ``RegionCounters``: machine totals over the
    whole region lifetime, derived from the static ``StepProfile`` breakdown
    scaled by the observed step count. This is what lets a regression finding
    name the computation whose counters moved instead of stopping at the
    factor leaf (e.g. "communication efficiency -> while_body.all_gather.3").

    ``kind`` is the call-graph role from core.hlo (entry|fusion|while_body|
    while_cond|branch|called); ``multiplicity`` is executions per step.
    """

    name: str = ""
    kind: str = "called"
    multiplicity: float = 1.0
    num_instructions: int = 0
    flops: float = 0.0
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_operand_bytes: float = 0.0

    # metrics a regression can be attributed to (share-shift ranking)
    METRICS = ("flops", "hbm_bytes", "collective_operand_bytes")

    def to_json(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d.pop("name")  # dict key carries the name
        return d

    @classmethod
    def from_json(cls, name: str, d: dict[str, Any]) -> "ComputationCounters":
        return cls(
            name=name or str(d.get("name", "")),
            kind=str(d.get("kind", "called")),
            multiplicity=float(d.get("multiplicity", 1.0)),
            num_instructions=int(d.get("num_instructions", 0)),
            flops=float(d.get("flops", 0.0)),
            dot_flops=float(d.get("dot_flops", 0.0)),
            hbm_bytes=float(d.get("hbm_bytes", 0.0)),
            collective_operand_bytes=float(d.get("collective_operand_bytes", 0.0)),
        )

    def scaled(self, steps: float) -> "ComputationCounters":
        return dataclasses.replace(
            self,
            flops=self.flops * steps,
            dot_flops=self.dot_flops * steps,
            hbm_bytes=self.hbm_bytes * steps,
            collective_operand_bytes=self.collective_operand_bytes * steps,
        )


@dataclasses.dataclass
class RegionRecord:
    name: str
    measurements: RegionMeasurements = dataclasses.field(
        default_factory=RegionMeasurements
    )
    counters: RegionCounters = dataclasses.field(default_factory=RegionCounters)
    # POP factor hierarchy, filled by factors.compute_pop (flat dict:
    # factor name -> value). Persisted so the report side never recomputes
    # from raw data of old schema versions.
    pop: dict[str, float] = dataclasses.field(default_factory=dict)
    # per-HLO-computation slice of ``counters`` (schema v3; the heaviest
    # computations only — the monitor truncates to its top_computations knob)
    computations: dict[str, ComputationCounters] = dataclasses.field(
        default_factory=dict
    )

    def top_computations(self, n: int = 8, by: str = "hbm_bytes") -> list[ComputationCounters]:
        return top_computations(self.computations.values(), n, by)

    def to_json(self) -> dict[str, Any]:
        d = {
            "measurements": self.measurements.to_json(),
            "counters": self.counters.to_json(),
            "pop": dict(self.pop),
        }
        if self.computations:
            d["computations"] = {
                cn: cc.to_json() for cn, cc in self.computations.items()
            }
        return d

    @classmethod
    def from_json(cls, name: str, d: dict[str, Any]) -> "RegionRecord":
        return cls(
            name=name,
            measurements=RegionMeasurements.from_json(d.get("measurements", {})),
            counters=RegionCounters.from_json(d.get("counters", {})),
            pop={k: float(v) for k, v in d.get("pop", {}).items()},
            computations={
                cn: ComputationCounters.from_json(cn, cd)
                for cn, cd in d.get("computations", {}).items()
            },
        )


# --------------------------------------------------------------------------
# run record (one JSON file)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RunRecord:
    app_name: str
    resources: ResourceConfig
    timestamp: str  # ISO-8601, end of execution (DLB semantics)
    regions: dict[str, RegionRecord] = dataclasses.field(default_factory=dict)
    # git metadata; commit timestamp overrides `timestamp` for time series
    metadata: dict[str, Any] = dataclasses.field(default_factory=dict)
    hardware: str = "tpu_v5e"
    schema_version: int = SCHEMA_VERSION

    # ---- convenience ----

    @property
    def global_region(self) -> RegionRecord:
        return self.regions[GLOBAL_REGION]

    @property
    def series_timestamp(self) -> str:
        """Timestamp used for time-series ordering (paper: git commit
        timestamp when present, else DLB end-of-execution timestamp)."""
        return str(self.metadata.get("git_commit_timestamp") or self.timestamp)

    def region(self, name: str) -> RegionRecord:
        return self.regions[name]

    # ---- (de)serialization ----

    def to_json(self) -> dict[str, Any]:
        return {
            "schema_version": self.schema_version,
            "app_name": self.app_name,
            "timestamp": self.timestamp,
            "hardware": self.hardware,
            "resources": self.resources.to_json(),
            "metadata": dict(self.metadata),
            "regions": {n: r.to_json() for n, r in self.regions.items()},
        }

    def save(self, path: str | os.PathLike) -> None:
        path = os.fspath(path)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
        os.replace(tmp, path)  # atomic: CI artifact collection never sees partial files

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "RunRecord":
        ver = int(d.get("schema_version", 1))
        if ver > SCHEMA_VERSION:
            raise ValueError(
                f"run record schema {ver} is newer than supported {SCHEMA_VERSION}"
            )
        regions = {
            name: RegionRecord.from_json(name, rd)
            for name, rd in d.get("regions", {}).items()
        }
        metadata = dict(d.get("metadata", {}))
        if ver < 3:
            _migrate_v2_computations(regions, metadata)
        return cls(
            app_name=str(d.get("app_name", "unknown")),
            resources=ResourceConfig.from_json(d.get("resources", {})),
            timestamp=str(d.get("timestamp", "")),
            regions=regions,
            metadata=metadata,
            hardware=str(d.get("hardware", "tpu_v5e")),
            # migrated records are v3-shaped in memory; a re-save writes v3
            schema_version=SCHEMA_VERSION,
        )

    @classmethod
    def load(cls, path: str | os.PathLike) -> "RunRecord":
        with open(os.fspath(path)) as f:
            return cls.from_json(json.load(f))


def _migrate_v2_computations(
    regions: dict[str, RegionRecord], metadata: dict[str, Any]
) -> None:
    """v2 -> v3: lift the untyped ``metadata["per_computation"]`` blob
    (region -> list of {name, kind, ...} dicts, written by the old monitor)
    into the typed ``RegionRecord.computations`` field, in place.

    Keeps the paper's merge-history loop intact: old CI artifacts keep
    loading and render through the same per-computation drill-down as fresh
    v3 records.
    """
    blob = metadata.pop("per_computation", None)
    if not isinstance(blob, dict):
        return
    for region_name, comps in blob.items():
        reg = regions.get(region_name)
        if reg is None or not isinstance(comps, list):
            continue
        for cd in comps:
            if not isinstance(cd, dict):
                continue
            cname = str(cd.get("name", ""))
            if cname and cname not in reg.computations:
                reg.computations[cname] = ComputationCounters.from_json(cname, cd)


def load_folder(folder: str | os.PathLike) -> list[RunRecord]:
    """Load every ``*.json`` directly inside ``folder`` (non-recursive)."""
    folder = os.fspath(folder)
    runs = []
    for name in sorted(os.listdir(folder)):
        if name.endswith(".json"):
            runs.append(RunRecord.load(os.path.join(folder, name)))
    return runs
