"""Time-evolution series (paper §Time-evolution plots, Figure 7).

For each resource configuration in an experiment folder, order runs by the
series timestamp (git commit timestamp when present, else the DLB
end-of-execution timestamp) and expose per-region metric series:
elapsed time, the computation counters (FLOPs, throughput, frequency
analogues), parallel efficiency and its sub-metrics.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.core import factors as F
from repro.core.records import RunRecord

# metric groups rendered as plot rows (paper: elapsed | computation | efficiency)
SERIES_GROUPS: list[tuple[str, list[str]]] = [
    ("Elapsed time [s]", [F.ELAPSED_S]),
    (
        "Computation",
        [F.ACHIEVED_TFLOPS, F.MXU_UTIL, F.FLOP_USEFULNESS],
    ),
    (
        "Parallel efficiency",
        [F.PARALLEL_EFF, F.DISPATCH_EFF, F.COMM_EFF, F.LOAD_BALANCE],
    ),
    (
        "Sub-metrics",
        [F.ICI_COMM_EFF, F.DCN_COMM_EFF, F.DATA_LB, F.EXPERT_LB, F.HOST_LB],
    ),
]


@dataclasses.dataclass
class SeriesPoint:
    timestamp: str
    commit: str | None
    values: dict[str, float]  # factor key -> value (one region)
    # per-HLO-computation counters at this point (schema v3):
    # computation name -> {metric -> value}, metrics per
    # records.ComputationCounters.METRICS
    computations: dict[str, dict[str, float]] = dataclasses.field(
        default_factory=dict
    )


@dataclasses.dataclass
class RegionSeries:
    region: str
    points: list[SeriesPoint]

    def series(self, key: str) -> list[tuple[str, float]]:
        return [
            (p.timestamp, p.values[key]) for p in self.points if key in p.values
        ]

    def computation_series(self, metric: str = "hbm_bytes") -> dict[str, list[float]]:
        """Per-computation time series of one counter metric, aligned to
        ``points`` (NaN where a point lacks the computation — e.g. runs
        recorded before the computation existed or below the top-N cut)."""
        names: list[str] = []
        for p in self.points:
            for n in p.computations:
                if n not in names:
                    names.append(n)
        return {
            n: [p.computations.get(n, {}).get(metric, float("nan")) for p in self.points]
            for n in names
        }

    def top_computation_names(self, n: int = 5, metric: str = "hbm_bytes") -> list[str]:
        """Names of the n heaviest computations by peak ``metric`` over the
        series (the ones worth plotting)."""
        peak: dict[str, float] = {}
        for p in self.points:
            for cn, cv in p.computations.items():
                peak[cn] = max(peak.get(cn, 0.0), cv.get(metric, 0.0))
        return sorted(peak, key=lambda cn: peak[cn], reverse=True)[:n]


@dataclasses.dataclass
class ConfigSeries:
    """All region series for one resource configuration."""

    label: str
    regions: dict[str, RegionSeries]

    def to_json(self) -> dict[str, Any]:
        return {
            "label": self.label,
            "regions": {
                name: [
                    {
                        "timestamp": p.timestamp,
                        "commit": p.commit,
                        "values": p.values,
                        "computations": p.computations,
                    }
                    for p in rs.points
                ]
                for name, rs in self.regions.items()
            },
        }


def build_series(runs: list[RunRecord]) -> list[ConfigSeries]:
    by_config: dict[str, list[RunRecord]] = {}
    for run in runs:
        by_config.setdefault(run.resources.label, []).append(run)

    out = []
    for label in sorted(by_config, key=lambda s: [int(t) for t in s.split("x") if t.isdigit()] or [0]):
        cfg_runs = sorted(by_config[label], key=lambda r: r.series_timestamp)
        regions: dict[str, RegionSeries] = {}
        for run in cfg_runs:
            for name, reg in run.regions.items():
                rs = regions.setdefault(name, RegionSeries(region=name, points=[]))
                values = dict(reg.pop) if reg.pop else {}
                values.setdefault(F.ELAPSED_S, reg.measurements.elapsed_s)
                # raw counters/measurements (underscore keys): consumed by
                # regression detection to compute cross-run scalability
                values["_useful_flops"] = reg.counters.useful_flops
                values["_model_flops"] = reg.counters.model_flops
                values["_hbm_bytes"] = reg.counters.hlo_bytes
                values["_collective_bytes"] = (
                    reg.counters.collective_bytes_ici
                    + reg.counters.collective_bytes_dcn
                )
                values["_device_time_s"] = reg.measurements.device_time_s
                rs.points.append(
                    SeriesPoint(
                        timestamp=run.series_timestamp,
                        commit=run.metadata.get("git_commit_short")
                        or run.metadata.get("git_commit"),
                        values=values,
                        computations={
                            cn: {m: getattr(cc, m) for m in cc.METRICS}
                            for cn, cc in reg.computations.items()
                        },
                    )
                )
        out.append(ConfigSeries(label=label, regions=regions))
    return out
