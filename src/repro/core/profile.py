"""StepProfile — static per-step counters extracted from a compiled step.

Bridges the HLO analyzer (core.hlo) and the monitor/roofline consumers.
A StepProfile describes ONE execution of a compiled SPMD program across the
whole machine (totals = per-device HLO numbers x device count).
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.core import hlo as _hlo
from repro.core.hardware import ChipSpec, get_target
from repro.core.records import (
    ComputationCounters,
    RegionCounters,
    top_computations as _top_computations,
)


@dataclasses.dataclass
class StepProfile:
    """Machine-total static counters for one step execution."""

    num_devices: int = 1
    flops: float = 0.0                  # executed HLO FLOPs, total
    dot_flops: float = 0.0
    remat_dot_flops: float = 0.0
    hbm_bytes: float = 0.0              # HBM traffic, total
    collective_bytes_ici: float = 0.0   # operand-bytes convention, total
    collective_bytes_dcn: float = 0.0
    collective_wire_bytes_ici: float = 0.0
    collective_wire_bytes_dcn: float = 0.0
    model_flops: float = 0.0            # analytic useful FLOPs (6ND-style)
    model_bytes: float = 0.0            # analytic minimal HBM bytes (decode)
    collective_counts: dict[str, int] = dataclasses.field(default_factory=dict)
    xla_cost: dict[str, float] = dataclasses.field(default_factory=dict)
    memory: dict[str, float] = dataclasses.field(default_factory=dict)
    max_while_trip_count: int = 0
    # machine-total slice per HLO computation; flows typed into
    # RegionRecord.computations (schema v3) so a regression can be
    # attributed to a computation all the way down in the report
    per_computation: dict[str, ComputationCounters] = dataclasses.field(
        default_factory=dict
    )

    # ---- construction ----

    @classmethod
    def from_compiled(
        cls,
        compiled,
        num_devices: int,
        devices_per_pod: int | None = None,
        model_flops: float = 0.0,
        model_bytes: float = 0.0,
    ) -> "StepProfile":
        from repro import compat as _compat

        cost = _hlo.analyze_hlo(
            _compat.compiled_text(compiled), devices_per_pod=devices_per_pod
        )
        return cls.from_hlo_cost(
            cost,
            num_devices=num_devices,
            model_flops=model_flops,
            model_bytes=model_bytes,
            xla_cost=_hlo.xla_cost_analysis(compiled),
            memory=_hlo.memory_stats(compiled),
        )

    @classmethod
    def from_hlo_cost(
        cls,
        cost: _hlo.HloCost,
        num_devices: int,
        model_flops: float = 0.0,
        model_bytes: float = 0.0,
        xla_cost: dict[str, float] | None = None,
        memory: dict[str, float] | None = None,
    ) -> "StepProfile":
        n = max(num_devices, 1)
        per_comp = {
            name: ComputationCounters(
                name=name,
                kind=cc.kind,
                multiplicity=cc.multiplicity,
                num_instructions=cc.num_instructions,
                flops=cc.flops * n,
                dot_flops=cc.dot_flops * n,
                hbm_bytes=cc.hbm_bytes * n,
                collective_operand_bytes=cc.collective_operand_bytes * n,
            )
            for name, cc in cost.per_computation.items()
        }
        return cls(
            num_devices=n,
            model_bytes=model_bytes,
            flops=cost.flops * n,
            dot_flops=cost.dot_flops * n,
            remat_dot_flops=cost.remat_dot_flops * n,
            hbm_bytes=cost.hbm_bytes * n,
            collective_bytes_ici=cost.collective_operand_bytes_ici * n,
            collective_bytes_dcn=cost.collective_operand_bytes_dcn * n,
            collective_wire_bytes_ici=cost.collective_wire_bytes_ici * n,
            collective_wire_bytes_dcn=cost.collective_wire_bytes_dcn * n,
            model_flops=model_flops,
            collective_counts=cost.collective_counts(),
            xla_cost=dict(xla_cost or {}),
            memory=dict(memory or {}),
            max_while_trip_count=cost.max_while_trip_count,
            per_computation=per_comp,
        )

    # ---- transforms ----

    def scaled(self, steps: float) -> "StepProfile":
        kw = {
            k: getattr(self, k) * steps
            for k in (
                "flops", "dot_flops", "remat_dot_flops", "hbm_bytes",
                "collective_bytes_ici", "collective_bytes_dcn",
                "collective_wire_bytes_ici", "collective_wire_bytes_dcn",
                "model_flops", "model_bytes",
            )
        }
        return dataclasses.replace(
            self,
            collective_counts=dict(self.collective_counts),
            xla_cost=dict(self.xla_cost),
            memory=dict(self.memory),
            per_computation={
                name: cc.scaled(steps) for name, cc in self.per_computation.items()
            },
            **kw,
        )

    def top_computations(self, n: int = 8, by: str = "hbm_bytes") -> list[ComputationCounters]:
        """The n most expensive computations by ``by``."""
        return _top_computations(self.per_computation.values(), n, by)

    def to_counters(self) -> RegionCounters:
        return RegionCounters(
            useful_flops=self.flops,
            hlo_bytes=self.hbm_bytes,
            collective_bytes_ici=self.collective_bytes_ici,
            collective_bytes_dcn=self.collective_bytes_dcn,
            model_flops=self.model_flops,
        )

    # ---- roofline (the §Roofline three terms) ----

    def roofline_terms(self, spec: ChipSpec | str | None = None) -> dict[str, float]:
        """Seconds per step on the target hardware.

        compute    = HLO_FLOPs / (chips * peak)
        memory     = HLO_bytes / (chips * HBM_bw)
        collective = collective_bytes / (chips * link_bw)   [operand-bytes]
        """
        if not isinstance(spec, ChipSpec):
            spec = get_target(spec)
        n = self.num_devices
        compute = self.flops / (n * spec.peak_flops_bf16)
        memory = self.hbm_bytes / (n * spec.hbm_bandwidth)
        coll_ici = (self.collective_bytes_ici) / (n * spec.ici_bandwidth)
        coll_dcn = (self.collective_bytes_dcn) / (n * spec.dcn_bandwidth)
        collective = coll_ici + coll_dcn
        terms = {
            "compute_s": compute,
            "memory_s": memory,
            "collective_s": collective,
            "collective_ici_s": coll_ici,
            "collective_dcn_s": coll_dcn,
        }
        bound = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
        terms["bottleneck"] = bound  # type: ignore[assignment]
        terms["step_time_lower_bound_s"] = max(compute, memory, collective)
        terms["step_time_serial_s"] = compute + memory + collective
        if self.model_flops > 0:
            # MFU against the no-overlap serial model and the roofline bound
            ideal = self.model_flops / (n * spec.peak_flops_bf16)
            terms["roofline_fraction"] = ideal / max(terms["step_time_serial_s"], 1e-30)
            terms["roofline_fraction_overlapped"] = ideal / max(
                terms["step_time_lower_bound_s"], 1e-30
            )
            terms["model_to_hlo_flops"] = self.model_flops / max(self.flops, 1e-30)
        if self.model_bytes > 0:
            ideal_mem = self.model_bytes / (n * spec.hbm_bandwidth)
            terms["memory_roofline_fraction"] = ideal_mem / max(terms["memory_s"], 1e-30)
        return terms

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "StepProfile":
        known = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in known}
        kw["per_computation"] = {
            name: ComputationCounters.from_json(name, cd)
            for name, cd in (kw.get("per_computation") or {}).items()
        }
        return cls(**kw)
