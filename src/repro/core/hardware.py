"""Hardware target models.

The container runs on CPU; TPU v5e is the *target*. All roofline terms,
modeled communication times and "hardware counter" analogues (the PAPI
replacement, see DESIGN.md §3) are derived against these specs.

Numbers come from the task spec: 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. DCN bandwidth is an estimate for pod-to-pod traffic and
only enters the multi-pod communication model, never the required roofline
table (which is single-pod / ICI only).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float     # FLOP/s per chip
    hbm_bandwidth: float       # bytes/s per chip
    hbm_bytes: float           # HBM capacity per chip
    ici_bandwidth: float       # bytes/s per link (one direction)
    ici_links: int             # ICI links per chip (2D torus -> 4)
    dcn_bandwidth: float       # bytes/s per chip for cross-pod traffic
    clock_ghz: float           # nominal clock; TPUs do not DVFS under load
    vmem_bytes: float          # VMEM per core


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    hbm_bytes=16 * 1024**3,
    ici_bandwidth=50e9,
    ici_links=4,
    dcn_bandwidth=6.25e9,
    clock_ghz=0.94,
    vmem_bytes=128 * 1024**2,
)

# Used by unit tests that need a second target to assert spec-independence.
TPU_V5P = ChipSpec(
    name="tpu_v5p",
    peak_flops_bf16=459e12,
    hbm_bandwidth=2765e9,
    hbm_bytes=95 * 1024**3,
    ici_bandwidth=100e9,
    ici_links=6,
    dcn_bandwidth=6.25e9,
    clock_ghz=1.75,
    vmem_bytes=128 * 1024**2,
)

TARGETS = {s.name: s for s in (TPU_V5E, TPU_V5P)}
DEFAULT_TARGET = TPU_V5E


def get_target(name: str | None) -> ChipSpec:
    if name is None:
        return DEFAULT_TARGET
    try:
        return TARGETS[name]
    except KeyError:
        raise KeyError(f"unknown hardware target {name!r}; known: {sorted(TARGETS)}")
