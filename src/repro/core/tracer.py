"""Tracing-based baseline — the Score-P/Extrae stand-in (paper §Comparison).

The paper compares TALP-Pages against trace-based toolchains that can also
produce the scaling-efficiency table, at orders-of-magnitude higher
post-processing cost (Table 2). To reproduce that comparison end-to-end we
implement the baseline **inside** the framework: a tracer that records the
full event timeline (per device, per step, per region, per collective — the
granularity Extrae/Score-P record at) and a post-processor that recovers
the *same* POP factors from the trace (the Tables 6/7 cross-tool agreement
check).

Cost structure mirrors the real tools by construction:
  * runtime: an event append per (device, step, region, collective) —
    O(devices x steps) work and storage vs the monitor's O(regions) state;
  * post-processing: the whole trace is materialized and sorted (Paraver/
    Scalasca semantics) before factors are computed.

This module is intentionally *not* optimized: it is the honest baseline.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any

import numpy as np

from repro.core import factors as _factors
from repro.core.profile import StepProfile
from repro.core.records import (
    DEFAULT_TOP_COMPUTATIONS,
    GLOBAL_REGION,
    RegionCounters,
    RegionMeasurements,
    RegionRecord,
    ResourceConfig,
    RunRecord,
    merge_computations,
)


class TraceRecorder:
    """Records one event stream per (simulated) device rank, like Extrae's
    per-process .mpit files."""

    def __init__(
        self,
        trace_dir: str,
        resources: ResourceConfig,
        app_name: str = "app",
        clock=time.perf_counter,
    ) -> None:
        self.trace_dir = trace_dir
        self.resources = resources
        self.app_name = app_name
        self.clock = clock
        os.makedirs(trace_dir, exist_ok=True)
        self._files = [
            open(os.path.join(trace_dir, f"rank_{r:05d}.trace"), "w")
            for r in range(resources.total_devices)
        ]
        self._region_stack: list[str] = []
        self._step_profiles: dict[str, StepProfile] = {}
        self._t0 = self.clock()
        self._emit_all("region_enter", region=GLOBAL_REGION)

    # -- event emission ------------------------------------------------

    def _emit_all(self, kind: str, **fields: Any) -> None:
        t = self.clock() - self._t0
        for rank, f in enumerate(self._files):
            rec = {"t": t, "kind": kind, "rank": rank, **fields}
            f.write(json.dumps(rec))
            f.write("\n")

    def region_enter(self, name: str) -> None:
        self._region_stack.append(name)
        self._emit_all("region_enter", region=name)

    def region_exit(self, name: str) -> None:
        if self._region_stack and self._region_stack[-1] == name:
            self._region_stack.pop()
        self._emit_all("region_exit", region=name)

    def attach_static(self, region: str, profile: StepProfile) -> None:
        self._step_profiles[region] = profile

    def record_step(self, outputs: Any = None, **aux: Any) -> None:
        """One step: emits compute events plus one event per collective
        instance per device — the Extrae-style full-granularity record."""
        if outputs is not None:
            import jax

            jax.block_until_ready(outputs)
        region = self._region_stack[-1] if self._region_stack else GLOBAL_REGION
        self._emit_all("step", region=region)
        profile = self._step_profiles.get(region)
        if profile is not None:
            per_dev = max(profile.num_devices, 1)
            for kind, count in profile.collective_counts.items():
                bytes_per = (
                    (profile.collective_bytes_ici + profile.collective_bytes_dcn)
                    / per_dev
                    / max(sum(profile.collective_counts.values()), 1)
                )
                for i in range(int(count)):
                    self._emit_all(
                        "collective", coll=kind, idx=i, bytes=bytes_per, region=region
                    )
        for k, v in aux.items():
            if v is None:
                continue
            arr = np.asarray(v, dtype=np.float64).reshape(-1)
            self._emit_all(k, values=arr.tolist(), region=region)

    def close(self) -> dict[str, Any]:
        self._emit_all("region_exit", region=GLOBAL_REGION)
        meta = {
            "app_name": self.app_name,
            "resources": self.resources.to_json(),
            "profiles": {k: p.to_json() for k, p in self._step_profiles.items()},
        }
        with open(os.path.join(self.trace_dir, "trace_meta.json"), "w") as f:
            json.dump(meta, f)
        for f in self._files:
            f.close()
        return meta


# ---------------------------------------------------------------------------
# post-processing (the expensive path measured in benchmark Table 2)
# ---------------------------------------------------------------------------


def trace_storage_bytes(trace_dir: str) -> int:
    total = 0
    for name in os.listdir(trace_dir):
        total += os.path.getsize(os.path.join(trace_dir, name))
    return total


def post_process(trace_dir: str) -> RunRecord:
    """Reconstruct the run record (and POP factors) from the raw trace.

    Deliberately materializes the full, globally sorted event list first —
    this is what Paraver/Scalasca-style analysis does, and what makes the
    memory row of Table 2 large.
    """
    with open(os.path.join(trace_dir, "trace_meta.json")) as f:
        meta = json.load(f)
    resources = ResourceConfig.from_json(meta["resources"])
    profiles = {k: StepProfile.from_json(p) for k, p in meta.get("profiles", {}).items()}

    events: list[dict[str, Any]] = []
    for name in sorted(os.listdir(trace_dir)):
        if not name.endswith(".trace"):
            continue
        with open(os.path.join(trace_dir, name)) as f:
            for line in f:
                events.append(json.loads(line))
    events.sort(key=lambda e: (e["t"], e["rank"]))

    # timeline reconstruction per region
    @dataclasses.dataclass
    class _Reg:
        elapsed: float = 0.0
        visits: int = 0
        steps: int = 0
        t_enter: float | None = None
        last_t: float = 0.0
        device_time: float = 0.0
        data_lb_samples: list[float] = dataclasses.field(default_factory=list)
        expert_lb_samples: list[float] = dataclasses.field(default_factory=list)
        host_lb_samples: list[float] = dataclasses.field(default_factory=list)

    regs: dict[str, _Reg] = {}
    t_end = events[-1]["t"] if events else 0.0

    for ev in events:
        if ev["rank"] != 0:  # rank 0 carries the canonical timeline
            continue
        region = ev.get("region", GLOBAL_REGION)
        reg = regs.setdefault(region, _Reg())
        kind = ev["kind"]
        if kind == "region_enter":
            if reg.t_enter is None:
                reg.t_enter = ev["t"]
                reg.visits += 1
                reg.last_t = ev["t"]
        elif kind == "region_exit":
            if reg.t_enter is not None:
                reg.elapsed += ev["t"] - reg.t_enter
                reg.t_enter = None
        elif kind == "step":
            reg.steps += 1
            reg.device_time += ev["t"] - reg.last_t
            reg.last_t = ev["t"]
            for other in regs.values():
                if other is not reg and other.t_enter is not None:
                    other.steps += 0  # nested accounting happens via own events
        elif kind == "tokens_per_shard":
            w = np.asarray(ev["values"])
            if w.size and w.max() > 0:
                reg.data_lb_samples.append(float(w.mean() / w.max()))
        elif kind == "expert_load":
            w = np.asarray(ev["values"])
            if w.size and w.max() > 0:
                reg.expert_lb_samples.append(float(w.mean() / w.max()))
        elif kind == "host_times":
            w = np.asarray(ev["values"])
            if w.size and w.max() > 0:
                reg.host_lb_samples.append(float(w.mean() / w.max()))

    regions: dict[str, RegionRecord] = {}
    for name, reg in regs.items():
        if reg.t_enter is not None:  # unclosed region: close at trace end
            reg.elapsed += t_end - reg.t_enter
        meas = RegionMeasurements(
            elapsed_s=reg.elapsed,
            num_visits=reg.visits,
            num_steps=reg.steps,
            device_time_s=reg.device_time,
            data_lb=float(np.mean(reg.data_lb_samples)) if reg.data_lb_samples else None,
            expert_lb=float(np.mean(reg.expert_lb_samples)) if reg.expert_lb_samples else None,
            host_lb=float(np.mean(reg.host_lb_samples)) if reg.host_lb_samples else None,
        )
        counters = RegionCounters()
        computations = {}
        if name in profiles:
            scaled = profiles[name].scaled(max(reg.steps, 1))
            counters = scaled.to_counters()
            # same typed breakdown as the monitor (cross-tool agreement)
            computations = {
                cc.name: cc
                for cc in scaled.top_computations(DEFAULT_TOP_COMPUTATIONS)
            }
        regions[name] = RegionRecord(
            name=name, measurements=meas, counters=counters,
            computations=computations,
        )

    g = regions.setdefault(GLOBAL_REGION, RegionRecord(name=GLOBAL_REGION))
    if g.counters.useful_flops == 0.0:
        for name, r in regions.items():
            if name == GLOBAL_REGION:
                continue
            g.counters.useful_flops += r.counters.useful_flops
            g.counters.hlo_bytes += r.counters.hlo_bytes
            g.counters.collective_bytes_ici += r.counters.collective_bytes_ici
            g.counters.collective_bytes_dcn += r.counters.collective_bytes_dcn
            g.counters.model_flops += r.counters.model_flops
        if not g.computations:
            # Global inherits the child breakdown, exactly like the monitor
            g.computations = merge_computations(
                r.computations for n_, r in regions.items() if n_ != GLOBAL_REGION
            )

    import datetime as _dt

    run = RunRecord(
        app_name=meta.get("app_name", "app"),
        resources=resources,
        timestamp=_dt.datetime.now(_dt.timezone.utc).isoformat(),
        regions=regions,
    )
    for r in run.regions.values():
        r.pop = _factors.compute_pop(r, run.resources)
    return run
