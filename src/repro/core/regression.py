"""Regression detection + explanation (paper §Reports / Figure 7).

The paper's value proposition over wall-clock-only CI monitors: when
elapsed time changes, the POP factor hierarchy *explains* it. Given the
time series of one (region, resource configuration), we compare each run
to the previous one; if elapsed time moved more than ``threshold``, we walk
the factor tree to the deepest factor whose change is sufficient to explain
the move ("OpenMP serialization efficiency is responsible for the parallel
efficiency increase" in the paper's GENE-X study becomes e.g. "dispatch
efficiency is responsible for the parallel-efficiency drop" here).
"""

from __future__ import annotations

import dataclasses

from repro.core import factors as F
from repro.core.timeseries import RegionSeries


@dataclasses.dataclass
class Finding:
    kind: str            # "regression" | "improvement"
    region: str
    config_label: str
    timestamp: str
    commit: str | None
    elapsed_before: float
    elapsed_after: float
    rel_change: float    # (after-before)/before; negative = faster
    explanation: list[str]   # factor path, outermost -> deepest
    factor_changes: dict[str, tuple[float, float]]

    def describe(self) -> str:
        direction = "improvement" if self.rel_change < 0 else "regression"
        pct = abs(self.rel_change) * 100.0
        where = f"{self.region} @ {self.config_label}"
        head = f"{direction} of {pct:.1f}% in elapsed time ({where})"
        if self.commit:
            head += f" at commit {self.commit}"
        if not self.explanation:
            return head + " — no factor change explains it (likely machine noise or external change)"
        path = " -> ".join(F.DISPLAY_NAMES.get(k, k) for k in self.explanation)
        leaf = self.explanation[-1]
        b, a = self.factor_changes[leaf]
        return f"{head} — explained by {path} ({b:.3f} -> {a:.3f})"


def _tree_children(key: str, node=F.FACTOR_TREE):
    name, children = node
    if name == key:
        return children
    for ch in children:
        found = _tree_children(key, ch)
        if found is not None:
            return found
    return None


def explain(
    before: dict[str, float],
    after: dict[str, float],
    factor_threshold: float = 0.02,
) -> tuple[list[str], dict[str, tuple[float, float]]]:
    """Walk the factor tree from the root; at each level descend into the
    child with the largest relative change (if above threshold). Returns the
    path and the (before, after) values of every factor on it."""
    path: list[str] = []
    changes: dict[str, tuple[float, float]] = {}
    key = F.GLOBAL_EFF
    while True:
        b, a = before.get(key), after.get(key)
        if b is None or a is None or b <= 0:
            break
        rel = abs(a - b) / b
        if rel < factor_threshold:
            break
        path.append(key)
        changes[key] = (b, a)
        children = _tree_children(key) or []
        best, best_rel = None, factor_threshold
        for child_node in children:
            ck = child_node[0]
            cb, ca = before.get(ck), after.get(ck)
            if cb is None or ca is None or cb <= 0:
                continue
            crel = abs(ca - cb) / cb
            if crel > best_rel:
                best, best_rel = ck, crel
        if best is None:
            break
        key = best
    return path, changes


def _with_cross_run_scalability(
    before: dict[str, float], after: dict[str, float]
) -> dict[str, float]:
    """Recompute ``after``'s computation-scalability branch relative to
    ``before`` (same input, same resources => strong-scaling assumption:
    total executed FLOPs should be constant; a remat/recompute bug shows up
    as flop_scaling < 1, a slower-kernel bug as throughput_scaling < 1)."""
    out = dict(after)
    bf, af = before.get("_useful_flops", 0.0), after.get("_useful_flops", 0.0)
    flop = bf / af if bf > 0 and af > 0 else 1.0
    bt, at_ = before.get("_device_time_s", 0.0), after.get("_device_time_s", 0.0)
    if bf > 0 and af > 0 and bt > 0 and at_ > 0:
        thr = (af / at_) / (bf / bt)
    else:
        thr = 1.0
    out[F.FLOP_SCALING] = flop
    out[F.THROUGHPUT_SCALING] = thr
    out[F.FREQUENCY_SCALING] = 1.0
    out[F.COMP_SCALABILITY] = flop * thr
    if F.PARALLEL_EFF in out:
        out[F.GLOBAL_EFF] = out[F.PARALLEL_EFF] * out[F.COMP_SCALABILITY]
    return out


def detect(
    series: RegionSeries,
    config_label: str,
    threshold: float = 0.05,
    factor_threshold: float = 0.02,
) -> list[Finding]:
    """Scan consecutive runs of one region/configuration for elapsed-time
    changes beyond ``threshold`` and explain each via the factor tree."""
    findings: list[Finding] = []
    pts = series.points
    for prev, cur in zip(pts, pts[1:]):
        eb = prev.values.get(F.ELAPSED_S)
        ea = cur.values.get(F.ELAPSED_S)
        if not eb or ea is None or eb <= 0:
            continue
        rel = (ea - eb) / eb
        if abs(rel) < threshold:
            continue
        after = _with_cross_run_scalability(prev.values, cur.values)
        path, changes = explain(prev.values, after, factor_threshold)
        findings.append(
            Finding(
                kind="improvement" if rel < 0 else "regression",
                region=series.region,
                config_label=config_label,
                timestamp=cur.timestamp,
                commit=cur.commit,
                elapsed_before=eb,
                elapsed_after=ea,
                rel_change=rel,
                explanation=path,
                factor_changes=changes,
            )
        )
    return findings
