"""Regression detection + explanation (paper §Reports / Figure 7).

The paper's value proposition over wall-clock-only CI monitors: when
elapsed time changes, the POP factor hierarchy *explains* it. Given the
time series of one (region, resource configuration), we compare each run
to the previous one; if elapsed time moved more than ``threshold``, we walk
the factor tree to the deepest factor whose change is sufficient to explain
the move ("OpenMP serialization efficiency is responsible for the parallel
efficiency increase" in the paper's GENE-X study becomes e.g. "dispatch
efficiency is responsible for the parallel-efficiency drop" here).

Schema v3 records carry a typed per-computation counter breakdown
(``RegionRecord.computations``), so the walk no longer stops at the factor
leaf: ``detect``/``explain_computations`` descend one more level and the
``Finding`` names the HLO computation(s) whose counter share shifted most —
e.g. "explained by Communication efficiency -> `while_body.all_gather.3`
(+41% collective bytes)".
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import factors as F
from repro.core.records import RANK_METRIC
from repro.core.timeseries import RegionSeries

# Which counter metric a leaf factor implicates. Communication factors move
# with collective traffic; FLOP scaling with executed FLOPs; throughput /
# dispatch with kernel cost (HBM traffic is the usual driver on TPUs).
# Factors without an entry (load balances) are measured, not counter-derived,
# so attribution falls back to the largest shift across all metrics.
_LEAF_METRIC: dict[str, str] = {
    F.COMM_EFF: "collective_operand_bytes",
    F.ICI_COMM_EFF: "collective_operand_bytes",
    F.DCN_COMM_EFF: "collective_operand_bytes",
    F.COMP_SCALABILITY: "flops",
    F.FLOP_SCALING: "flops",
    F.THROUGHPUT_SCALING: "hbm_bytes",
    F.DISPATCH_EFF: "hbm_bytes",
}

_METRIC_LABELS = {
    "flops": "flops",
    "hbm_bytes": "hbm bytes",
    "collective_operand_bytes": "collective bytes",
}


@dataclasses.dataclass
class ComputationShift:
    """One HLO computation whose counter moved between two runs."""

    name: str
    metric: str          # which ComputationCounters metric shifted
    before: float
    after: float
    share_shift: float   # |after-before| / max(metric totals of both runs)

    @property
    def rel_change(self) -> float:
        if self.before > 0:
            return (self.after - self.before) / self.before
        return float("inf") if self.after > 0 else 0.0

    def describe(self) -> str:
        label = _METRIC_LABELS.get(self.metric, self.metric)
        if self.before > 0 and self.after > 0:
            return f"`{self.name}` ({self.rel_change * 100.0:+.0f}% {label})"
        if self.before == 0:
            return f"`{self.name}` (new, {label})"
        return f"`{self.name}` (gone, {label})"

    def to_json(self) -> dict:
        rel = self.rel_change
        return {
            "name": self.name, "metric": self.metric,
            "before": self.before, "after": self.after,
            # inf (computation appeared) is not valid JSON; null means "new"
            "rel_change": rel if math.isfinite(rel) else None,
            "share_shift": self.share_shift,
        }


@dataclasses.dataclass
class Finding:
    kind: str            # "regression" | "improvement"
    region: str
    config_label: str
    timestamp: str
    commit: str | None
    elapsed_before: float
    elapsed_after: float
    rel_change: float    # (after-before)/before; negative = faster
    explanation: list[str]   # factor path, outermost -> deepest
    factor_changes: dict[str, tuple[float, float]]
    # one level deeper than the factor leaf: the computations whose counter
    # share shifted most (empty when the records carry no breakdown)
    computations: list[ComputationShift] = dataclasses.field(default_factory=list)

    def describe(self) -> str:
        direction = "improvement" if self.rel_change < 0 else "regression"
        pct = abs(self.rel_change) * 100.0
        where = f"{self.region} @ {self.config_label}"
        head = f"{direction} of {pct:.1f}% in elapsed time ({where})"
        if self.commit:
            head += f" at commit {self.commit}"
        if not self.explanation:
            tail = " — no factor change explains it (likely machine noise or external change)"
            if self.computations:
                tail = " — no factor change explains it; counter shift in " + ", ".join(
                    c.describe() for c in self.computations
                )
            return head + tail
        path = " -> ".join(F.DISPLAY_NAMES.get(k, k) for k in self.explanation)
        leaf = self.explanation[-1]
        b, a = self.factor_changes[leaf]
        out = f"{head} — explained by {path} ({b:.3f} -> {a:.3f})"
        if self.computations:
            out += " -> " + ", ".join(c.describe() for c in self.computations)
        return out


def _tree_children(key: str, node=F.FACTOR_TREE):
    name, children = node
    if name == key:
        return children
    for ch in children:
        found = _tree_children(key, ch)
        if found is not None:
            return found
    return None


def explain(
    before: dict[str, float],
    after: dict[str, float],
    factor_threshold: float = 0.02,
) -> tuple[list[str], dict[str, tuple[float, float]]]:
    """Walk the factor tree from the root; at each level descend into the
    child with the largest relative change (if above threshold). Returns the
    path and the (before, after) values of every factor on it."""
    path: list[str] = []
    changes: dict[str, tuple[float, float]] = {}
    key = F.GLOBAL_EFF
    while True:
        b, a = before.get(key), after.get(key)
        if b is None or a is None or b <= 0:
            break
        rel = abs(a - b) / b
        if rel < factor_threshold:
            break
        path.append(key)
        changes[key] = (b, a)
        children = _tree_children(key) or []
        best, best_rel = None, factor_threshold
        for child_node in children:
            ck = child_node[0]
            cb, ca = before.get(ck), after.get(ck)
            if cb is None or ca is None or cb <= 0:
                continue
            crel = abs(ca - cb) / cb
            if crel > best_rel:
                best, best_rel = ck, crel
        if best is None:
            break
        key = best
    return path, changes


def explain_computations(
    before: dict[str, dict[str, float]],
    after: dict[str, dict[str, float]],
    metric: str | None = None,
    top_n: int = 3,
    min_share_shift: float = 0.02,
) -> list[ComputationShift]:
    """Descend below the factor leaf: rank HLO computations by how much of
    the region's counter total their change accounts for.

    ``before``/``after`` map computation name -> {metric -> value} (the
    ``SeriesPoint.computations`` shape). With ``metric`` given (from the
    factor leaf via ``_LEAF_METRIC``) only that counter is ranked; otherwise
    each computation is scored on its most-shifted metric. Share-of-total
    ranking (|delta| / max(total_before, total_after)) keeps tiny-but-noisy
    computations out even when their relative change is huge.

    The persisted breakdowns are top-N truncated (MonitorConfig
    .top_computations, ranked by ``records.RANK_METRIC``), so a computation
    missing from one side may merely have fallen below that side's cut, not
    appeared/vanished. A one-sided computation is attributed only when its
    RANK_METRIC value exceeds the absent side's cut (the smallest retained
    value) — it could not have been truncated away — and is then genuinely
    "new"/"gone" (missing values are 0).
    """
    if not before or not after:
        # one side carries no breakdown at all (pre-v3 record): any
        # attribution would mark every computation new/gone — say nothing
        return []
    metrics = [metric] if metric else list(_METRIC_LABELS)
    totals = {
        m: max(
            sum(c.get(m, 0.0) for c in before.values()),
            sum(c.get(m, 0.0) for c in after.values()),
            1e-30,
        )
        for m in metrics
    }
    cut_b = min((c.get(RANK_METRIC, 0.0) for c in before.values()), default=0.0)
    cut_a = min((c.get(RANK_METRIC, 0.0) for c in after.values()), default=0.0)
    shifts: list[ComputationShift] = []
    for name in {*before, *after}:
        b_c, a_c = before.get(name), after.get(name)
        if b_c is None and a_c.get(RANK_METRIC, 0.0) <= cut_b:
            continue  # may just sit below before's truncation cut
        if a_c is None and b_c.get(RANK_METRIC, 0.0) <= cut_a:
            continue  # may just sit below after's truncation cut
        best: ComputationShift | None = None
        for m in metrics:
            b = b_c.get(m, 0.0) if b_c is not None else 0.0
            a = a_c.get(m, 0.0) if a_c is not None else 0.0
            share = abs(a - b) / totals[m]
            if best is None or share > best.share_shift:
                best = ComputationShift(
                    name=name, metric=m, before=b, after=a, share_shift=share
                )
        if best is not None and best.share_shift >= min_share_shift:
            shifts.append(best)
    shifts.sort(key=lambda s: s.share_shift, reverse=True)
    return shifts[:top_n]


def _with_cross_run_scalability(
    before: dict[str, float], after: dict[str, float]
) -> dict[str, float]:
    """Recompute ``after``'s computation-scalability branch relative to
    ``before`` (same input, same resources => strong-scaling assumption:
    total executed FLOPs should be constant; a remat/recompute bug shows up
    as flop_scaling < 1, a slower-kernel bug as throughput_scaling < 1)."""
    out = dict(after)
    bf, af = before.get("_useful_flops", 0.0), after.get("_useful_flops", 0.0)
    flop = bf / af if bf > 0 and af > 0 else 1.0
    bt, at_ = before.get("_device_time_s", 0.0), after.get("_device_time_s", 0.0)
    if bf > 0 and af > 0 and bt > 0 and at_ > 0:
        thr = (af / at_) / (bf / bt)
    else:
        thr = 1.0
    out[F.FLOP_SCALING] = flop
    out[F.THROUGHPUT_SCALING] = thr
    out[F.FREQUENCY_SCALING] = 1.0
    out[F.COMP_SCALABILITY] = flop * thr
    if F.PARALLEL_EFF in out:
        out[F.GLOBAL_EFF] = out[F.PARALLEL_EFF] * out[F.COMP_SCALABILITY]
    return out


def detect(
    series: RegionSeries,
    config_label: str,
    threshold: float = 0.05,
    factor_threshold: float = 0.02,
) -> list[Finding]:
    """Scan consecutive runs of one region/configuration for elapsed-time
    changes beyond ``threshold`` and explain each via the factor tree."""
    findings: list[Finding] = []
    pts = series.points
    for prev, cur in zip(pts, pts[1:]):
        eb = prev.values.get(F.ELAPSED_S)
        ea = cur.values.get(F.ELAPSED_S)
        if not eb or ea is None or eb <= 0:
            continue
        rel = (ea - eb) / eb
        if abs(rel) < threshold:
            continue
        after = _with_cross_run_scalability(prev.values, cur.values)
        path, changes = explain(prev.values, after, factor_threshold)
        leaf_metric = _LEAF_METRIC.get(path[-1]) if path else None
        comps = explain_computations(
            prev.computations, cur.computations, metric=leaf_metric
        )
        findings.append(
            Finding(
                kind="improvement" if rel < 0 else "regression",
                region=series.region,
                config_label=config_label,
                timestamp=cur.timestamp,
                commit=cur.commit,
                elapsed_before=eb,
                elapsed_after=ea,
                rel_change=rel,
                explanation=path,
                factor_changes=changes,
                computations=comps,
            )
        )
    return findings
