"""repro.core — TALP-Pages for JAX: the paper's contribution.

Public API:
  MonitorConfig                 on-the-fly POP collection knobs (TALP)
  StepProfile                   compiled-step static counters (PAPI analogue)
  RunRecord / ResourceConfig    the JSON artifact schema
  build_table / render_text     scaling-efficiency tables
  generate_report               static HTML report (TALP-Pages)
  scan / merge_history          CI folder handling
  post_process                  trace post-processing (Score-P/Extrae stand-in)

Collectors (``TalpMonitor``, ``TraceRecorder``) are constructed exclusively
behind ``repro.session.PerfSession`` — the one instrumentation surface. The
one-release deprecation aliases here were removed after PR 3; select a
backend via ``SessionConfig(backend="monitor"|"tracer")`` or ``TALP_ENABLE=1
TALP_BACKEND=...`` instead.
"""

from repro.core.factors import compute_pop, validate_pop
from repro.core.folder import Experiment, git_metadata, merge_history, scan
from repro.core.hardware import DEFAULT_TARGET, TPU_V5E, TPU_V5P, ChipSpec, get_target
from repro.core.monitor import MonitorConfig
from repro.core.profile import StepProfile
from repro.core.records import (
    GLOBAL_REGION,
    SCHEMA_VERSION,
    ComputationCounters,
    RegionCounters,
    RegionMeasurements,
    RegionRecord,
    ResourceConfig,
    RunRecord,
)
from repro.core.regression import ComputationShift, Finding, detect, explain_computations
from repro.core.report import badge_svg, generate_report
from repro.core.scaling import ScalingTable, build_table, latest_per_config, render_text
from repro.core.timeseries import build_series
from repro.core.tracer import post_process, trace_storage_bytes

__all__ = [
    "MonitorConfig", "StepProfile", "RunRecord", "RegionRecord",
    "RegionCounters", "RegionMeasurements", "ComputationCounters",
    "ResourceConfig", "GLOBAL_REGION", "SCHEMA_VERSION",
    "ComputationShift", "Finding", "detect", "explain_computations",
    "ChipSpec", "TPU_V5E", "TPU_V5P", "DEFAULT_TARGET", "get_target",
    "compute_pop", "validate_pop", "build_table", "render_text", "ScalingTable",
    "latest_per_config", "build_series", "generate_report", "badge_svg",
    "scan", "merge_history", "git_metadata", "Experiment",
    "post_process", "trace_storage_bytes",
]
