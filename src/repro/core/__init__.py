"""repro.core — TALP-Pages for JAX: the paper's contribution.

Public API:
  TalpMonitor / MonitorConfig   on-the-fly POP factor collection (TALP)
  StepProfile                   compiled-step static counters (PAPI analogue)
  RunRecord / ResourceConfig    the JSON artifact schema
  build_table / render_text     scaling-efficiency tables
  generate_report               static HTML report (TALP-Pages)
  scan / merge_history          CI folder handling
  TraceRecorder / post_process  the tracing baseline (Score-P/Extrae stand-in)
"""

import warnings as _warnings

from repro.core.factors import compute_pop, validate_pop
from repro.core.folder import Experiment, git_metadata, merge_history, scan
from repro.core.hardware import DEFAULT_TARGET, TPU_V5E, TPU_V5P, ChipSpec, get_target
from repro.core.monitor import MonitorConfig
from repro.core.monitor import TalpMonitor as _TalpMonitorImpl
from repro.core.profile import StepProfile
from repro.core.records import (
    GLOBAL_REGION,
    SCHEMA_VERSION,
    ComputationCounters,
    RegionCounters,
    RegionMeasurements,
    RegionRecord,
    ResourceConfig,
    RunRecord,
)
from repro.core.regression import ComputationShift, Finding, detect, explain_computations
from repro.core.report import badge_svg, generate_report
from repro.core.scaling import ScalingTable, build_table, latest_per_config, render_text
from repro.core.timeseries import build_series
from repro.core.tracer import TraceRecorder as _TraceRecorderImpl
from repro.core.tracer import post_process, trace_storage_bytes


def _deprecated(old: str) -> None:
    _warnings.warn(
        f"constructing {old} directly is deprecated; go through "
        "repro.session.PerfSession (backend='monitor'|'tracer') — the one "
        "instrumentation surface. Direct construction will be removed next "
        "release.",
        DeprecationWarning,
        stacklevel=3,
    )


class TalpMonitor(_TalpMonitorImpl):
    """Deprecated alias kept for one release; use repro.session.PerfSession."""

    def __init__(self, *args, **kw):
        _deprecated("repro.core.TalpMonitor")
        super().__init__(*args, **kw)


class TraceRecorder(_TraceRecorderImpl):
    """Deprecated alias kept for one release; use repro.session.PerfSession."""

    def __init__(self, *args, **kw):
        _deprecated("repro.core.TraceRecorder")
        super().__init__(*args, **kw)

__all__ = [
    "TalpMonitor", "MonitorConfig", "StepProfile", "RunRecord", "RegionRecord",
    "RegionCounters", "RegionMeasurements", "ComputationCounters",
    "ResourceConfig", "GLOBAL_REGION", "SCHEMA_VERSION",
    "ComputationShift", "Finding", "detect", "explain_computations",
    "ChipSpec", "TPU_V5E", "TPU_V5P", "DEFAULT_TARGET", "get_target",
    "compute_pop", "validate_pop", "build_table", "render_text", "ScalingTable",
    "latest_per_config", "build_series", "generate_report", "badge_svg",
    "scan", "merge_history", "git_metadata", "Experiment",
    "TraceRecorder", "post_process", "trace_storage_bytes",
]
