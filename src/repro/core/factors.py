"""POP fundamental performance factors, adapted to multi-pod TPU JAX.

The paper computes the POP efficiency hierarchy [Wagner et al., 17] from
TALP's on-the-fly MPI/OpenMP measurements + PAPI counters. On TPU/XLA none of
those interfaces exist; DESIGN.md §3 defines the mapping implemented here:

  Global efficiency
  ├── Parallel efficiency                         (absolute, per run)
  │   ├── Dispatch efficiency      [measured]  device-busy wall fraction —
  │   │                                        the OpenMP-serialization analogue
  │   │                                        (host stalls, input pipeline)
  │   ├── Communication efficiency [modeled]   exposed collective time from
  │   │   ├── ICI comm efficiency              HLO collective bytes + fabric
  │   │   └── DCN comm efficiency              bandwidth model
  │   └── Load balance             [measured]
  │       ├── Data load balance                non-pad tokens per data shard
  │       ├── Expert load balance              MoE router occupancy
  │       └── Host load balance                per-host step times
  │           ├── In-pod load balance          (ICI domain)
  │           └── Inter-pod load balance       (DCN domain)
  └── Computation scalability                     (relative to reference run)
      ├── FLOP scaling             "instruction scaling": executed HLO FLOPs
      ├── Throughput scaling       "IPC scaling": achieved FLOP/s per device
      └── Frequency scaling        chip clock ratio (≈1 on TPU, kept for
                                   table parity with the paper)

Every factor is an efficiency in [0, 1]-ish (scalability factors may exceed
1, exactly as in the paper's Table 7 where superlinear IPC scaling appears).
Products hold exactly:  parallel = dispatch * comm * lb,
comm = ici * dcn,  lb = data * expert * host,  host = in_pod * inter_pod,
comp_scalability = flop * throughput * frequency,
global = parallel * comp_scalability.
"""

from __future__ import annotations

from typing import Any

from repro.core.hardware import ChipSpec, get_target
from repro.core.records import RegionRecord, ResourceConfig

# Canonical factor keys ------------------------------------------------------

GLOBAL_EFF = "global_efficiency"
PARALLEL_EFF = "parallel_efficiency"
DISPATCH_EFF = "dispatch_efficiency"
COMM_EFF = "communication_efficiency"
ICI_COMM_EFF = "ici_comm_efficiency"
DCN_COMM_EFF = "dcn_comm_efficiency"
LOAD_BALANCE = "load_balance"
DATA_LB = "data_load_balance"
EXPERT_LB = "expert_load_balance"
HOST_LB = "host_load_balance"
IN_POD_LB = "in_pod_load_balance"
INTER_POD_LB = "inter_pod_load_balance"
COMP_SCALABILITY = "computation_scalability"
FLOP_SCALING = "flop_scaling"
THROUGHPUT_SCALING = "throughput_scaling"
FREQUENCY_SCALING = "frequency_scaling"

# informational (non-multiplicative) rows
MXU_UTIL = "mxu_utilization"
FLOP_USEFULNESS = "flop_usefulness"
ACHIEVED_TFLOPS = "achieved_tflops_per_device"
ELAPSED_S = "elapsed_s"

# (name, children) recursive tree; rendering + regression explanation walk it.
FACTOR_TREE: tuple = (
    GLOBAL_EFF,
    [
        (
            PARALLEL_EFF,
            [
                (DISPATCH_EFF, []),
                (COMM_EFF, [(ICI_COMM_EFF, []), (DCN_COMM_EFF, [])]),
                (
                    LOAD_BALANCE,
                    [
                        (DATA_LB, []),
                        (EXPERT_LB, []),
                        (HOST_LB, [(IN_POD_LB, []), (INTER_POD_LB, [])]),
                    ],
                ),
            ],
        ),
        (
            COMP_SCALABILITY,
            [(FLOP_SCALING, []), (THROUGHPUT_SCALING, []), (FREQUENCY_SCALING, [])],
        ),
    ],
)

INFO_ROWS = (MXU_UTIL, FLOP_USEFULNESS, ACHIEVED_TFLOPS, ELAPSED_S)

DISPLAY_NAMES = {
    GLOBAL_EFF: "Global efficiency",
    PARALLEL_EFF: "Parallel efficiency",
    DISPATCH_EFF: "Dispatch efficiency",
    COMM_EFF: "Communication efficiency",
    ICI_COMM_EFF: "ICI communication efficiency",
    DCN_COMM_EFF: "DCN communication efficiency",
    LOAD_BALANCE: "Load balance",
    DATA_LB: "Data load balance",
    EXPERT_LB: "Expert load balance",
    HOST_LB: "Host load balance",
    IN_POD_LB: "In-pod load balance",
    INTER_POD_LB: "Inter-pod load balance",
    COMP_SCALABILITY: "Computation scalability",
    FLOP_SCALING: "FLOP (instruction) scaling",
    THROUGHPUT_SCALING: "Throughput (IPC) scaling",
    FREQUENCY_SCALING: "Frequency scaling",
    MXU_UTIL: "MXU utilization",
    FLOP_USEFULNESS: "FLOP usefulness (model/HLO)",
    ACHIEVED_TFLOPS: "Achieved TFLOP/s/device",
    ELAPSED_S: "Elapsed time [s]",
}


def iter_tree(node=FACTOR_TREE, depth: int = 0):
    """Yield (key, depth) over the factor tree, pre-order."""
    name, children = node
    yield name, depth
    for child in children:
        yield from iter_tree(child, depth + 1)


# ---------------------------------------------------------------------------
# modeled communication times
# ---------------------------------------------------------------------------


def modeled_times(
    region: RegionRecord,
    resources: ResourceConfig,
    spec: ChipSpec,
    overlap_fraction: float = 0.0,
) -> dict[str, float]:
    """Per-device modeled times (seconds, whole region lifetime).

    ``t_useful`` is the roofline of the useful (non-collective) work:
    max(compute, memory). Collective times are scaled by
    ``1 - overlap_fraction`` — the exposed share after compute/comm overlap
    (0.0 = fully serial, the conservative paper-faithful default).
    """
    c = region.counters
    n = max(resources.total_devices, 1)
    t_compute = c.useful_flops / (n * spec.peak_flops_bf16)
    t_memory = c.hlo_bytes / (n * spec.hbm_bandwidth)
    t_useful = max(t_compute, t_memory)
    exposed = 1.0 - min(max(overlap_fraction, 0.0), 1.0)
    t_ici = exposed * c.collective_bytes_ici / (n * spec.ici_bandwidth)
    t_dcn = exposed * c.collective_bytes_dcn / (n * spec.dcn_bandwidth)
    return {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_useful": t_useful,
        "t_ici": t_ici,
        "t_dcn": t_dcn,
        "t_total": t_useful + t_ici + t_dcn,
    }


# ---------------------------------------------------------------------------
# absolute factors (parallel-efficiency branch)
# ---------------------------------------------------------------------------


def _clamp01(x: float) -> float:
    return min(max(x, 0.0), 1.0)


def absolute_factors(
    region: RegionRecord,
    resources: ResourceConfig,
    spec: ChipSpec | str | None = None,
    overlap_fraction: float = 0.0,
) -> dict[str, float]:
    """Parallel-efficiency hierarchy + informational rows for one region."""
    if not isinstance(spec, ChipSpec):
        spec = get_target(spec)
    m = region.measurements
    t = modeled_times(region, resources, spec, overlap_fraction)

    # communication efficiency: multiplicative split that composes exactly
    if t["t_total"] > 0:
        ici_eff = t["t_useful"] / (t["t_useful"] + t["t_ici"]) if t["t_useful"] > 0 else 1.0
        dcn_eff = (
            (t["t_useful"] + t["t_ici"]) / t["t_total"] if t["t_total"] > 0 else 1.0
        )
    else:
        ici_eff = dcn_eff = 1.0
    comm_eff = ici_eff * dcn_eff

    # dispatch efficiency (measured): device-busy wall fraction
    if m.elapsed_s > 0 and m.device_time_s > 0:
        dispatch_eff = _clamp01(m.device_time_s / m.elapsed_s)
    else:
        dispatch_eff = 1.0

    # load balance (measured sub-balances default to 1 when not observed)
    data_lb = 1.0 if m.data_lb is None else m.data_lb
    expert_lb = 1.0 if m.expert_lb is None else m.expert_lb
    if m.in_pod_lb is not None or m.inter_pod_lb is not None:
        in_pod = 1.0 if m.in_pod_lb is None else m.in_pod_lb
        inter_pod = 1.0 if m.inter_pod_lb is None else m.inter_pod_lb
        host_lb = in_pod * inter_pod
    else:
        host_lb = 1.0 if m.host_lb is None else m.host_lb
        in_pod = host_lb
        inter_pod = 1.0
    lb = data_lb * expert_lb * host_lb

    parallel = dispatch_eff * comm_eff * lb

    out = {
        PARALLEL_EFF: parallel,
        DISPATCH_EFF: dispatch_eff,
        COMM_EFF: comm_eff,
        ICI_COMM_EFF: ici_eff,
        DCN_COMM_EFF: dcn_eff,
        LOAD_BALANCE: lb,
        DATA_LB: data_lb,
        EXPERT_LB: expert_lb,
        HOST_LB: host_lb,
        IN_POD_LB: in_pod,
        INTER_POD_LB: inter_pod,
    }

    # informational rows
    c = region.counters
    n = max(resources.total_devices, 1)
    if m.device_time_s > 0 and c.useful_flops > 0:
        achieved = c.useful_flops / (n * m.device_time_s)
        out[ACHIEVED_TFLOPS] = achieved / 1e12
        out[MXU_UTIL] = achieved / spec.peak_flops_bf16
    if c.useful_flops > 0 and c.model_flops > 0:
        out[FLOP_USEFULNESS] = c.model_flops / c.useful_flops
    out[ELAPSED_S] = m.elapsed_s
    return out


# ---------------------------------------------------------------------------
# computation scalability (relative to a reference run)
# ---------------------------------------------------------------------------

WEAK = "weak"
STRONG = "strong"


def detect_scaling_mode(
    runs: list[tuple[RegionRecord, ResourceConfig]],
    rel_tol: float = 0.2,
) -> str:
    """Paper's rule: weak scaling iff instructions per CPU are constant
    (within tolerance); otherwise strong. "Instructions" -> HLO FLOPs,
    "CPU" -> device."""
    per_dev = [
        r.counters.useful_flops / max(res.total_devices, 1) for r, res in runs
    ]
    per_dev = [p for p in per_dev if p > 0]
    if len(per_dev) < 2:
        return STRONG
    lo, hi = min(per_dev), max(per_dev)
    return WEAK if hi <= lo * (1.0 + rel_tol) else STRONG


def scalability_factors(
    region: RegionRecord,
    resources: ResourceConfig,
    ref_region: RegionRecord,
    ref_resources: ResourceConfig,
    mode: str,
    spec: ChipSpec | str | None = None,
) -> dict[str, float]:
    """FLOP/throughput/frequency scaling vs the reference configuration.

    Mirrors the paper exactly: strong scaling assumes *total* instructions
    constant, weak scaling assumes instructions *per CPU* constant; deviations
    count as inefficiency. Throughput scaling is the IPC-scaling analogue
    (achieved useful FLOP/s per device relative to reference); frequency
    scaling uses the (fixed) chip clock.
    """
    if not isinstance(spec, ChipSpec):
        spec = get_target(spec)
    c, rc = region.counters, ref_region.counters
    m, rm = region.measurements, ref_region.measurements
    n, rn = max(resources.total_devices, 1), max(ref_resources.total_devices, 1)

    if mode == STRONG:
        flop_scaling = rc.useful_flops / c.useful_flops if c.useful_flops > 0 else 1.0
    else:
        per = c.useful_flops / n
        rper = rc.useful_flops / rn
        flop_scaling = rper / per if per > 0 else 1.0

    # throughput (IPC) scaling: achieved FLOP/s per device, relative
    if m.device_time_s > 0 and rm.device_time_s > 0 and c.useful_flops > 0 and rc.useful_flops > 0:
        thr = c.useful_flops / (n * m.device_time_s)
        rthr = rc.useful_flops / (rn * rm.device_time_s)
        throughput_scaling = thr / rthr if rthr > 0 else 1.0
    else:
        throughput_scaling = 1.0

    frequency_scaling = 1.0  # TPU clocks are fixed (DESIGN.md §3)

    return {
        COMP_SCALABILITY: flop_scaling * throughput_scaling * frequency_scaling,
        FLOP_SCALING: flop_scaling,
        THROUGHPUT_SCALING: throughput_scaling,
        FREQUENCY_SCALING: frequency_scaling,
    }


def compute_pop(
    region: RegionRecord,
    resources: ResourceConfig,
    spec: ChipSpec | str | None = None,
    overlap_fraction: float = 0.0,
    ref: tuple[RegionRecord, ResourceConfig] | None = None,
    mode: str = STRONG,
) -> dict[str, float]:
    """Full factor dict for one region. Without a reference, the
    scalability branch is identity (absolute run)."""
    pop = absolute_factors(region, resources, spec, overlap_fraction)
    if ref is not None:
        pop.update(
            scalability_factors(region, resources, ref[0], ref[1], mode, spec)
        )
    else:
        pop.update(
            {
                COMP_SCALABILITY: 1.0,
                FLOP_SCALING: 1.0,
                THROUGHPUT_SCALING: 1.0,
                FREQUENCY_SCALING: 1.0,
            }
        )
    pop[GLOBAL_EFF] = pop[PARALLEL_EFF] * pop[COMP_SCALABILITY]
    return pop


def validate_pop(pop: dict[str, float], atol: float = 1e-9) -> list[str]:
    """Check the multiplicative identities; returns list of violations.

    Used by hypothesis property tests: for any raw inputs, the published
    factor dict must compose exactly.
    """
    errors = []

    def close(a: float, b: float) -> bool:
        return abs(a - b) <= atol + 1e-6 * max(abs(a), abs(b))

    checks = [
        (GLOBAL_EFF, [PARALLEL_EFF, COMP_SCALABILITY]),
        (PARALLEL_EFF, [DISPATCH_EFF, COMM_EFF, LOAD_BALANCE]),
        (COMM_EFF, [ICI_COMM_EFF, DCN_COMM_EFF]),
        (LOAD_BALANCE, [DATA_LB, EXPERT_LB, HOST_LB]),
        (HOST_LB, [IN_POD_LB, INTER_POD_LB]),
        (COMP_SCALABILITY, [FLOP_SCALING, THROUGHPUT_SCALING, FREQUENCY_SCALING]),
    ]
    for parent, children in checks:
        if parent in pop and all(ch in pop for ch in children):
            prod = 1.0
            for ch in children:
                prod *= pop[ch]
            if not close(pop[parent], prod):
                errors.append(f"{parent}={pop[parent]} != prod(children)={prod}")
    return errors
