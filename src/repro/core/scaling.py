"""Scaling-efficiency tables (paper Fig. 3, Tables 6/7).

Given the runs of one experiment folder:
  * group runs by resource configuration (column key),
  * keep the run with the **latest timestamp** per configuration,
  * pick the configuration with the **least resources** as the reference,
  * detect weak vs strong scaling from the instructions-per-device rule,
  * emit one column of POP factors per configuration.

All rules follow the paper's §Scaling-efficiency table verbatim.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.core import factors as F
from repro.core.records import GLOBAL_REGION, RegionRecord, ResourceConfig, RunRecord


@dataclasses.dataclass
class ScalingColumn:
    label: str
    resources: ResourceConfig
    timestamp: str
    pop: dict[str, float]
    is_reference: bool


@dataclasses.dataclass
class ScalingTable:
    region: str
    mode: str  # factors.WEAK | factors.STRONG | "comparison"
    columns: list[ScalingColumn]

    def row(self, key: str) -> list[float | None]:
        return [c.pop.get(key) for c in self.columns]

    def to_json(self) -> dict[str, Any]:
        return {
            "region": self.region,
            "mode": self.mode,
            "columns": [
                {
                    "label": c.label,
                    "resources": c.resources.to_json(),
                    "timestamp": c.timestamp,
                    "pop": dict(c.pop),
                    "is_reference": c.is_reference,
                }
                for c in self.columns
            ],
        }


def latest_per_config(runs: list[RunRecord]) -> list[RunRecord]:
    """One run per resource configuration — the latest timestamp wins."""
    best: dict[str, RunRecord] = {}
    for run in runs:
        key = run.resources.label
        cur = best.get(key)
        if cur is None or run.timestamp > cur.timestamp:
            best[key] = run
    return sorted(best.values(), key=lambda r: r.resources.total_devices)


def build_table(
    runs: list[RunRecord],
    region: str = GLOBAL_REGION,
    overlap_fraction: float = 0.0,
    mode: str | None = None,
) -> ScalingTable | None:
    """Build the scaling-efficiency table for one experiment folder."""
    selected = [r for r in latest_per_config(runs) if region in r.regions]
    if not selected:
        return None

    pairs: list[tuple[RegionRecord, ResourceConfig]] = [
        (r.regions[region], r.resources) for r in selected
    ]
    if mode is None:
        mode = F.detect_scaling_mode(pairs)
    ref_region, ref_resources = pairs[0]  # least resources (sorted above)

    columns = []
    for run, (reg, res) in zip(selected, pairs):
        pop = F.compute_pop(
            reg,
            res,
            run.hardware,
            overlap_fraction=overlap_fraction,
            ref=(ref_region, ref_resources),
            mode=mode,
        )
        columns.append(
            ScalingColumn(
                label=res.label,
                resources=res,
                timestamp=run.timestamp,
                pop=pop,
                is_reference=res.label == ref_resources.label,
            )
        )
    return ScalingTable(region=region, mode=mode, columns=columns)


def render_text(table: ScalingTable, width: int = 9) -> str:
    """Plain-text rendering (used by the CLI and tests)."""
    header = ["Metrics".ljust(36)] + [c.label.rjust(width) for c in table.columns]
    lines = [" | ".join(header)]
    lines.append("-" * len(lines[0]))
    for key, depth in F.iter_tree():
        vals = table.row(key)
        if all(v is None for v in vals):
            continue
        name = ("  " * depth) + F.DISPLAY_NAMES.get(key, key)
        cells = [
            ("-".rjust(width) if v is None else f"{v:.2f}".rjust(width)) for v in vals
        ]
        lines.append(" | ".join([name.ljust(36)] + cells))
    for key in F.INFO_ROWS:
        vals = table.row(key)
        if all(v is None for v in vals):
            continue
        fmt = "{:.2f}" if key != F.ELAPSED_S else "{:.2f}"
        cells = [
            ("-".rjust(width) if v is None else fmt.format(v).rjust(width)) for v in vals
        ]
        lines.append(" | ".join([F.DISPLAY_NAMES.get(key, key).ljust(36)] + cells))
    lines.append(f"(scaling mode: {table.mode}, region: {table.region})")
    return "\n".join(lines)
