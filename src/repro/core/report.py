"""Static HTML report + SVG badges (paper §TALP-Pages, §Reports).

Produces a fully self-contained static site (inline CSS/JS/SVG, zero
external assets — it must render from GitLab/GitHub Pages artifact hosting
with no server): per-experiment scaling-efficiency tables, time-evolution
plots with client-side region toggling, regression findings, and SVG
parallel-efficiency badges per resource configuration.
"""

from __future__ import annotations

import html
import json
import os
import re
from typing import Sequence

from repro.core import factors as F
from repro.core import regression as _regression
from repro.core import scaling as _scaling
from repro.core import timeseries as _timeseries
from repro.core.folder import Experiment
from repro.core.records import GLOBAL_REGION

_CSS = """
body{font-family:-apple-system,Segoe UI,Helvetica,Arial,sans-serif;margin:2rem;
     color:#1a1a1a;max-width:1200px}
h1{border-bottom:2px solid #444}
h2{margin-top:2.2rem;border-bottom:1px solid #bbb}
table.pop{border-collapse:collapse;margin:0.8rem 0;font-size:0.92rem}
table.pop th,table.pop td{border:1px solid #999;padding:3px 10px;text-align:right}
table.pop td.name{text-align:left;font-family:ui-monospace,monospace;white-space:pre}
td.good{background:#bfe3bf}td.ok{background:#f5e6a8}td.bad{background:#f3b8b8}
td.na{color:#999}
.badge{margin-right:0.6rem}
.plot{margin:0.5rem 1rem 1rem 0;display:inline-block;vertical-align:top}
.plot svg{background:#fcfcfc;border:1px solid #ddd}
.legend{font-size:0.8rem}
.finding-regression{color:#a00;font-weight:600}
.finding-improvement{color:#060;font-weight:600}
.meta{color:#666;font-size:0.85rem}
details{margin:0.4rem 0}
"""

_JS = """
function toggleRegion(exp, region, on) {
  document.querySelectorAll('[data-exp="'+exp+'"][data-region="'+region+'"]')
    .forEach(el => { el.style.display = on ? '' : 'none'; });
}
function toggleCompMetric(exp, metric) {
  document.querySelectorAll('[data-exp="'+exp+'"][data-cmetric]')
    .forEach(el => {
      el.style.display = (el.getAttribute('data-cmetric') === metric)
        ? '' : 'none';
    });
}
"""

# the per-computation counter metrics the client-side toggle switches
# between (keys of records.ComputationCounters.METRICS)
COMP_METRICS = (
    ("hbm_bytes", "HBM bytes"),
    ("flops", "FLOPs"),
    ("collective_operand_bytes", "collective bytes"),
)
DEFAULT_COMP_METRIC = "hbm_bytes"

_PALETTE = ["#1f77b4", "#d62728", "#2ca02c", "#9467bd", "#ff7f0e",
            "#8c564b", "#e377c2", "#17becf", "#7f7f7f", "#bcbd22"]


def _cell_class(key: str, v: float | None) -> str:
    if v is None:
        return "na"
    if key in (F.ELAPSED_S, F.ACHIEVED_TFLOPS, F.MXU_UTIL):
        return ""
    if v >= 0.8:
        return "good"
    if v >= 0.6:
        return "ok"
    return "bad"


# ---------------------------------------------------------------------------
# badges
# ---------------------------------------------------------------------------


def badge_svg(label: str, value: float | None) -> str:
    txt = "n/a" if value is None else f"{value:.2f}"
    color = "#9f9f9f"
    if value is not None:
        color = "#4c1" if value >= 0.8 else ("#dfb317" if value >= 0.6 else "#e05d44")
    lw = 7 * len(label) + 10
    vw = 7 * len(txt) + 10
    return f"""<svg xmlns="http://www.w3.org/2000/svg" width="{lw+vw}" height="20" role="img">
<rect width="{lw}" height="20" fill="#555"/>
<rect x="{lw}" width="{vw}" height="20" fill="{color}"/>
<g fill="#fff" text-anchor="middle" font-family="Verdana,sans-serif" font-size="11">
<text x="{lw/2}" y="14">{html.escape(label)}</text>
<text x="{lw + vw/2}" y="14">{txt}</text></g></svg>"""


# ---------------------------------------------------------------------------
# SVG line plots
# ---------------------------------------------------------------------------


def _svg_plot(
    title: str,
    series: list[tuple[str, list[float]]],
    xlabels: list[str],
    width: int = 420,
    height: int = 190,
    y01: bool = False,
) -> str:
    """Tiny dependency-free polyline chart."""
    ml, mr, mt, mb = 46, 8, 22, 34
    pw, ph = width - ml - mr, height - mt - mb
    ys = [v for _, vals in series for v in vals if v == v]
    if not ys:
        return ""
    ymin, ymax = (0.0, 1.05) if y01 else (min(ys), max(ys))
    if ymax <= ymin:
        ymax = ymin + (abs(ymin) if ymin else 1.0) * 0.1 + 1e-12
    pad = 0.06 * (ymax - ymin)
    if not y01:
        ymin, ymax = ymin - pad, ymax + pad
    n = max(len(xlabels), 2)

    def X(i: int) -> float:
        return ml + pw * (i / (n - 1))

    def Y(v: float) -> float:
        return mt + ph * (1 - (v - ymin) / (ymax - ymin))

    parts = [
        f'<svg width="{width}" height="{height}" xmlns="http://www.w3.org/2000/svg">',
        f'<text x="{ml}" y="14" font-size="12" font-weight="600">{html.escape(title)}</text>',
    ]
    for frac in (0.0, 0.5, 1.0):
        yv = ymin + frac * (ymax - ymin)
        yy = Y(yv)
        parts.append(
            f'<line x1="{ml}" y1="{yy:.1f}" x2="{width-mr}" y2="{yy:.1f}" stroke="#e0e0e0"/>'
            f'<text x="{ml-4}" y="{yy+4:.1f}" font-size="9" text-anchor="end">{yv:.3g}</text>'
        )
    for i, lab in enumerate(xlabels):
        parts.append(
            f'<text x="{X(i):.1f}" y="{height-4}" font-size="8" text-anchor="middle">'
            f"{html.escape(lab[:12])}</text>"
        )
    legend_y = mt
    for si, (name, vals) in enumerate(series):
        color = _PALETTE[si % len(_PALETTE)]
        pts = " ".join(
            f"{X(i):.1f},{Y(v):.1f}" for i, v in enumerate(vals) if v == v
        )
        if pts:
            parts.append(
                f'<polyline points="{pts}" fill="none" stroke="{color}" stroke-width="1.6"/>'
            )
            for i, v in enumerate(vals):
                if v == v:
                    parts.append(
                        f'<circle cx="{X(i):.1f}" cy="{Y(v):.1f}" r="2.3" fill="{color}"/>'
                    )
        parts.append(
            f'<text x="{width-mr}" y="{legend_y}" font-size="9" text-anchor="end" '
            f'fill="{color}">{html.escape(name)}</text>'
        )
        legend_y += 11
    parts.append("</svg>")
    return "".join(parts)


# ---------------------------------------------------------------------------
# tables
# ---------------------------------------------------------------------------


def table_html(table: _scaling.ScalingTable) -> str:
    rows = [
        "<table class='pop'><tr><th>Metrics</th>"
        + "".join(
            f"<th>{html.escape(c.label)}{' (ref)' if c.is_reference else ''}</th>"
            for c in table.columns
        )
        + "</tr>"
    ]
    for key, depth in F.iter_tree():
        vals = table.row(key)
        if all(v is None for v in vals):
            continue
        name = "&nbsp;" * (2 * depth) + html.escape(
            ("- " if depth else "") + F.DISPLAY_NAMES.get(key, key)
        )
        cells = "".join(
            f"<td class='{_cell_class(key, v)}'>{'-' if v is None else f'{v:.2f}'}</td>"
            for v in vals
        )
        rows.append(f"<tr><td class='name'>{name}</td>{cells}</tr>")
    for key in F.INFO_ROWS:
        vals = table.row(key)
        if all(v is None for v in vals):
            continue
        cells = "".join(
            f"<td>{'-' if v is None else f'{v:.4g}'}</td>" for v in vals
        )
        rows.append(
            f"<tr><td class='name'>{html.escape(F.DISPLAY_NAMES.get(key, key))}</td>{cells}</tr>"
        )
    rows.append("</table>")
    rows.append(
        f"<p class='meta'>scaling mode: <b>{table.mode}</b>, region: "
        f"<b>{html.escape(table.region)}</b>, reference: least resources, "
        f"latest run per configuration</p>"
    )
    return "".join(rows)


def _sparkline(
    vals: list[float], width: int = 96, height: int = 18,
    color: str = "#1f77b4",
) -> str:
    """Inline mini-trend of one computation metric over the run history."""
    finite = [(i, v) for i, v in enumerate(vals) if v == v]
    if len(finite) < 2:
        return ""
    ys = [v for _, v in finite]
    ymin, ymax = min(ys), max(ys)
    if ymax <= ymin:
        ymax = ymin + (abs(ymin) if ymin else 1.0) * 0.1 + 1e-12
    n = max(len(vals), 2)
    pts = " ".join(
        f"{1 + (width - 2) * i / (n - 1):.1f},"
        f"{1 + (height - 2) * (1 - (v - ymin) / (ymax - ymin)):.1f}"
        for i, v in finite
    )
    lx, ly = (
        1 + (width - 2) * finite[-1][0] / (n - 1),
        1 + (height - 2) * (1 - (finite[-1][1] - ymin) / (ymax - ymin)),
    )
    return (
        f'<svg width="{width}" height="{height}" '
        'xmlns="http://www.w3.org/2000/svg" style="vertical-align:middle">'
        f'<polyline points="{pts}" fill="none" stroke="{color}" '
        'stroke-width="1.2"/>'
        f'<circle cx="{lx:.1f}" cy="{ly:.1f}" r="1.8" fill="{color}"/></svg>'
    )


def comp_metric_toggle_html(eid: str) -> str:
    """Radio group driving every ``data-cmetric`` element of an experiment
    (drill-down sparklines + per-computation time-evolution plots)."""
    labels = []
    for key, label in COMP_METRICS:
        checked = " checked" if key == DEFAULT_COMP_METRIC else ""
        labels.append(
            f"<label><input type='radio' name='cmetric_{eid}'{checked} "
            f"onchange=\"toggleCompMetric('{eid}','{key}')\"/>"
            f"{html.escape(label)}</label> "
        )
    return (
        "<div class='legend'>per-computation metric: "
        + "".join(labels)
        + "</div>"
    )


def computation_breakdown_html(
    run, eid: str, top_n: int = 8, open_details: bool = False,
    series_by_region: dict | None = None,
) -> str:
    """Per-experiment drill-down: collapsible per-region tables of the
    heaviest HLO computations (typed ``RegionRecord.computations``, schema
    v3). Anchored at ``comps_{eid}`` so regression findings and the
    time-evolution plots can deep-link into it. ``series_by_region``
    (region -> metric -> computation -> values over the run history) adds a
    trend sparkline per row, switched by the experiment's metric toggle."""
    parts: list[str] = []
    series_by_region = series_by_region or {}
    for region, reg in run.regions.items():
        comps = reg.top_computations(top_n)
        if not comps:
            continue
        metric_series = series_by_region.get(region, {})
        has_spark = any(metric_series.get(m) for m, _ in COMP_METRICS)
        rows = [
            "<table class='pop'><tr><th>computation</th><th>kind</th>"
            "<th>mult</th><th>GFLOP</th><th>HBM GiB</th><th>coll GiB</th>"
            + ("<th>trend</th>" if has_spark else "")
            + "</tr>"
        ]
        for c in comps:
            spark_cells = ""
            if has_spark:
                spans = []
                for m, _label in COMP_METRICS:
                    vals = metric_series.get(m, {}).get(c.name)
                    svg = _sparkline(vals) if vals else ""
                    hide = " style='display:none'" if m != DEFAULT_COMP_METRIC else ""
                    spans.append(
                        f"<span data-exp='{eid}' data-cmetric='{m}'{hide}>"
                        f"{svg}</span>"
                    )
                spark_cells = f"<td>{''.join(spans)}</td>"
            rows.append(
                f"<tr><td class='name'>{html.escape(c.name[:48])}</td>"
                f"<td>{html.escape(c.kind)}</td>"
                f"<td>{c.multiplicity:.0f}</td>"
                f"<td>{c.flops / 1e9:.2f}</td>"
                f"<td>{c.hbm_bytes / 2**30:.3f}</td>"
                f"<td>{c.collective_operand_bytes / 2**30:.3f}</td>"
                f"{spark_cells}</tr>"
            )
        rows.append("</table>")
        parts.append(
            f"<details{' open' if open_details else ''}>"
            f"<summary>HLO computation breakdown — region "
            f"<code>{html.escape(region)}</code> (top {len(comps)}, latest run)"
            f"</summary>{''.join(rows)}</details>"
        )
    if not parts:
        return ""
    # eid is sanitized to [A-Za-z0-9_-] by the caller, so id == href target
    return f"<div id='comps_{eid}'>{''.join(parts)}</div>"


# ---------------------------------------------------------------------------
# full report
# ---------------------------------------------------------------------------


def generate_report(
    experiments: Sequence[Experiment],
    out_dir: str,
    regions: Sequence[str] = (),
    region_for_badge: str | None = None,
    overlap_fraction: float = 0.0,
    title: str = "TALP-Pages performance report",
    top_computations: int = 8,
) -> str:
    """Write the report site under ``out_dir``; returns index.html path."""
    os.makedirs(out_dir, exist_ok=True)
    badge_region = region_for_badge or GLOBAL_REGION
    all_regions = [GLOBAL_REGION, *[r for r in regions if r != GLOBAL_REGION]]

    body: list[str] = [f"<h1>{html.escape(title)}</h1>"]
    summary_findings: list[_regression.Finding] = []

    for exp in experiments:
        # id-safe: eid feeds element ids, #fragment hrefs and JS strings
        eid = re.sub(r"[^A-Za-z0-9_-]", "_", exp.rel_path.replace(os.sep, "__"))
        body.append(f"<h2>Experiment: {html.escape(exp.name)}</h2>")
        body.append(
            f"<p class='meta'>{len(exp.runs)} runs, "
            f"{len({r.resources.label for r in exp.runs})} resource configurations</p>"
        )

        # --- badges (one per resource configuration) ---
        latest = _scaling.latest_per_config(exp.runs)
        for run in latest:
            reg = run.regions.get(badge_region)
            value = reg.pop.get(F.PARALLEL_EFF) if reg else None
            name = f"badge_{eid}_{run.resources.label}.svg"
            with open(os.path.join(out_dir, name), "w") as f:
                f.write(badge_svg(f"parallel eff {run.resources.label}", value))
            body.append(f"<span class='badge'><img src='{name}' alt='badge'/></span>")

        # --- scaling-efficiency tables (per requested region) ---
        for region in all_regions:
            table = _scaling.build_table(
                exp.runs, region=region, overlap_fraction=overlap_fraction
            )
            if table is None or not table.columns:
                continue
            body.append(f"<h3>Scaling efficiency — region <code>{html.escape(region)}</code></h3>")
            body.append(table_html(table))

        # --- time-evolution series (also feeds the drill-down sparklines) ---
        cfg_series = _timeseries.build_series(exp.runs)
        series_by_label = {cs.label: cs for cs in cfg_series}

        # --- per-computation drill-down (latest run that recorded one) ---
        has_breakdown = False
        if top_computations > 0:
            for run in reversed(latest):
                cs = series_by_label.get(run.resources.label)
                series_by_region = {
                    rn: {
                        m: rs.computation_series(m) for m, _ in COMP_METRICS
                    }
                    for rn, rs in (cs.regions if cs else {}).items()
                    if len(rs.points) >= 2
                }
                bd = computation_breakdown_html(
                    run, eid, top_computations,
                    series_by_region=series_by_region,
                )
                if bd:
                    body.append(comp_metric_toggle_html(eid))
                    body.append(bd)
                    has_breakdown = True
                    break
        for cs in cfg_series:
            if all(len(rs.points) < 2 for rs in cs.regions.values()):
                continue
            body.append(f"<h3>Time evolution — {html.escape(cs.label)}</h3>")
            shown_regions = [r for r in cs.regions if r in all_regions] or list(cs.regions)
            body.append("<div class='legend'>regions: ")
            for rn in shown_regions:
                body.append(
                    f"<label><input type='checkbox' checked "
                    f"onchange=\"toggleRegion('{eid}','{html.escape(rn)}',this.checked)\"/>"
                    f"{html.escape(rn)}</label> "
                )
            body.append("</div>")
            for rn in shown_regions:
                rs = cs.regions[rn]
                xlabels = [
                    (p.commit or p.timestamp.replace("T", " ")[:16]) for p in rs.points
                ]
                body.append(
                    f"<div data-exp='{eid}' data-region='{html.escape(rn)}'>"
                    f"<b>{html.escape(rn)}</b><br/>"
                )
                for gtitle, keys in _timeseries.SERIES_GROUPS:
                    series = []
                    for k in keys:
                        vals = [p.values.get(k, float("nan")) for p in rs.points]
                        if any(v == v for v in vals):
                            series.append((F.DISPLAY_NAMES.get(k, k), vals))
                    if not series:
                        continue
                    y01 = gtitle not in ("Elapsed time [s]", "Computation")
                    svg = _svg_plot(f"{gtitle} ({cs.label})", series, xlabels, y01=y01)
                    if svg:
                        body.append(f"<span class='plot'>{svg}</span>")
                # per-computation time evolution (heaviest HLO computations;
                # one plot per counter metric, switched client-side by the
                # experiment's metric toggle)
                if top_computations > 0:
                    any_comp_plot = False
                    for metric, mlabel in COMP_METRICS:
                        comp_names = rs.top_computation_names(
                            min(5, top_computations), metric=metric
                        )
                        if not comp_names:
                            continue
                        cseries = rs.computation_series(metric)
                        svg = _svg_plot(
                            f"Top computations, {mlabel} ({cs.label})",
                            [(name[-28:], cseries[name]) for name in comp_names],
                            xlabels,
                        )
                        if not svg:
                            continue
                        hide = (
                            " style='display:none'"
                            if metric != DEFAULT_COMP_METRIC
                            else ""
                        )
                        body.append(
                            f"<span class='plot' data-exp='{eid}' "
                            f"data-cmetric='{metric}'{hide}>{svg}</span>"
                        )
                        any_comp_plot = True
                    if any_comp_plot and has_breakdown:
                        body.append(
                            f"<p class='meta'><a href='#comps_{eid}'>"
                            "per-computation drill-down</a></p>"
                        )
                body.append("</div>")

            # --- findings (regressions / improvements) ---
            for rn in shown_regions:
                findings = _regression.detect(cs.regions[rn], cs.label)
                summary_findings.extend(findings)
                for fd in findings:
                    link = (
                        f" <a href='#comps_{eid}'>[computation breakdown]</a>"
                        if has_breakdown and fd.computations
                        else ""
                    )
                    body.append(
                        f"<p class='finding-{fd.kind}'>&#9888; "
                        f"{html.escape(fd.describe())}{link}</p>"
                    )

    page = (
        "<!doctype html><html><head><meta charset='utf-8'>"
        f"<title>{html.escape(title)}</title><style>{_CSS}</style>"
        f"<script>{_JS}</script></head><body>"
        + "".join(body)
        + "</body></html>"
    )
    index = os.path.join(out_dir, "index.html")
    with open(index, "w") as f:
        f.write(page)
    with open(os.path.join(out_dir, "findings.json"), "w") as f:
        json.dump(
            [
                {
                    "kind": fd.kind, "region": fd.region, "config": fd.config_label,
                    "timestamp": fd.timestamp, "commit": fd.commit,
                    "rel_change": fd.rel_change,
                    "explanation": fd.explanation,
                    "computations": [c.to_json() for c in fd.computations],
                    "description": fd.describe(),
                }
                for fd in summary_findings
            ],
            f,
            indent=1,
        )
    return index
