"""TalpMonitor — the TALP (DLB) module analogue for JAX programs.

On-the-fly, O(1)-memory collection of the measurements that feed the POP
factor hierarchy (core.factors). Mirrors TALP's design:

* an implicit **Global region** spanning monitor start..stop,
* a user **region API** (``with monitor.region("timestep"): ...``) for
  fine-grained attribution — the paper's TALP_API analogue (nesting allowed,
  regions accumulate over visits),
* per-region running accumulators only — never per-step logs (that is the
  *tracer baseline*'s job, see core.tracer),
* metrics are written to a single JSON artifact at the end
  (``monitor.finalize().save(path)``).

Runtime-measured quantities: elapsed wall time, device-busy time (host
observes ``block_until_ready`` spans), step counts, data/expert/host load
balances (tiny per-step reductions, sampled every ``lb_sample_every`` steps).
Static quantities (the PAPI analogue): attached once per region from the
compiled step via ``attach_static`` (core.profile.StepProfile) and scaled by
the observed step count at finalize time.

The ``sync_regions`` knob reproduces the paper's overhead trade-off
(Table 1): synchronizing at region boundaries gives exact attribution but
costs pipeline overlap; the overhead benchmark measures exactly this.
"""

from __future__ import annotations

import contextlib
import dataclasses
import datetime as _dt
import time
from typing import Any, Callable

import numpy as np

from repro.core import factors as _factors
from repro.core.profile import StepProfile
from repro.core.records import (
    DEFAULT_TOP_COMPUTATIONS,
    GLOBAL_REGION,
    RegionCounters,
    RegionMeasurements,
    RegionRecord,
    ResourceConfig,
    RunRecord,
    merge_computations,
)


def _block(tree) -> None:
    import jax

    jax.block_until_ready(tree)


@dataclasses.dataclass
class MonitorConfig:
    app_name: str = "app"
    hardware: str = "tpu_v5e"
    sync_regions: bool = True
    lb_sample_every: int = 10
    overlap_fraction: float = 0.0  # modeled compute/comm overlap for comm-eff
    # how many of the heaviest HLO computations to persist per region
    # (bounds the run-record size; 0 disables the breakdown entirely)
    top_computations: int = DEFAULT_TOP_COMPUTATIONS
    clock: Callable[[], float] = time.perf_counter


class _LBAccumulator:
    """Running step-weighted mean of avg/max work ratios. O(1) state."""

    __slots__ = ("total", "count")

    def __init__(self) -> None:
        self.total = 0.0
        self.count = 0

    def update(self, work: np.ndarray | list[float]) -> None:
        w = np.asarray(work, dtype=np.float64).reshape(-1)
        if w.size == 0:
            return
        mx = float(w.max())
        if mx <= 0.0:
            return
        self.total += float(w.mean()) / mx
        self.count += 1

    def value(self) -> float | None:
        if self.count == 0:
            return None
        return self.total / self.count


class _RegionState:
    __slots__ = (
        "name", "elapsed", "visits", "steps", "device_time", "open_depth",
        "t_enter", "t_last_mark", "data_lb", "expert_lb", "in_pod_lb",
        "inter_pod_lb", "host_lb", "static", "static_steps",
    )

    def __init__(self, name: str) -> None:
        self.name = name
        self.elapsed = 0.0
        self.visits = 0
        self.steps = 0
        self.device_time = 0.0
        self.open_depth = 0
        self.t_enter = 0.0
        self.t_last_mark = 0.0
        self.data_lb = _LBAccumulator()
        self.expert_lb = _LBAccumulator()
        self.host_lb = _LBAccumulator()
        self.in_pod_lb = _LBAccumulator()
        self.inter_pod_lb = _LBAccumulator()
        self.static: StepProfile | None = None
        self.static_steps = 0


class TalpMonitor:
    name = "monitor"  # satisfies the repro.session.Collector protocol

    def __init__(
        self,
        config: MonitorConfig | None = None,
        resources: ResourceConfig | None = None,
        metadata: dict[str, Any] | None = None,
    ) -> None:
        self.config = config or MonitorConfig()
        self.resources = resources or ResourceConfig()
        self.metadata = dict(metadata or {})
        self._regions: dict[str, _RegionState] = {}
        self._stack: list[_RegionState] = []
        self._started = False
        self._stopped = False
        self._step_counter = 0

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def start(self) -> "TalpMonitor":
        if self._started:
            raise RuntimeError("monitor already started")
        self._started = True
        self._enter(GLOBAL_REGION)
        return self

    def stop(self) -> None:
        if self._stopped:
            return
        while self._stack:
            self._exit(self._stack[-1].name, sync=None)
        self._stopped = True

    def __enter__(self) -> "TalpMonitor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    # regions
    # ------------------------------------------------------------------

    def _state(self, name: str) -> _RegionState:
        st = self._regions.get(name)
        if st is None:
            st = self._regions[name] = _RegionState(name)
        return st

    def _enter(self, name: str) -> None:
        st = self._state(name)
        now = self.config.clock()
        if st.open_depth == 0:
            st.t_enter = now
            st.t_last_mark = now
            st.visits += 1
        st.open_depth += 1
        self._stack.append(st)

    def _exit(self, name: str, sync: Any) -> None:
        st = self._regions[name]
        if self.config.sync_regions and sync is not None:
            _block(sync)
        now = self.config.clock()
        st.open_depth -= 1
        if st.open_depth == 0:
            st.elapsed += now - st.t_enter
        if self._stack and self._stack[-1] is st:
            self._stack.pop()
        else:  # out-of-order exit: remove the most recent matching frame
            for i in range(len(self._stack) - 1, -1, -1):
                if self._stack[i] is st:
                    del self._stack[i]
                    break

    def region_enter(self, name: str) -> None:
        """Open a region (pairs with ``region_exit``). The context-manager
        ``region`` and the ``repro.session`` facade are built on these."""
        if name == GLOBAL_REGION:
            raise ValueError("the Global region is implicit")
        if not self._started:
            self.start()
        self._enter(name)

    def region_exit(self, name: str, sync: Any = None) -> None:
        self._exit(name, sync)

    @contextlib.contextmanager
    def region(self, name: str, sync: Any = None):
        """Annotate a region. If ``sync_regions`` and the block produces jax
        values, pass them via ``observe_step``/``mark_device`` or give a
        ``sync`` pytree to block on at exit."""
        self.region_enter(name)
        try:
            yield self
        finally:
            self.region_exit(name, sync)

    # ------------------------------------------------------------------
    # per-step observation
    # ------------------------------------------------------------------

    def observe_step(
        self,
        outputs: Any = None,
        *,
        tokens_per_shard: Any = None,
        expert_load: Any = None,
        host_times: Any = None,
        pod_size: int | None = None,
    ) -> None:
        """Record one training/serving step.

        outputs          -- step outputs; blocked on (measures device time)
        tokens_per_shard -- (data_shards,) real (non-pad) tokens per shard
        expert_load      -- (experts,) tokens routed per expert
        host_times       -- (hosts,) per-host step durations (from the
                            framework's psum heartbeat)
        All are optional and sampled every ``lb_sample_every`` steps.
        """
        cfg = self.config
        self._step_counter += 1
        opened = [st for st in self._regions.values() if st.open_depth > 0]
        if outputs is not None:
            _block(outputs)
        now = cfg.clock()
        for st in opened:
            st.steps += 1
            st.device_time += now - st.t_last_mark
            st.t_last_mark = now
        if self._step_counter % max(cfg.lb_sample_every, 1) != 0:
            return
        if tokens_per_shard is not None:
            arr = np.asarray(tokens_per_shard, dtype=np.float64)
            for st in opened:
                st.data_lb.update(arr)
        if expert_load is not None:
            arr = np.asarray(expert_load, dtype=np.float64)
            for st in opened:
                st.expert_lb.update(arr)
        if host_times is not None:
            arr = np.asarray(host_times, dtype=np.float64).reshape(-1)
            # host LB splits: in-pod = balance within each pod (mean over
            # pods), inter-pod = balance of per-pod maxima
            if pod_size and pod_size > 0 and arr.size % pod_size == 0 and arr.size > pod_size:
                pods = arr.reshape(-1, pod_size)
                in_pod = float(np.mean(pods.mean(axis=1) / np.maximum(pods.max(axis=1), 1e-30)))
                pod_max = pods.max(axis=1)
                inter_pod = float(pod_max.mean() / max(pod_max.max(), 1e-30))
                for st in opened:
                    st.in_pod_lb.total += in_pod
                    st.in_pod_lb.count += 1
                    st.inter_pod_lb.total += inter_pod
                    st.inter_pod_lb.count += 1
            else:
                for st in opened:
                    st.host_lb.update(arr)

    def mark_device(self) -> None:
        """Reset the device-time mark (call after host-only work inside a
        region so it is not attributed to device time)."""
        now = self.config.clock()
        for st in self._regions.values():
            if st.open_depth > 0:
                st.t_last_mark = now

    # ------------------------------------------------------------------
    # static counters (the PAPI analogue)
    # ------------------------------------------------------------------

    def attach_static(self, region: str, profile: StepProfile) -> None:
        """Attach the compiled-step profile for a region. Counters scale
        with the region's observed step count at finalize time."""
        self._state(region).static = profile

    # ------------------------------------------------------------------
    # finalize
    # ------------------------------------------------------------------

    def finalize(self) -> RunRecord:
        if not self._stopped:
            self.stop()
        regions: dict[str, RegionRecord] = {}
        for name, st in self._regions.items():
            meas = RegionMeasurements(
                elapsed_s=st.elapsed,
                num_visits=st.visits,
                num_steps=st.steps,
                device_time_s=st.device_time,
                data_lb=st.data_lb.value(),
                expert_lb=st.expert_lb.value(),
                host_lb=st.host_lb.value(),
                in_pod_lb=st.in_pod_lb.value(),
                inter_pod_lb=st.inter_pod_lb.value(),
            )
            counters = RegionCounters()
            computations = {}
            if st.static is not None:
                n = max(st.steps, st.visits, 1)
                scaled = st.static.scaled(n)
                counters = scaled.to_counters()
                # typed per-computation slice (schema v3), truncated to the
                # heaviest entries so the artifact stays O(regions)-small
                computations = {
                    cc.name: cc
                    for cc in scaled.top_computations(self.config.top_computations)
                }
            regions[name] = RegionRecord(
                name=name, measurements=meas, counters=counters,
                computations=computations,
            )

        # Global region inherits summed counters from annotated children if
        # it has none itself (TALP's implicit-global semantics).
        g = regions.get(GLOBAL_REGION)
        if g is not None and g.counters.useful_flops == 0.0:
            agg = RegionCounters()
            for name, r in regions.items():
                if name == GLOBAL_REGION:
                    continue
                agg.useful_flops += r.counters.useful_flops
                agg.hlo_bytes += r.counters.hlo_bytes
                agg.collective_bytes_ici += r.counters.collective_bytes_ici
                agg.collective_bytes_dcn += r.counters.collective_bytes_dcn
                agg.model_flops += r.counters.model_flops
            g.counters = agg
            if not g.computations:
                g.computations = merge_computations(
                    (r.computations for n_, r in regions.items() if n_ != GLOBAL_REGION),
                    self.config.top_computations,
                )

        run = RunRecord(
            app_name=self.config.app_name,
            resources=self.resources,
            timestamp=_dt.datetime.now(_dt.timezone.utc).isoformat(),
            regions=regions,
            metadata=dict(self.metadata),
            hardware=self.config.hardware,
        )
        for r in run.regions.values():
            r.pop = _factors.compute_pop(
                r, run.resources, self.config.hardware,
                overlap_fraction=self.config.overlap_fraction,
            )
        return run
