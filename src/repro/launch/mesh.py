"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never module-level state) so that
importing this module does not touch jax device initialization — the
dry-run must set XLA_FLAGS before any device query.

All mesh construction goes through :mod:`repro.compat` so the same code
runs on JAX releases with and without the ``axis_types``/``AxisType`` API.
"""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    The "pod" axis crosses DCN; "data"/"model" stay on ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Small mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    model = model or 1
    data = n // model
    return compat.make_mesh((data, model), ("data", "model"))


def devices_per_pod(mesh) -> int | None:
    """Device count inside one pod (None when single-pod => no DCN)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if "pod" not in sizes or sizes["pod"] == 1:
        return None
    total = 1
    for s in mesh.devices.shape:
        total *= s
    return total // sizes["pod"]
