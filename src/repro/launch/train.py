"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 10 --ckpt-dir /tmp/ckpt --talp-out talp/case/strong

On a real TPU slice this runs under the standard multi-host JAX bootstrap
(jax.distributed.initialize is called automatically when the TPU env vars
are present); on this container use --smoke (reduced config, host devices).
Re-running with the same --ckpt-dir resumes from the latest checkpoint
(crash = restart the process; the data pipeline is step-indexed).
"""

from __future__ import annotations

import argparse
import os


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf-optimized preset")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--talp-out", default="",
                    help="directory for the TALP run record (CI artifact)")
    ap.add_argument("--model-axis", type=int, default=1,
                    help="model-parallel degree of the host mesh")
    args = ap.parse_args(argv)

    try:  # multi-host TPU bootstrap (no-op on CPU)
        import jax

        if os.environ.get("TPU_WORKER_HOSTNAMES"):
            jax.distributed.initialize()
    except Exception as e:  # pragma: no cover
        print(f"[launch] distributed init skipped: {e}")

    from repro.configs import get_config, optimized_config, smoke_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.optim import AdamWConfig
    from repro.train.loop import LoopConfig, TrainLoop
    from repro.train.train import TrainConfig

    if args.smoke:
        cfg = smoke_config(args.arch)
    elif args.optimized:
        cfg = optimized_config(args.arch)
    else:
        cfg = get_config(args.arch)

    data = SyntheticLM(DataConfig(
        global_batch=args.global_batch, seq_len=args.seq_len,
        vocab=cfg.vocab, accum_steps=args.accum, pad_fraction=0.05,
        frontend_tokens=cfg.n_frontend_tokens if cfg.frontend == "vlm" else 0,
        d_model=cfg.d_model,
    ))
    loop = TrainLoop(
        cfg, make_host_mesh(model=args.model_axis),
        TrainConfig(optimizer=AdamWConfig(lr=args.lr), total_steps=args.steps),
        data,
        LoopConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                   ckpt_dir=args.ckpt_dir, lb_sample_every=1,
                   monitor_app_name=args.arch),
    )
    loop.run()
    h = loop.metrics_history
    print(f"[launch] {args.arch}: steps {h[0]['step']}..{h[-1]['step']} "
          f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}")
    # git metadata + CI folder layout in one call (repro.session); writes
    # only when a destination resolves (--talp-out or TALP_OUT)
    loop.finalize_run(args.talp_out or None)
    if loop.session.last_record_path:
        print(f"[launch] TALP record: {loop.session.last_record_path}")
    elif args.talp_out:
        print("[launch] monitoring disabled by environment; no run record")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
