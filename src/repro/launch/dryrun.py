import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with NO buffer allocation (ShapeDtypeStruct inputs).

For each non-skipped cell this produces a JSON artifact with:
  * compiled.memory_analysis()      -- proves the cell fits per-device HBM
  * compiled.cost_analysis()        -- XLA's per-device FLOPs/bytes
  * the HLO-text counter analysis   -- loop-corrected FLOPs/bytes +
                                       collective bytes split ICI/DCN
  * the three roofline terms        -- §Roofline (single-pod mesh)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
  PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \
      --shape train_4k --multi-pod both --out results/dryrun

The sweep itself is uninstrumented; run it under ``TALP_ENABLE=1`` and every
cell becomes a region of an env-activated ``repro.session`` (lower+compile
wall time, the cell's static counters) with one TALP run record written next
to the cell artifacts — the paper's zero-code-change LD_PRELOAD analogue.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import (
    SHAPES, SHAPE_BY_NAME, effective_mode, get_config, list_archs, skip_reason,
)
from repro.core.profile import StepProfile
from repro.data.pipeline import batch_specs
from repro.distributed import sharding as SH
from repro.launch.mesh import devices_per_pod, make_production_mesh
from repro.layers.common import abstract_params, param_pspecs
from repro.models import transformer as T
from repro.models.flops import (
    decode_model_bytes,
    decode_model_flops,
    prefill_model_flops,
    train_step_model_flops,
)
from repro.optim import AdamWConfig
from repro.serve.serve import cache_pspec_tree, make_decode_step, make_encoder_step, make_prefill_step
from repro.train.train import TrainConfig, make_train_step


def abstract_state(cfg, tcfg: TrainConfig):
    params = abstract_params(T.model_params(cfg), cfg.param_dtype)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree_util.tree_map(f32, params),
        "v": jax.tree_util.tree_map(f32, params),
    }
    if tcfg.optimizer.keep_master:
        opt["master"] = jax.tree_util.tree_map(f32, params)
    return {"params": params, "opt_state": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _sharding(mesh, pspec_tree):
    return jax.tree_util.tree_map(
        lambda p: compat.named_sharding(mesh, p), pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _batch_shardings(mesh, batch_tree, lead_dims: int = 1):
    """Shard the batch dim (index lead_dims-1... actually index 0 for serve,
    index 1 for train's (A,B,...) layout)."""

    def f(x):
        b_index = 1 if lead_dims == 2 else 0
        axes = SH.divisible_batch_axes(mesh, x.shape[b_index])
        spec = [None] * len(x.shape)
        spec[b_index] = axes
        return compat.named_sharding(mesh, P(*spec))

    return jax.tree_util.tree_map(f, batch_tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, tcfg=None, cfg=None,
               accum: int = 1):
    """Lower+compile one cell; returns (compiled, model_flops, mesh, meta).
    ``cfg`` overrides the registry config (perf hillclimbing); ``accum``
    splits the train global batch into microbatches (peak-memory knob —
    per-step roofline totals are unchanged)."""
    cfg = cfg or get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mode = effective_mode(cfg, shape)
    tcfg = tcfg or TrainConfig(optimizer=AdamWConfig())
    meta = {"arch": arch, "shape": shape_name, "mode": mode,
            "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "strategy": SH.effective_strategy(cfg, mesh)}

    with compat.use_mesh(mesh):
        if mode == "train":
            state = abstract_state(cfg, tcfg)
            from repro.train.train import train_state_pspecs

            state_sh = _sharding(mesh, train_state_pspecs(cfg, mesh, tcfg))
            batch = batch_specs(cfg, shape, "train")
            if accum > 1:
                batch = jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(
                        (accum, x.shape[1] // accum) + x.shape[2:], x.dtype
                    ),
                    batch,
                )
            batch_sh = _batch_shardings(mesh, batch, lead_dims=2)
            step = make_train_step(cfg, mesh, tcfg)
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None), donate_argnums=(0,),
            ).lower(state, batch)
            model_flops = train_step_model_flops(cfg, batch["labels"].shape)
        elif mode in ("prefill", "encoder"):
            params = abstract_params(T.model_params(cfg), cfg.param_dtype)
            params_sh = _sharding(
                mesh, param_pspecs(T.model_params(cfg), SH.param_rules(cfg, mesh), mesh)
            )
            batch = batch_specs(cfg, shape, "prefill")
            batch_sh = _batch_shardings(mesh, batch)
            if mode == "encoder":
                step = make_encoder_step(cfg, mesh)
                lowered = jax.jit(
                    step, in_shardings=(params_sh, batch_sh)
                ).lower(params, batch)
            else:
                caches = jax.eval_shape(
                    lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len)
                )
                caches_sh = _sharding(mesh, cache_pspec_tree(cfg, mesh, caches))
                step = make_prefill_step(cfg, mesh)
                lowered = jax.jit(
                    step, in_shardings=(params_sh, batch_sh, caches_sh),
                    out_shardings=(None, caches_sh), donate_argnums=(2,),
                ).lower(params, batch, caches)
            model_flops = prefill_model_flops(cfg, shape.global_batch, shape.seq_len)
        elif mode == "decode":
            params = abstract_params(T.model_params(cfg), cfg.param_dtype)
            params_sh = _sharding(
                mesh, param_pspecs(T.model_params(cfg), SH.param_rules(cfg, mesh), mesh)
            )
            caches = jax.eval_shape(
                lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len)
            )
            caches_sh = _sharding(mesh, cache_pspec_tree(cfg, mesh, caches))
            tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            tokens_sh = _batch_shardings(mesh, tokens)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            step = make_decode_step(cfg, mesh)
            lowered = jax.jit(
                step,
                in_shardings=(params_sh, tokens_sh, None, caches_sh),
                out_shardings=(None, caches_sh), donate_argnums=(3,),
            ).lower(params, tokens, pos, caches)
            model_flops = decode_model_flops(cfg, shape.global_batch, shape.seq_len)
            meta["model_bytes"] = decode_model_bytes(cfg, shape.global_batch, shape.seq_len)
        else:
            raise ValueError(mode)

        t0 = time.time()
        compiled = lowered.compile()
        meta["compile_s"] = round(time.time() - t0, 1)
    return compiled, model_flops, mesh, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False, optimized: bool = False) -> dict:
    tag = f"{arch}__{shape_name}__{'multipod' if multi_pod else 'singlepod'}"
    if optimized:
        tag += "__opt"
    out_path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        rec = {"status": "skipped", "reason": reason, "arch": arch,
               "shape": shape_name, "multi_pod": multi_pod}
        _save(out_path, rec)
        return rec

    try:
        cfg_over = None
        if optimized:
            from repro.configs import optimized_config

            cfg_over = optimized_config(arch)
        compiled, model_flops, mesh, meta = lower_cell(
            arch, shape_name, multi_pod, cfg=cfg_over
        )
        profile = StepProfile.from_compiled(
            compiled,
            num_devices=mesh.devices.size,
            devices_per_pod=devices_per_pod(mesh),
            model_flops=model_flops,
            model_bytes=meta.pop("model_bytes", 0.0),
        )
        rec = {
            "status": "ok", "multi_pod": multi_pod, **meta,
            "profile": profile.to_json(),
            "roofline": profile.roofline_terms(),
            "memory_analysis": profile.memory,
        }
    except Exception as e:  # a failed cell is a bug — record it loudly
        rec = {"status": "failed", "arch": arch, "shape": shape_name,
               "multi_pod": multi_pod, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()}
    _save(out_path, rec)
    return rec


def _save(path: str, rec: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["on", "off", "both"], default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="use the §Perf-optimized presets instead of the "
                         "paper-faithful baselines")
    args = ap.parse_args(argv)

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in SHAPES] if args.shape == "all" else args.shape.split(",")
    pods = {"on": [True], "off": [False], "both": [False, True]}[args.multi_pod]

    import repro

    session = repro.start("dryrun")  # no-op unless TALP_ENABLE=1

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch}__{shape}__{'multipod' if mp else 'singlepod'}"
                t0 = time.time()
                with session.region(tag):
                    rec = run_cell(arch, shape, mp, args.out, args.force,
                                   args.optimized)
                if rec.get("status") == "ok" and "profile" in rec:
                    session.attach_static(
                        tag, StepProfile.from_json(rec["profile"])
                    )
                dt = time.time() - t0
                status = rec["status"]
                line = f"{arch:24s} {shape:12s} {'2x16x16' if mp else '16x16':8s} {status:8s} {dt:6.1f}s"
                if status == "ok":
                    r = rec["roofline"]
                    mem = rec["memory_analysis"]
                    hbm = (mem.get("argument_size_in_bytes", 0) +
                           mem.get("temp_size_in_bytes", 0)) / 2**30
                    frac = r.get("memory_roofline_fraction", r.get("roofline_fraction", 0))
                    line += (f" bottleneck={r['bottleneck'][:-2]:12s}"
                             f" roofline={frac:.3f}"
                             f" mem/dev={hbm:.2f}GiB")
                elif status == "skipped":
                    line += f" ({rec['reason'][:60]})"
                else:
                    n_fail += 1
                    line += f" {rec['error'][:120]}"
                print(line, flush=True)
    if session.finalize(os.path.join(args.out, "talp")) is not None:
        print(f"[dryrun] TALP record: {session.last_record_path}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
