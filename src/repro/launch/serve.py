"""Serving launcher: continuous-batching decode over a paged KV cache
(``--dense`` for the baseline layout), chunked prefill-on-attach overlapped
with in-flight decode, optional temperature/top-k sampling, and monitoring
of both phases.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --requests 8 --max-new 8 --prefill-chunk 16 \
        --page-size 16 --talp-out talp/serve

``--arrival poisson|burst`` swaps the fixed trace for the open-loop
traffic harness (seeded arrivals, mixed lengths, priority classes,
``--cancel-frac`` mid-stream cancellations) and reports goodput, TTFT
percentiles and queue depth; ``--preempt-policy`` picks the victim order
when the page pool exhausts (preempted requests park and recompute-resume
bitwise identically):

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --arrival burst --rate 0.8 --requests 16 \
        --num-pages 8 --page-size 8 --cancel-frac 0.2

``--chaos`` attaches the seeded fault injector (``--fault-seed``): NaN
logits, KV-page corruption, allocator spikes and hung dispatches land
mid-run and the scheduler retries/quarantines through them, reporting
the recovery counters next to the pressure stats:

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --arrival burst --requests 12 --chaos --fault-seed 3 \
        --watchdog-deadline 0.1 --checksum-pages

``--spec`` turns on speculative decoding (paged only): an n-gram
drafter proposes up to ``--spec-k`` tokens from each request's own
history and one batched verify dispatch scores them all, emitting every
accepted token — bitwise identical to plain decode, with the acceptance
rate reported next to the KV stats:

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --spec --spec-k 4 --max-new 32
"""

from __future__ import annotations

import argparse


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prefill token budget per scheduler tick")
    ap.add_argument("--no-overlap", action="store_true",
                    help="stop-the-world prefill on attach (A/B baseline)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="retire requests early on this token id")
    ap.add_argument("--paged", dest="paged", action="store_true", default=True,
                    help="paged KV cache (the default): shared page pool + "
                         "per-slot block tables")
    ap.add_argument("--dense", dest="paged", action="store_false",
                    help="dense (batch x max_len) KV cache — the A/B baseline")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (must divide --max-len)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="KV pool size in pages (default: dense-equivalent "
                         "capacity; size to the expected concurrent-token "
                         "peak for the memory win)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="cross-request prefix sharing: map previously "
                         "prefilled prompt pages into new requests' block "
                         "tables (copy-on-write at the divergence point)")
    ap.add_argument("--prefix-trie-capacity", type=int, default=None,
                    help="max pages the prefix trie may pin (LRU-trimmed); "
                         "default: unbounded (pool pressure still evicts)")
    ap.add_argument("--arrival", choices=("poisson", "burst"), default=None,
                    help="open-loop traffic instead of the fixed trace: "
                         "Poisson or Markov-modulated bursty arrivals from "
                         "the seeded repro.serve.traffic harness (mixed "
                         "lengths, priority classes, mid-stream cancels)")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per scheduler tick (calm state)")
    ap.add_argument("--cancel-frac", type=float, default=0.0,
                    help="fraction of traffic requests that cancel "
                         "mid-stream at a scheduled tick")
    ap.add_argument("--preempt-policy", default="priority",
                    choices=("priority", "pages", "progress", "never"),
                    help="victim selection when the page pool exhausts: "
                         "lowest-priority-first (default), most-pages, "
                         "least-progress, or never (exhaustion raises)")
    ap.add_argument("--chaos", action="store_true",
                    help="attach the seeded fault injector (NaN logits, "
                         "KV-page corruption, allocator spikes, hung "
                         "dispatches) — the scheduler must recover")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="FaultConfig seed: same seed, same fault schedule")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="fault retries per request before quarantine")
    ap.add_argument("--watchdog-deadline", type=float, default=None,
                    help="per-dispatch watchdog deadline in seconds "
                         "(default: off; --chaos defaults it to 0.5)")
    ap.add_argument("--checksum-pages", action="store_true",
                    help="per-page fingerprints validated at prefix-cache "
                         "sharing (catches silent bit flips)")
    ap.add_argument("--shed-queue-depth", type=int, default=None,
                    help="admission queue depth beyond which new lowest-"
                         "priority requests are shed (default: never)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding: n-gram self-drafting + one "
                         "batched verify dispatch per tick (paged only; "
                         "tokens stay bitwise identical to plain decode)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens proposed per verify dispatch")
    ap.add_argument("--spec-min-match", type=int, default=2,
                    help="shortest history n-gram the drafter may match on")
    ap.add_argument("--sample", action="store_true",
                    help="temperature/top-k sampling instead of greedy argmax")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--sample-seed", type=int, default=0)
    ap.add_argument("--talp-out", default="")
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro import compat
    from repro.configs import get_config, smoke_config
    from repro.core import ResourceConfig
    from repro.launch.mesh import make_host_mesh
    from repro.layers.common import init_params
    from repro.models import transformer as T
    from repro.serve.serve import BatchScheduler, ServeConfig
    from repro.session import PerfSession, SessionConfig

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    session = PerfSession(
        SessionConfig(app_name=f"serve-{args.arch}", backend="monitor",
                      lb_sample_every=1),
        ResourceConfig(num_hosts=1, devices_per_host=len(jax.devices())),
    )
    rng = np.random.default_rng(0)
    injector = None
    watchdog = args.watchdog_deadline
    if args.chaos:
        from repro.serve.faults import FaultConfig, FaultInjector

        injector = FaultInjector(FaultConfig(seed=args.fault_seed))
        if watchdog is None:
            watchdog = 0.5
    with compat.use_mesh(mesh), session:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=args.max_len, batch=args.batch,
                        prefill_chunk=args.prefill_chunk,
                        overlap=not args.no_overlap, eos_id=args.eos_id,
                        paged=args.paged, page_size=args.page_size,
                        num_pages=args.num_pages,
                        prefix_cache=args.prefix_cache,
                        prefix_trie_capacity=args.prefix_trie_capacity,
                        greedy=not args.sample,
                        temperature=args.temperature, top_k=args.top_k,
                        sample_seed=args.sample_seed,
                        preempt_policy=args.preempt_policy,
                        max_retries=args.max_retries,
                        watchdog_deadline_s=watchdog,
                        checksum_pages=args.checksum_pages,
                        shed_queue_depth=args.shed_queue_depth,
                        spec_decode=args.spec, spec_k=args.spec_k,
                        spec_min_match=args.spec_min_match),
            params, session=session, fault_injector=injector,
        )
        if args.arrival:
            # open-loop traffic: arrivals, lengths, priorities and cancels
            # are a pure function of the seeded TrafficConfig
            from repro.serve.traffic import (TrafficConfig, generate_workload,
                                             replay)

            workload = generate_workload(TrafficConfig(
                n_requests=args.requests, arrival=args.arrival,
                rate=args.rate, cancel_frac=args.cancel_frac,
                vocab_hi=cfg.vocab,
            ))
            metrics = replay(sched, workload)
            steps = metrics["ticks"]
        else:
            metrics = None
            # with prefix sharing on, give requests something to share: a
            # common system prompt spanning several pages, divergent tails
            system = (
                rng.integers(4, cfg.vocab,
                             size=min(4 * args.page_size, args.max_len // 2)).tolist()
                if args.prefix_cache else []
            )
            for rid in range(args.requests):
                prompt = system + rng.integers(4, cfg.vocab,
                                               size=rng.integers(3, 10)).tolist()
                sched.submit(prompt, request_id=rid, max_new=args.max_new)
            steps = 0
            while len(sched.completed) < args.requests and steps < 10 * args.max_len:
                sched.step()
                steps += 1
            sched.drain()
    print(f"[serve] completed {len(sched.completed)}/{args.requests} requests "
          f"in {steps} ticks ({sched.stats['decode_steps']} decode steps, "
          f"{sched.stats['prefill_chunks']} prefill chunks)")
    if metrics is not None:
        print(f"[serve] traffic ({args.arrival}): "
              f"goodput {metrics['goodput_tokens_per_sec']} tok/s "
              f"({metrics['good_tokens']} tokens), "
              f"{metrics['cancelled']} cancelled, {metrics['failed']} failed; "
              f"TTFT p50/p95/p99 {metrics['ttft_p50_s']}/"
              f"{metrics['ttft_p95_s']}/{metrics['ttft_p99_s']} s; "
              f"queue depth peak {metrics['queue_depth_peak']} "
              f"(mean {metrics['queue_depth_mean']})")
    kv = sched.kv_cache_stats()
    if kv["layout"] == "paged":
        print(f"[serve] paged KV: {kv['kv_bytes']} pool bytes, "
              f"{kv['num_pages']} pages x {kv['page_size']} tokens, "
              f"peak {kv['peak_used_pages']} pages in use "
              f"(utilization {kv['pool_utilization']})")
        if "prefix_cache" in kv:
            pc = kv["prefix_cache"]
            print(f"[serve] prefix cache: hit rate {pc['hit_rate']} "
                  f"({pc['hits']}/{pc['hits'] + pc['misses']} attaches), "
                  f"{pc['prefill_tokens_skipped']} prefill tokens skipped, "
                  f"{pc['pages_saved_by_sharing']} pages saved by sharing, "
                  f"{pc['cow_copies']} copy-on-write pages, "
                  f"{pc['trie_pages']} pages cached "
                  f"({pc['evicted_pages']} evicted)")
    else:
        print(f"[serve] dense KV: {kv['kv_bytes']} bytes")
    pr = kv["pressure"]
    print(f"[serve] pressure: {pr['preemptions']} preemptions "
          f"({pr['pages_freed_by_preempt']} pages freed), "
          f"{pr['resumes']} resumes, "
          f"{pr['evictions_for_preempt']} trie evictions for preempt, "
          f"{pr['cancellations']} cancellations, "
          f"peak queue depth {pr['peak_queue_depth']}")
    if args.spec:
        sp = kv["speculation"]
        print(f"[serve] speculation: acceptance rate {sp['acceptance_rate']} "
              f"({sp['accepted']}/{sp['drafted']} drafts), "
              f"{sp['tokens_per_dispatch']} tokens/dispatch over "
              f"{sp['verify_dispatches']} verify dispatches "
              f"(mean accepted len {sp['mean_accepted_len']})")
    rec = kv["recovery"]
    print(f"[serve] recovery: {rec['retries']} retries "
          f"({rec['backoff_total_ticks']} backoff ticks), "
          f"{rec['quarantined']} quarantined, {rec['shed']} shed, "
          f"{rec['watchdog_trips']} watchdog trips, "
          f"{rec['checksum_failures']} checksum failures"
          + (f"; injected {rec['injected']}" if "injected" in rec else ""))
    session.finalize(args.talp_out or None)
    if session.last_record_path:
        print(f"[serve] TALP record: {session.last_record_path}")
    elif args.talp_out:
        print("[serve] monitoring disabled by environment; no run record")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
