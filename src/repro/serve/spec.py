"""Self-speculative drafting: deterministic prompt-lookup (n-gram) proposal.

The drafter is a PURE FUNCTION of the request's token history — no second
model, no state, no randomness — exactly like a traffic workload is a pure
function of its ``TrafficConfig`` and a fault schedule of its
``FaultConfig``. That purity is what makes speculation compose with every
recovery path for free: a preempted request resumes with its history, a
faulted request replays its clean history, and in both cases the drafter
re-derives bit-for-bit the same proposals it would have made uninterrupted.

Prompt lookup (PLD-style): take the longest recent suffix of the history
(between ``min_match`` and ``max_match`` tokens), find its most recent
earlier occurrence, and propose the tokens that followed it. On
repetitive text — code, templated prose, a greedy decode that has fallen
into a cycle — the continuation usually repeats too, and the batched
verify step accepts the whole window; on non-repetitive text the drafter
proposes nothing (or its proposals are rejected) and decoding degrades to
exactly the sequential path.

Acceptance is decided by the verify dispatch, not here: the scheduler
keeps the longest prefix where draft == model output (argmax in greedy
mode; the per-request position-folded sample otherwise), which is
provably bitwise-identical to step-by-step decode — a draft token is only
ever kept when it IS the token sequential decode would have produced.
"""

from __future__ import annotations

__all__ = ["draft_tokens"]


def draft_tokens(history, k: int, *, min_match: int = 2,
                 max_match: int = 8) -> list[int]:
    """Propose up to ``k`` continuation tokens for ``history`` by n-gram
    lookup.

    Scans suffix lengths from ``min(max_match, len-1)`` down to
    ``min_match``; for the first suffix with an earlier occurrence,
    returns (a copy of) the up-to-``k`` tokens that followed its MOST
    RECENT earlier occurrence. Ties on suffix length break toward the
    longer match, then the later occurrence — both deterministic — so
    the proposal is a pure function of ``history`` alone. Returns ``[]``
    when the history is too short or nothing matches."""
    if k <= 0:
        return []
    hist = [int(t) for t in history]
    n = len(hist)
    for m in range(min(int(max_match), n - 1), max(int(min_match), 1) - 1, -1):
        suffix = hist[n - m:]
        # most recent earlier occurrence; i == n - m is the suffix itself
        for i in range(n - m - 1, -1, -1):
            if hist[i:i + m] == suffix:
                cont = hist[i + m : i + m + k]
                if cont:
                    return cont
                break  # suffix ends flush against itself: shorter m next
    return []
