from repro.serve.serve import (
    ServeConfig,
    make_decode_step,
    make_prefill_step,
    make_prefill_chunk_step,
    make_serve_decode_step,
    make_spec_verify_step,
    serve_cache_pspecs,
    BatchScheduler,
    RequestHandle,
)
from repro.serve.spec import draft_tokens
from repro.serve.traffic import (
    TrafficConfig,
    TrafficRequest,
    generate_workload,
    replay,
)
from repro.serve.faults import (
    FaultConfig,
    FaultEvent,
    FaultInjector,
    generate_faults,
)

__all__ = [
    "ServeConfig", "make_decode_step", "make_prefill_step",
    "make_prefill_chunk_step", "make_serve_decode_step",
    "make_spec_verify_step", "draft_tokens",
    "serve_cache_pspecs", "BatchScheduler", "RequestHandle",
    "TrafficConfig", "TrafficRequest", "generate_workload", "replay",
    "FaultConfig", "FaultEvent", "FaultInjector", "generate_faults",
]
