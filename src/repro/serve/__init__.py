from repro.serve.serve import (
    ServeConfig,
    make_decode_step,
    make_prefill_step,
    make_prefill_chunk_step,
    make_serve_decode_step,
    serve_cache_pspecs,
    BatchScheduler,
    RequestHandle,
)
from repro.serve.traffic import (
    TrafficConfig,
    TrafficRequest,
    generate_workload,
    replay,
)

__all__ = [
    "ServeConfig", "make_decode_step", "make_prefill_step",
    "make_prefill_chunk_step", "make_serve_decode_step",
    "serve_cache_pspecs", "BatchScheduler", "RequestHandle",
    "TrafficConfig", "TrafficRequest", "generate_workload", "replay",
]
