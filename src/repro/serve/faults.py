"""Seeded fault injection for the serving stack — the chaos harness.

A fault schedule is a pure function of its :class:`FaultConfig`, exactly
like a traffic workload is of its ``TrafficConfig``: every injection tick,
fault kind and target pick comes out of one seeded
``np.random.default_rng``, so two chaos runs with the same config inject
bit-for-bit the same faults — which is what lets CI assert that recovery
is *bitwise identical* to a fault-free run instead of merely "didn't
crash".

Four fault kinds, covering the serving failure modes the scheduler must
survive (``BatchScheduler`` consumes the injector via ``sched.faults``):

  ``nan``           poison one decode dispatch's logits with NaN for a
                    chosen slot (a numerically-diverged step, an XLA
                    miscompile, a bad reduction) — caught by the on-device
                    finiteness sentinel riding the token readback
  ``page_corrupt``  overwrite one KV pool page a live request reads
                    (``corrupt_mode="nan"``: sentinel-detectable on the
                    next attention read; ``"bitflip"``: a silent bit flip
                    only per-page checksums can catch — the prefix-cache
                    validation path)
  ``alloc_spike``   grab free pages from the pool for a few ticks (a
                    co-tenant's transient burst) — the scheduler must
                    degrade through its normal park/preempt pressure path
                    and recover when the spike releases
  ``hang``          delay one decode dispatch past the watchdog deadline
                    (a stuck collective, a wedged host thread) — the
                    watchdog trips and the victim retries

The injector never touches scheduler internals directly: it hands the
scheduler *due events*; the scheduler applies them through the same
jitted page-edit steps and allocation paths real faults would corrupt,
and defers events that have no applicable target yet (so every scheduled
fault eventually lands while work is live).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Everything a fault schedule is; hash the fields, hash the chaos."""

    seed: int = 0
    horizon_ticks: int = 48       # injection ticks draw from [1, horizon]
    n_nan: int = 1                # poisoned decode dispatches
    n_page_corrupt: int = 1       # corrupted KV pool pages
    n_alloc_spike: int = 1        # transient allocator-exhaustion spikes
    n_hang: int = 1               # delayed (hung) decode dispatches
    corrupt_mode: str = "nan"     # "nan" (sentinel) | "bitflip" (checksum)
    spike_pages: int = 2          # pages a spike grabs (clamped to free)
    spike_ticks: int = 4          # ticks a spike holds them
    hang_s: float = 0.05          # injected dispatch delay (seconds)

    def __post_init__(self):
        if self.corrupt_mode not in ("nan", "bitflip"):
            raise ValueError(
                f"corrupt_mode must be nan|bitflip, got {self.corrupt_mode!r}"
            )
        if self.horizon_ticks < 1:
            raise ValueError("horizon_ticks must be >= 1")


@dataclasses.dataclass
class FaultEvent:
    """One scheduled injection. ``tick`` is advanced when the event is
    deferred (no applicable target yet); ``pick`` selects the victim among
    the applicable candidates (mod their count), so the same schedule hits
    the same targets on a bit-identical rerun. ``request_id`` (tests)
    restricts candidates to one request."""

    kind: str                 # "nan" | "page_corrupt" | "alloc_spike" | "hang"
    tick: int
    pick: int = 0
    pick2: int = 0            # secondary pick (page index within the slot)
    request_id: object = None


def generate_faults(fcfg: FaultConfig) -> list[FaultEvent]:
    """The fault schedule as a pure function of its config."""
    rng = np.random.default_rng(fcfg.seed)
    events: list[FaultEvent] = []
    for kind, n in (("nan", fcfg.n_nan),
                    ("page_corrupt", fcfg.n_page_corrupt),
                    ("alloc_spike", fcfg.n_alloc_spike),
                    ("hang", fcfg.n_hang)):
        for _ in range(max(int(n), 0)):
            events.append(FaultEvent(
                kind=kind,
                tick=int(rng.integers(1, fcfg.horizon_ticks + 1)),
                pick=int(rng.integers(0, 1 << 30)),
                pick2=int(rng.integers(0, 1 << 30)),
            ))
    events.sort(key=lambda e: (e.tick, e.kind, e.pick))
    return events


class FaultInjector:
    """Drives a fault schedule into a ``BatchScheduler`` tick by tick.

    The scheduler polls ``due(tick)`` once per tick and applies each event
    it can; an event with no applicable target (no decoding slot to
    poison, no free page to grab) is handed back via ``defer`` and comes
    due again next tick — a scheduled fault is never silently dropped
    while the injector is attached. ``counters`` records what actually
    landed (the chaos bench artifact and the ``recovery`` stats block
    surface them)."""

    def __init__(self, fcfg: FaultConfig | None = None,
                 events: list[FaultEvent] | None = None):
        self.fcfg = fcfg if fcfg is not None else FaultConfig()
        self.pending: list[FaultEvent] = (
            list(events) if events is not None else generate_faults(self.fcfg)
        )
        self.counters = {
            "nan_injected": 0, "pages_corrupted": 0, "alloc_spikes": 0,
            "hangs": 0, "deferrals": 0,
        }

    def due(self, tick: int) -> list[FaultEvent]:
        """Pop every event scheduled at or before ``tick``."""
        ready = [e for e in self.pending if e.tick <= tick]
        if ready:
            self.pending = [e for e in self.pending if e.tick > tick]
        return ready

    def defer(self, event: FaultEvent, tick: int) -> None:
        """No applicable target this tick: retry the event next tick."""
        event.tick = tick + 1
        self.pending.append(event)
        self.counters["deferrals"] += 1

    @property
    def exhausted(self) -> bool:
        return not self.pending

    def record(self, kind: str) -> None:
        key = {"nan": "nan_injected", "page_corrupt": "pages_corrupted",
               "alloc_spike": "alloc_spikes", "hang": "hangs"}[kind]
        self.counters[key] += 1


# ---------------------------------------------------------------------------
# device-side page edits: corruption, scrubbing, fingerprints
# ---------------------------------------------------------------------------


def _is_paged(path) -> bool:
    # mirrors serve._is_paged_leaf without importing serve (no cycle): the
    # paged attention pools are the only cache leaves with "pages" in their
    # pytree path
    return "pages" in "/".join(
        str(getattr(p, "key", p)) for p in path
    )


_UINT = {2: jnp.uint16, 4: jnp.uint32}
_FLIP = {2: 0x5A5A, 4: 0x5A5A5A5A}


def _edit_leaf(leaf, page, mode):
    """One paged pool leaf (R, P, page, Hkv, hd): rewrite physical ``page``."""
    if mode == "nan":
        return leaf.at[:, page].set(jnp.asarray(jnp.nan, leaf.dtype))
    if mode == "zero":
        return leaf.at[:, page].set(jnp.asarray(0, leaf.dtype))
    # "bitflip": XOR a fixed pattern through a bitcast — values stay finite
    # often enough that the NaN sentinel alone cannot catch this; only the
    # per-page checksum path does
    ubits = _UINT[leaf.dtype.itemsize]
    u = jax.lax.bitcast_convert_type(leaf, ubits)
    u = u.at[:, page].set(u[:, page] ^ jnp.asarray(_FLIP[leaf.dtype.itemsize],
                                                   ubits))
    return jax.lax.bitcast_convert_type(u, leaf.dtype)


def make_page_edit_step(mode: str):
    """Jitted whole-tree page rewrite: corrupt (``nan``/``bitflip``) or
    scrub (``zero``) one physical page across every paged pool leaf;
    non-paged leaves (recurrent state, dense caches) pass through. The
    cache tree is donated — the edit replaces the scheduler's caches the
    same way a decode dispatch does."""

    def edit(caches, page):
        flat = jax.tree_util.tree_flatten_with_path(caches)
        leaves = [
            _edit_leaf(leaf, page, mode) if _is_paged(path) else leaf
            for path, leaf in flat[0]
        ]
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(caches), leaves
        )

    return jax.jit(edit, donate_argnums=(0,))


@functools.lru_cache(maxsize=4)
def page_edit_step(mode: str):
    """Process-shared jitted page-edit per mode (page index is traced, so
    one trace covers every page)."""
    return make_page_edit_step(mode)


def make_page_fingerprint_step():
    """Jitted uint32 content fingerprint of one physical page across every
    paged pool leaf (bitcast to integers, wrapping sum — deterministic,
    order-independent within a page, and any single bit flip moves it).
    Cheap enough to run per shared page at prefix-cache attach when
    ``ServeConfig.checksum_pages`` is on."""

    def fingerprint(caches, page):
        acc = jnp.uint32(0)
        for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]:
            if not _is_paged(path):
                continue
            u = jax.lax.bitcast_convert_type(leaf, _UINT[leaf.dtype.itemsize])
            acc = acc + jnp.sum(u[:, page].astype(jnp.uint32),
                                dtype=jnp.uint32)
        return acc

    return jax.jit(fingerprint)


@functools.lru_cache(maxsize=1)
def page_fingerprint_step():
    return make_page_fingerprint_step()


__all__ = [
    "FaultConfig", "FaultEvent", "FaultInjector", "generate_faults",
    "make_page_edit_step", "page_edit_step",
    "make_page_fingerprint_step", "page_fingerprint_step",
]
