"""Serving: prefill + batched decode with sharded KV caches.

``serve_step`` (one new token against a KV cache of ``seq_len``) is what the
``decode_*`` / ``long_*`` dry-run shapes lower, per the assignment spec.
Caches shard like activations: batch over ("pod","data"), kv-heads over
"model" where divisible (megatron) else replicated; recurrent states shard
over their head/inner dims.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.distributed import sharding as SH
from repro.layers.common import LogicalConstraints
from repro.models import transformer as T
from repro.serve.spec import draft_tokens

# the static fields ``_serve_step_fns`` keys its lru cache on — see
# ServeConfig.step_statics() for what belongs here (and what must not)
_StepStatics = collections.namedtuple(
    "_StepStatics",
    ["paged", "greedy", "temperature", "top_k",
     "prefix_cache", "prefix_trie_capacity",
     "spec_decode", "spec_k", "spec_min_match"],
)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    batch: int
    # sampling: greedy argmax by default (bitwise-stable serving); with
    # greedy=False the decode and prefill-chunk steps sample on device with
    # temperature (and optionally top_k) from a per-request PRNG key the
    # scheduler writes into the slot's key row at attach
    # (fold_in(PRNGKey(sample_seed), request_tag)), folded with the sampled
    # position each step — a request's stream is a pure function of
    # (params, prompt, request_id, sample_seed), never of co-resident
    # traffic, the overlap schedule, who occupied the slot before, or which
    # slot the request (re)attaches into — which is what makes a preempted
    # request's resumed stream identical in any slot.
    temperature: float = 1.0
    greedy: bool = True
    top_k: int | None = None
    sample_seed: int = 0
    # chunked prefill-on-attach: token budget (= chunk size) the scheduler
    # spends on prefill per tick. With ``overlap=True`` (the default) chunks
    # are dispatched asynchronously BETWEEN decode dispatches, so attaching a
    # queued request never stalls the in-flight decode pipeline; overlap=False
    # is the stop-the-world baseline (whole prompt prefilled synchronously on
    # attach) kept for benchmarks/serve_throughput.py.
    prefill_chunk: int = 32
    overlap: bool = True
    # early stop: retire a request when it emits ``eos_id``. EOS needs token
    # *values* on the host, so pending readbacks are additionally flushed
    # every ``eos_check_every`` ticks (bounded detection latency without
    # paying one transfer per step).
    eos_id: int | None = None
    eos_check_every: int = 8
    # paged KV cache (the default): attention caches live in a shared pool
    # of ``page_size``-token pages addressed through per-slot block tables,
    # allocated as prefill/decode actually write and freed on retire — HBM
    # scales with live tokens instead of batch x max_len. ``num_pages=None``
    # sizes the pool at dense-equivalent capacity (batch*max_len/page_size);
    # real deployments size it to the expected concurrent-token peak.
    # Tokens are bitwise identical paged vs dense. paged=False keeps the
    # dense (B, max_len) layout (the A/B baseline).
    paged: bool = True
    page_size: int = 16
    num_pages: int | None = None
    # cross-request prefix cache (opt-in, paged only): a radix trie keyed
    # on prompt-token pages maps previously prefilled prompt prefixes into
    # a new request's block table read-only (refcount bump; that part of
    # chunked prefill is skipped), and the first partially-shared page is
    # copy-on-write. ``prefix_trie_capacity`` caps how many pages the trie
    # may pin, LRU-trimmed on insert; None = unbounded (pool pressure
    # still evicts LRU entries nobody else reads).
    prefix_cache: bool = False
    prefix_trie_capacity: int | None = None
    # preemption under memory pressure (paged only): when the page pool
    # exhausts, pick a victim request by policy, release its pages, and park
    # it for recompute-resume — re-prefill is cheap through the paged cache
    # (and the PrefixCache/CoW path when enabled), and the resumed stream is
    # bitwise identical to an uninterrupted run. Policies order victim
    # candidates (never a request older/higher-priority than the one asking):
    #   "priority"  lowest priority first, then most pages, then least
    #               progress (the default — frees the most for the least
    #               wasted work among the least important)
    #   "pages"     most pages first (frees fastest)
    #   "progress"  least generated tokens first (wastes the least recompute)
    #   "never"     pre-preemption behavior: exhaustion unwinds the failed
    #               attach (releasing every page it held — nothing leaks)
    #               and raises
    preempt_policy: str = "priority"
    # resilience (fault detection + recovery). A request whose dispatch is
    # detected bad (NaN/Inf logits via the on-device sentinel, a page
    # checksum mismatch, a watchdog trip) is RETRIED through the existing
    # park/recompute-resume path with capped exponential backoff
    # (min(cap, base << (retries-1)) ticks before it may re-attach) — a
    # retried stream is bitwise identical to an unfaulted run, greedy AND
    # sampled, because resume replays the clean history. After
    # ``max_retries`` failed attempts the request is QUARANTINED: terminal
    # "failed" status on its handle, pages freed, co-residents untouched —
    # the scheduler never crashes on a misbehaving request.
    max_retries: int = 3
    retry_backoff_base: int = 1
    retry_backoff_cap: int = 8
    # watchdog: a decode dispatch whose host-side dispatch call exceeds
    # this many seconds trips the watchdog — the (late) tokens are kept
    # (identity is preserved) and the targeted request retries so a wedged
    # dispatch path cannot stall its stream forever. None = off.
    watchdog_deadline_s: float | None = None
    # per-page content checksums (paged + prefix_cache only): fingerprint
    # each prompt page at trie insert and validate before mapping it into
    # a new request's block table — a silently corrupted shared page
    # (bitflip, not NaN) is evicted and re-prefilled fresh instead of
    # poisoning every future reader.
    checksum_pages: bool = False
    # load shedding: when the admission queue already holds this many
    # requests, a new submit sheds the lowest-priority youngest waiter
    # (possibly the new arrival itself) with a terminal "shed" status —
    # a clear rejection instead of unbounded queueing under sustained
    # pressure or fault rate. None = never shed.
    shed_queue_depth: int | None = None
    # speculative multi-token decoding (opt-in, paged only): each tick a
    # deterministic prompt-lookup drafter (repro.serve.spec — a pure
    # function of the request's prompt + emitted tokens, no second model)
    # proposes up to ``spec_k`` draft tokens, and ONE batched verify
    # dispatch scores all K+1 positions against the paged KV cache
    # (the prefill-chunk multi-token path). The longest prefix where
    # draft == model output is accepted — greedy acceptance is provably
    # bitwise-identical to step-by-step decode, and sampled acceptance
    # folds the per-request key at each verified POSITION (the PR 8
    # stream-purity invariant), so spec on/off never changes a token.
    # ``spec_min_match`` is the shortest history n-gram the drafter may
    # match on (shorter = more, lower-confidence drafts).
    spec_decode: bool = False
    spec_k: int = 4
    spec_min_match: int = 2

    def __post_init__(self):
        if self.checksum_pages and not (self.paged and self.prefix_cache):
            raise ValueError(
                "checksum_pages=True requires paged=True and "
                "prefix_cache=True: checksums guard pages shared across "
                "requests through the prefix trie"
            )
        if self.max_retries < 0 or self.retry_backoff_base < 1 \
                or self.retry_backoff_cap < 1:
            raise ValueError(
                "max_retries must be >= 0 and retry backoff base/cap >= 1"
            )
        if self.prefix_cache and not self.paged:
            raise ValueError(
                "prefix_cache=True requires paged=True: prefix sharing maps "
                "pool pages into multiple slots' block tables, which the "
                "dense (batch, max_len) layout cannot express"
            )
        if self.preempt_policy not in ("priority", "pages", "progress",
                                       "never"):
            raise ValueError(
                f"preempt_policy must be one of priority|pages|progress|never,"
                f" got {self.preempt_policy!r}"
            )
        if self.spec_decode and not self.paged:
            raise ValueError(
                "spec_decode=True requires paged=True: the batched verify "
                "step scores K+1 positions through the paged pool's "
                "block-table reads, and rollback of rejected positions "
                "relies on the pool's masked scatter writes"
            )
        if self.spec_decode and (self.spec_k < 1 or self.spec_min_match < 1):
            raise ValueError(
                f"spec_k ({self.spec_k}) and spec_min_match "
                f"({self.spec_min_match}) must be >= 1"
            )

    def step_statics(self) -> "_StepStatics":
        """The step-function cache key: every field that changes WHICH
        jitted step functions a scheduler needs or HOW they compute —
        sampling statics, the prefix-cache knobs (the CoW step only
        exists for prefix-cached schedulers), and the speculation knobs
        (the verify step only exists for spec schedulers, and its
        compiled acceptance math depends on them). Shape-only fields
        (max_len, batch, num_pages, ...) stay OUT: jit retraces per
        shape on its own, and excluding them lets A/B benchmark pairs
        (ample vs tight pool, eos on/off) share compiled traces."""
        return _StepStatics(
            self.paged, self.greedy, self.temperature, self.top_k,
            self.prefix_cache, self.prefix_trie_capacity,
            self.spec_decode, self.spec_k, self.spec_min_match,
        )


def _cache_path_name(path) -> str:
    return "/".join(str(p.key) if hasattr(p, "key") else str(p) for p in path)


def cache_pspec_tree(cfg, mesh, caches):
    """PartitionSpecs for the stacked cache pytree.

    Attention KV caches are the serving-memory wall (command-r decode_32k:
    343 GB). Sharding priority: batch over ("pod","data") when divisible;
    kv-heads over "model" when divisible, else the **sequence** dim over
    "model" (decode attention over a seq-sharded cache = partial softmax +
    tiny all-reduces — the GSPMD-native flash-decode layout)."""
    rules = SH.activation_rules(cfg, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)

    def batch_ax(b: int):
        return SH.divisible_batch_axes(mesh, b)

    kv_div = cfg.n_kv_heads % model == 0 and model > 1
    inner = rules["inner"]
    ssm_heads = (
        "model"
        if cfg.ssm and cfg.ssm.n_heads(cfg.d_model) % model == 0 and model > 1
        else None
    )

    def f(path_leaf):
        path, leaf = path_leaf
        name = _cache_path_name(path)
        nd = len(leaf.shape)
        if "pages" in name:  # paged pool (R, P, page, Hkv, hd): no batch dim
            # pages are gathered by physical index, so the page axis must
            # stay unsharded; kv-heads shard over "model" like the dense
            # layout (the pool is the same bytes, just re-bucketed)
            return P(None, None, None, "model" if kv_div else None, None)
        b = leaf.shape[1] if nd >= 2 else 1
        batch = batch_ax(b)
        if "attn" in name:  # (R, B, Smax, Hkv, hd)
            if kv_div:
                return P(None, batch, None, "model", None)
            return P(None, batch, "model" if model > 1 else None, None, None)
        if "mamba" in name and nd == 4:  # conv (R, B, K-1, C)
            return P(None, batch, None, inner)
        if "mamba" in name and nd == 5:  # ssm (R, B, h, p, n)
            return P(None, batch, ssm_heads, None, None)
        return P(*([None, batch] + [None] * (nd - 2)))

    paths = jax.tree_util.tree_flatten_with_path(caches)[0]
    specs = [f(pl) for pl in paths]
    treedef = jax.tree_util.tree_structure(caches)
    return jax.tree_util.tree_unflatten(treedef, specs)


def serve_cache_pspecs(cfg, mesh, batch: int, max_len: int, *,
                       paged: bool = False, page_size: int = 16,
                       num_pages: int | None = None):
    caches = jax.eval_shape(
        lambda: T.init_cache(cfg, batch, max_len, paged=paged,
                             page_size=page_size, num_pages=num_pages)
    )
    return cache_pspec_tree(cfg, mesh, caches)


def make_prefill_step(cfg, mesh):
    lc = LogicalConstraints(mesh, SH.activation_rules(cfg, mesh))

    def prefill_step(params, batch, caches):
        logits, new_caches = T.prefill(params, batch, cfg, caches, lc)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_caches

    return prefill_step


def make_decode_step(cfg, mesh):
    lc = LogicalConstraints(mesh, SH.activation_rules(cfg, mesh))

    def decode_step(params, tokens, pos, caches):
        """tokens: (B,1) int32; pos: () int32 shared position, or (B,) int32
        per-slot positions (continuous batching)."""
        logits, new_caches = T.decode_step(params, tokens, pos, cfg, caches, lc)
        next_tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(jnp.int32)
        return next_tok, new_caches

    return decode_step


def _sample_tokens(logits, rng_keys, positions, *, greedy, temperature,
                   top_k, vocab):
    """On-device next-token selection for a batch of slots.

    logits: (N, V); rng_keys: (N, 2) uint32 base keys (the scheduler
    writes each attached request's own key into its slot's row); positions:
    (N,) int32 — the position whose logits are being sampled. Greedy (the
    default) is a plain argmax, bitwise identical to the historical
    behavior. Otherwise temperature (and optionally top-k) sampling with
    the key ``fold_in(rng_keys[i], positions[i])`` — STATELESS per step,
    so a request's sampled stream is a pure function of (params, prompt,
    request_id, sample_seed): it cannot depend on co-resident requests'
    decode traffic, the overlap schedule, who occupied the slot before,
    or which slot it (re)attaches into.
    Padded vocab ids are masked out. Returns tokens (N,) int32."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    use = jax.vmap(jax.random.fold_in)(rng_keys, positions)
    lg = logits.astype(jnp.float32) / max(float(temperature), 1e-6)
    V = lg.shape[-1]
    if vocab < V:
        lg = jnp.where(jnp.arange(V)[None, :] < vocab, lg, -jnp.inf)
    if top_k:
        kth = jnp.sort(lg, axis=-1)[:, -int(top_k)][:, None]
        lg = jnp.where(lg >= kth, lg, -jnp.inf)
    return jax.vmap(jax.random.categorical)(use, lg).astype(jnp.int32)


def make_serve_decode_step(cfg, mesh, *, paged=False, greedy=True,
                           temperature=1.0, top_k=None):
    """Continuous-batching decode: per-slot positions + active mask.

    Inactive slots (empty, or mid-prefill — their cache lines belong to the
    concurrently dispatched prefill chunks) neither write the KV cache nor
    advance recurrent state; their sampled tokens are garbage and ignored.
    ``paged=True`` adds a ``block_tables`` argument routing attention-cache
    writes and reads through the shared page pool.

    The trailing ``fault_mask`` (B,) bool argument poisons the masked
    slots' logits with NaN *before* the finiteness sentinel — the chaos
    harness's logit-fault injection point. An all-False mask is a bitwise
    no-op (``jnp.where`` selects, never propagates), so the fault-free
    path pays one fused select. The second return value is the on-device
    NaN/Inf sentinel: ``bad[i]`` is True when slot ``i``'s logits contain
    a non-finite value — it rides the deferred token readback for free
    (one extra (B,) bool per flush), and the scheduler retries flagged
    requests instead of streaming garbage."""
    lc = LogicalConstraints(mesh, SH.activation_rules(cfg, mesh))
    sample = functools.partial(
        _sample_tokens, greedy=greedy, temperature=temperature, top_k=top_k,
        vocab=cfg.vocab,
    )

    def _poison_and_sample(logits, fault_mask, rng_keys, pos):
        logits = jnp.where(fault_mask[:, None],
                           jnp.asarray(jnp.nan, logits.dtype), logits)
        bad = ~jnp.all(jnp.isfinite(logits), axis=-1)
        pos_v = jnp.broadcast_to(jnp.asarray(pos).reshape(-1),
                                 logits.shape[:1])
        return sample(logits, rng_keys, pos_v), bad

    if paged:
        def decode_step(params, tokens, pos, active, caches, block_tables,
                        rng_keys, fault_mask):
            """tokens: (B,1); pos: (B,); active: (B,) bool; block_tables:
            (B, n_logical) int32; rng_keys: (B,2) uint32 (static per slot
            — the sampling key is folded with the position); fault_mask:
            (B,) bool."""
            logits, new_caches = T.decode_step(
                params, tokens, pos, cfg, caches, lc, active=active,
                block_tables=block_tables,
            )
            tok, bad = _poison_and_sample(logits, fault_mask, rng_keys, pos)
            return tok[:, None], bad, new_caches
    else:
        def decode_step(params, tokens, pos, active, caches, rng_keys,
                        fault_mask):
            """tokens: (B,1) int32; pos: (B,) int32; active: (B,) bool;
            fault_mask: (B,) bool."""
            logits, new_caches = T.decode_step(
                params, tokens, pos, cfg, caches, lc, active=active
            )
            tok, bad = _poison_and_sample(logits, fault_mask, rng_keys, pos)
            return tok[:, None], bad, new_caches

    return decode_step


def _is_paged_leaf(path) -> bool:
    return "pages" in _cache_path_name(path)


def make_prefill_chunk_step(cfg, mesh, *, paged=False, greedy=True,
                            temperature=1.0, top_k=None):
    """One chunk of one request's prompt into ONE slot's cache lines.

    The slot's recurrent-state rows are sliced out of the stacked cache
    pytree, run through ``T.prefill_chunk`` at batch 1, and scattered back —
    the other slots' state passes through untouched. Paged attention pools
    are passed whole: the chunk writes only the pages its block-table row
    owns, so it commutes with in-flight decode dispatches exactly like the
    dense slot-sliced write does."""
    lc = LogicalConstraints(mesh, SH.activation_rules(cfg, mesh))
    sample = functools.partial(
        _sample_tokens, greedy=greedy, temperature=temperature, top_k=top_k,
        vocab=cfg.vocab,
    )

    def _slot_slice(caches, slot):
        flat = jax.tree_util.tree_flatten_with_path(caches)
        leaves = [
            leaf if _is_paged_leaf(path)
            else jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=1)
            for path, leaf in flat[0]
        ]
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(caches), leaves
        )

    def _scatter_back(caches, new_slot, slot):
        flat_full = jax.tree_util.tree_flatten_with_path(caches)
        flat_new = jax.tree_util.tree_leaves(new_slot)
        leaves = [
            upd if _is_paged_leaf(path)
            else jax.lax.dynamic_update_slice_in_dim(
                full, upd.astype(full.dtype), slot, axis=1
            )
            for (path, full), upd in zip(flat_full[0], flat_new)
        ]
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(caches), leaves
        )

    def chunk_step(params, tokens, start, length, slot, caches, block_tables,
                   rng_keys):
        """tokens: (1,C) int32 (padded); start/length: (1,) int32;
        slot: () int32; caches: full stacked tree; block_tables: the full
        (B, n_logical) table (or None when dense); rng_keys: (B,2) static
        per-slot base keys. Returns (next_tok (1,) sampled at the last
        valid position, bad (1,) — the NaN/Inf finiteness sentinel over
        the chunk's logits, catching a corrupted shared page read during
        prefill the same way the decode sentinel catches it — and
        new_caches)."""
        slot_caches = _slot_slice(caches, slot)
        tbl_row = (
            jax.lax.dynamic_slice_in_dim(block_tables, slot, 1, axis=0)
            if paged else None
        )
        logits, new_slot = T.prefill_chunk(
            params, {"tokens": tokens}, cfg, slot_caches, start, length, lc,
            block_tables=tbl_row,
        )
        key_row = jax.lax.dynamic_slice_in_dim(rng_keys, slot, 1, axis=0)
        next_tok = sample(logits, key_row, start + length - 1)
        bad = ~jnp.all(jnp.isfinite(logits), axis=-1)
        new_caches = _scatter_back(caches, new_slot, slot)
        return next_tok, bad, new_caches

    if paged:
        return chunk_step

    def chunk_step_dense(params, tokens, start, length, slot, caches,
                         rng_keys):
        return chunk_step(params, tokens, start, length, slot, caches, None,
                          rng_keys)

    return chunk_step_dense


def make_spec_verify_step(cfg, mesh, *, greedy=True, temperature=1.0,
                          top_k=None, two_pass=False):
    """Batched speculative verify: score K+1 positions per slot in ONE
    dispatch against the paged KV cache.

    The scoring body IS ``T.prefill_chunk`` (``all_logits=True``): each
    slot's row carries ``[last_token, draft_1 .. draft_k]`` at positions
    ``start .. start+length-1``, attends through the block tables with
    per-row causal/window masking (the ``paged_prefill_attention`` S>1
    read), and yields the logits a sequential ``decode_step`` would have
    produced at every one of those positions — so the argmax (greedy) or
    the position-folded sample (sampled mode; the same
    ``fold_in(request_key, position)`` stream as sequential decode) at
    position ``start+i`` is bitwise the token step-by-step decode emits
    there. Acceptance keeps the longest prefix where draft == output,
    computed on device (a cumulative product over the match mask), so the
    host readback is just ``(tokens, accept_len, bad)``.

    Rollback of rejected positions is free under the paged layout: their
    K/V writes are masked scatters that later (correct) writes at the
    same positions overwrite, and every read is clipped to the reader's
    own ``cache_len`` — so the scheduler rolls back by simply not
    advancing ``pos`` past the accepted prefix.

    ``two_pass=True`` (recurrent/hybrid archs — mamba/xLSTM state has no
    positional masking and cannot be clamped back): the scoring pass
    discards its caches, and a second pass over the SAME tokens clamped
    to the accepted length re-commits — recurrent state then advances
    over exactly the accepted tokens, and attention K/V holds no stale
    rejected writes at all. Both passes run inside the one dispatch.

    ``fault_mask`` poisons whole rows ahead of the sentinel exactly like
    the decode step; ``bad`` is the NaN/Inf sentinel over each row's
    VALID positions (a poisoned dispatch, or a corrupted page any of the
    K+1 reads touched)."""
    lc = LogicalConstraints(mesh, SH.activation_rules(cfg, mesh))
    sample = functools.partial(
        _sample_tokens, greedy=greedy, temperature=temperature, top_k=top_k,
        vocab=cfg.vocab,
    )

    def verify_step(params, tokens, start, length, caches, block_tables,
                    rng_keys, fault_mask):
        """tokens: (B,C) int32 — row r is [last_tok, drafts...] padded;
        start: (B,) int32 per-slot positions; length: (B,) int32 valid
        tokens per row (0 = inactive slot: writes masked, state
        untouched); rng_keys: (B,2); fault_mask: (B,) bool.
        Returns (out (B,C) int32 — the verified token at each position,
        accept (B,) int32 — accepted DRAFT count (0..length-1),
        bad (B,) bool, new_caches)."""
        B, C = tokens.shape
        logits, new_caches = T.prefill_chunk(
            params, {"tokens": tokens}, cfg, caches, start, length, lc,
            block_tables=block_tables, all_logits=True,
        )  # (B, C, V)
        logits = jnp.where(
            fault_mask[:, None, None], jnp.asarray(jnp.nan, logits.dtype),
            logits,
        )
        offs = jnp.arange(C, dtype=jnp.int32)[None, :]
        valid = offs < length[:, None]
        bad = jnp.any(~jnp.all(jnp.isfinite(logits), axis=-1) & valid, axis=1)
        positions = start[:, None] + offs
        out = sample(
            logits.reshape(B * C, -1),
            jnp.repeat(rng_keys, C, axis=0),
            positions.reshape(-1),
        ).reshape(B, C)
        # longest accepted draft prefix: draft i (= tokens[:, i+1]) is
        # accepted iff it equals the verified token at position i AND
        # every earlier draft was accepted (cumprod)
        match = (out[:, :-1] == tokens[:, 1:]) & (
            offs[:, : C - 1] < (length - 1)[:, None]
        )
        accept = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
        if two_pass:
            commit = jnp.where(length > 0, jnp.minimum(accept + 1, length), 0)
            _, new_caches = T.prefill_chunk(
                params, {"tokens": tokens}, cfg, caches, start, commit, lc,
                block_tables=block_tables,
            )
        return out, accept, bad, new_caches

    return verify_step


def make_cow_copy_step():
    """Copy one physical page's K/V rows (every layer, both pools) to a
    fresh page, on device — the copy-on-write half of prefix sharing: the
    shared rows of a partially-matched page are duplicated so the new
    request's divergent tokens never touch the donor page. Non-paged
    leaves (recurrent state) pass through untouched."""

    def cow_copy(caches, src, dst):
        """caches: full stacked tree; src/dst: () int32 physical pages."""
        flat = jax.tree_util.tree_flatten_with_path(caches)
        leaves = [
            leaf.at[:, dst].set(leaf[:, src]) if _is_paged_leaf(path)
            else leaf
            for path, leaf in flat[0]
        ]
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(caches), leaves
        )

    return cow_copy


def make_encoder_step(cfg, mesh):
    """Encoder-only archs have no decode; "prefill" = full forward."""
    lc = LogicalConstraints(mesh, SH.activation_rules(cfg, mesh))

    def encoder_step(params, batch):
        logits, _ = T.apply_logits(params, batch, cfg, lc)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return encoder_step


# ---------------------------------------------------------------------------
# simple continuous-batching scheduler (example/serving driver)
# ---------------------------------------------------------------------------


# Bounded: each entry pins a tuple of jitted fns with donated-buffer traces
# for the process lifetime, so an unbounded cache grows without limit when
# tests/benchmarks construct many scheduler configurations. The key is the
# FULL static tuple (``ServeConfig.step_statics()``) — every knob that
# changes which step functions exist or how they compute, including the
# speculation knobs, so two distinct configurations can never collide on
# one entry (a collision would hand a spec scheduler a triple with no
# verify step, or a prefix scheduler one with no CoW step). 32 entries
# cover every concurrent A/B pattern in the repo (paged/dense x sampling x
# prefix x spec x arch) without thrashing; an evicted entry merely
# recompiles on the next scheduler construction.
@functools.lru_cache(maxsize=32)
def _serve_step_fns(cfg, mesh, statics: _StepStatics):
    """Shared jitted (decode, prefill-chunk, cow-copy, spec-verify) tuple
    per (cfg, mesh, full serve statics): scheduler instances (restarts,
    A/B benchmark runs) reuse traces instead of paying a fresh compile
    each. ``cow`` is None unless the prefix cache is on; ``verify`` is
    None unless spec decoding is on (its trace depends on the arch —
    recurrent/hybrid patterns verify in two passes so state advances
    over exactly the accepted tokens)."""
    kw = dict(paged=statics.paged, greedy=statics.greedy,
              temperature=statics.temperature, top_k=statics.top_k)
    cow = (
        jax.jit(make_cow_copy_step(), donate_argnums=(0,))
        if statics.paged and statics.prefix_cache else None
    )
    verify = None
    if statics.spec_decode:
        two_pass = any(
            kind in ("mamba2", "mlstm", "slstm") for kind in cfg.pattern
        )
        verify = jax.jit(
            make_spec_verify_step(
                cfg, mesh, greedy=statics.greedy,
                temperature=statics.temperature, top_k=statics.top_k,
                two_pass=two_pass,
            ),
            donate_argnums=(4,),
        )
    return (
        jax.jit(make_serve_decode_step(cfg, mesh, **kw), donate_argnums=(4,)),
        jax.jit(make_prefill_chunk_step(cfg, mesh, **kw), donate_argnums=(5,)),
        cow,
        verify,
    )


class PageAllocator:
    """Refcounted free-list allocator over the shared KV page pool.

    Pages are plain integers into the pool's page axis; the scheduler owns
    the per-slot block tables. ``alloc`` raises a clean error on exhaustion
    *before* any index is handed out — a full pool can never silently remap
    a neighbor's pages. With cross-request prefix sharing a physical page
    may back multiple block-table rows (plus the prefix trie's own pin):
    ``alloc`` hands pages out at refcount 1, ``share`` bumps the count, and
    ``release`` decrements it, returning a page to the free list only when
    its count drops to zero — retiring a request can never free a page a
    neighbor (or the trie) still reads."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, -1, -1))  # pop() -> page 0 first
        self.refs: dict[int, int] = {}  # allocated page -> reference count
        self.peak_used = 0

    @property
    def used(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def shared_pages(self) -> int:
        """Pages currently held by more than one reference."""
        return sum(1 for c in self.refs.values() if c > 1)

    def alloc(self, n: int, *, owner=None) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"paged KV pool exhausted: request {owner!r} needs {n} more "
                f"page(s) but only {len(self._free)} of {self.num_pages} are "
                f"free; raise ServeConfig.num_pages (--num-pages) or retire "
                f"requests sooner"
            )
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self.refs[p] = 1
        self.peak_used = max(self.peak_used, self.used)
        return pages

    def share(self, pages: list[int]) -> None:
        """Add one reference to each (already allocated) page."""
        for p in pages:
            self.refs[p] += 1

    def release(self, pages: list[int]) -> None:
        for p in pages:
            c = self.refs[p] = self.refs[p] - 1
            if c == 0:
                del self.refs[p]
                self._free.append(p)


class _TrieNode:
    __slots__ = ("tokens", "page", "children", "parent", "last_used",
                 "checksum")

    def __init__(self, tokens, page, parent):
        self.tokens = tokens      # the page_size-token tuple keying this node
        self.page = page          # physical pool page holding their K/V
        self.children: dict[tuple, _TrieNode] = {}
        self.parent = parent
        self.last_used = 0
        self.checksum = None      # uint32 page fingerprint (checksum_pages)


class PrefixCache:
    """Radix trie over prompt-token pages for cross-request KV sharing.

    Nodes are keyed by the exact ``page_size``-token tuple they cover —
    Python's tuple hashing IS the page hash, with exact compare, so a hash
    collision can never alias two different prefixes — and a root-to-node
    path spells out a prompt prefix in whole pages, mapped to resident
    pool pages. The trie holds its OWN reference on every inserted page
    (``PageAllocator.share``), so cached pages survive their inserting
    request's retirement; they are reclaimed by LRU eviction under pool
    pressure (``evict_for`` — only leaves whose page has no reader besides
    the trie, since evicting a still-shared page frees nothing) or by LRU
    trim when ``capacity`` (max pinned pages) would be exceeded on insert.
    """

    def __init__(self, page_size: int, allocator: PageAllocator,
                 capacity: int | None = None):
        self.page_size = page_size
        self.allocator = allocator
        self.capacity = capacity
        self.root = _TrieNode((), -1, None)
        self.size = 0       # nodes == pages currently pinned
        self._clock = 0     # monotonic LRU clock
        self.stats = {
            "hits": 0, "misses": 0, "hit_tokens": 0,
            "prefill_tokens_skipped": 0, "pages_shared": 0, "cow_copies": 0,
            "inserted_pages": 0, "evicted_pages": 0,
        }

    def _touch(self, node: _TrieNode) -> None:
        self._clock += 1
        node.last_used = self._clock

    def match(self, prompt):
        """Longest cached prefix of ``prompt``: the chain of fully-matched
        page nodes, plus the best partially-matching child of the last one
        (the copy-on-write donor) with its matching row count."""
        psize = self.page_size
        node, chain, i = self.root, [], 0
        while len(prompt) - i >= psize:
            child = node.children.get(tuple(int(t) for t in prompt[i:i + psize]))
            if child is None:
                break
            chain.append(child)
            node, i = child, i + psize
        tail = tuple(int(t) for t in prompt[i:i + psize])
        donor, donor_rows = None, 0
        for key, child in node.children.items():
            n = 0
            for a, b in zip(key, tail):
                if a != b:
                    break
                n += 1
            if n > donor_rows:
                donor, donor_rows = child, n
        return chain, donor, donor_rows

    def insert(self, prompt, pages, checksums=None) -> None:
        """Record a prefilled prompt's full pages (called when a request's
        prefill completes). Existing nodes are LRU-touched; new nodes pin
        their page with a trie-owned reference. Pages straddling the
        prompt/generated boundary are never inserted — decode will write
        over their tails. ``checksums`` (one uint fingerprint per full
        page, when ``ServeConfig.checksum_pages`` is on) are stored on
        the nodes and validated before any future attach maps them."""
        psize = self.page_size
        node = self.root
        for j in range(len(prompt) // psize):
            key = tuple(int(t) for t in prompt[j * psize:(j + 1) * psize])
            child = node.children.get(key)
            if child is None:
                if self.capacity is not None and self.size >= self.capacity:
                    # at capacity: trim the LRU leaf off some OTHER path;
                    # if the whole trie is this insertion, stop growing
                    if not self._evict_lru(exclude=self._path_ids(node)):
                        return
                child = _TrieNode(key, pages[j], node)
                node.children[key] = child
                self.allocator.share([pages[j]])
                self.size += 1
                self.stats["inserted_pages"] += 1
            if checksums is not None:
                child.checksum = checksums[j]
            self._touch(child)
            node = child

    # -- eviction --------------------------------------------------------

    def _path_ids(self, node: _TrieNode) -> set:
        out = set()
        while node is not None:
            out.add(id(node))
            node = node.parent
        return out

    def _leaves(self) -> list[_TrieNode]:
        out, stack = [], list(self.root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def _evict(self, node: _TrieNode) -> None:
        del node.parent.children[node.tokens]
        self.allocator.release([node.page])
        self.size -= 1
        self.stats["evicted_pages"] += 1

    def _evict_lru(self, *, exclude=frozenset(),
                   only_unreferenced: bool = False) -> bool:
        """Evict the least-recently-used leaf; True if one was evicted."""
        cand = [
            n for n in self._leaves()
            if id(n) not in exclude
            and (not only_unreferenced
                 or self.allocator.refs.get(n.page, 0) == 1)
        ]
        if not cand:
            return False
        self._evict(min(cand, key=lambda n: n.last_used))
        return True

    def evict_subtree(self, node: _TrieNode) -> int:
        """Evict ``node`` and every descendant (post-order): a checksum
        mismatch means the page's content can no longer be trusted, and
        the descendants' pages are unreachable without it. Returns the
        number of nodes evicted."""
        count = 0
        stack, order = [node], []
        while stack:
            n = stack.pop()
            order.append(n)
            stack.extend(n.children.values())
        for n in reversed(order):  # children before parents
            self._evict(n)
            count += 1
        return count

    def evict_for(self, n_pages: int) -> int:
        """Pool pressure: free >= ``n_pages`` by evicting LRU leaves whose
        page has no reader besides the trie. Inner nodes become evictable
        as their children go. Returns the number of pages actually freed
        (may fall short — the caller's alloc then raises cleanly)."""
        freed = 0
        while freed < n_pages:
            before = self.allocator.free_pages
            if not self._evict_lru(only_unreferenced=True):
                break
            freed += self.allocator.free_pages - before
        return freed

    def reclaimable(self) -> int:
        """Pages the trie could free under pressure: nodes whose page has no
        reader besides the trie itself (inner nodes become evictable as
        their children go, so every refcount-1 node counts)."""
        count = 0
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if self.allocator.refs.get(n.page, 0) == 1:
                count += 1
        return count

    def clear(self) -> None:
        """Drop every cached page (teardown / tests)."""
        while self._evict_lru():
            pass


class _PoolPressure(Exception):
    """Internal: an allocation could not be satisfied even after trie
    eviction and victim preemption. ``fatal=False`` means the *requester*
    should be parked (pressure will drop when older/higher-priority work
    retires); ``fatal=True`` means no amount of waiting can help (policy
    "never", or the requester is the only page holder left) — the caller
    unwinds its partial allocation and re-raises as RuntimeError."""

    def __init__(self, fatal: bool, msg: str):
        super().__init__(msg)
        self.fatal = fatal
        self.msg = msg


def _request_tag(request_id) -> int:
    """Stable 31-bit tag for a request id, independent of submission order
    and slot placement — the sampling key seed. Integer ids map to
    themselves; anything else hashes via crc32 (Python's ``hash`` is
    process-seeded for strings, which would break cross-run determinism)."""
    if isinstance(request_id, (int, np.integer)):
        return int(request_id) & 0x7FFFFFFF
    return zlib.crc32(repr(request_id).encode()) & 0x7FFFFFFF


# terminal request statuses: the stream is closed, no more tokens can come
_TERMINAL = ("done", "cancelled", "failed", "shed")


class RequestHandle:
    """Caller-facing view of a submitted request — the async half of the
    admission API. ``submit()`` returns one immediately (arrival time is
    decoupled from slot attach); the handle observes the request's
    lifecycle (``queued -> prefilling -> decoding -> done``, with
    ``preempted``/``retrying`` parking and ``cancelled``/``failed``/
    ``shed`` exits), exposes the tokens generated so far, and can cancel
    mid-stream. ``failed`` is the quarantine exit: the request exhausted
    ``ServeConfig.max_retries`` fault recoveries and was detached with
    its pages freed — co-residents never see it. ``shed`` is the
    load-shedding exit: the admission queue was over
    ``shed_queue_depth`` and this was the lowest-priority youngest
    waiter."""

    __slots__ = ("_sched", "_req")

    def __init__(self, sched: "BatchScheduler", req: dict):
        self._sched = sched
        self._req = req

    @property
    def request_id(self):
        return self._req["id"]

    @property
    def status(self) -> str:
        return self._req["_status"]

    @property
    def tokens(self) -> list[int]:
        """Tokens generated (and flushed to the host) so far."""
        return list(self._req["generated"])

    @property
    def done(self) -> bool:
        return self._req["_status"] in _TERMINAL

    def cancel(self) -> bool:
        return self._sched.cancel(self._req["id"])

    def stream(self, *, timeout: int | None = None):
        """Synchronous token stream (drives the scheduler); see
        ``BatchScheduler.stream``. ``timeout`` bounds the scheduler ticks
        spent waiting for the next token — a stalled scheduler raises
        ``TimeoutError`` instead of spinning forever."""
        return self._sched.stream(self._req["id"], timeout=timeout)

    def result(self, *, timeout: int | None = None) -> list[int]:
        """Drive the scheduler until this request finishes; returns its
        tokens. ``timeout`` (scheduler ticks between tokens) raises
        ``TimeoutError`` on a stall."""
        for _ in self.stream(timeout=timeout):
            pass
        return self.tokens


class BatchScheduler:
    """Slot-based continuous batching with genuine chunked prefill-on-attach
    overlapped with in-flight decode.

    Every slot carries its own position (``pos`` is a (B,) vector): a request
    attached mid-flight decodes at *its* sequence position, not the batch's.
    Attaching runs a real prefill — the prompt is written into the slot's KV
    cache in fixed ``prefill_chunk``-token chunks, ONE chunk dispatched per
    tick *after* that tick's decode dispatch, so the decode pipeline never
    waits on a prefill (``overlap=True``; ``overlap=False`` prefills the
    whole prompt synchronously on attach — the stop-the-world baseline).
    Decode and prefill commute on the cache: inactive/prefilling slots are
    masked out of the decode step's cache writes and recurrent-state
    advance, and a prefill chunk only touches its own slot's cache lines —
    so the generated tokens are bitwise identical with overlap on or off.
    Reattaching a freed slot restores its recurrent-state carries to their
    initial values (stale attention KV is already masked by the visible
    window), so a reused slot behaves exactly like a fresh one.

    Attention KV lives in a **paged cache** by default (``scfg.paged``): a
    shared pool of ``page_size``-token pages plus a per-slot block table.
    Pages are allocated exactly as prefill chunks / decode steps write them
    and freed when the request retires, so KV HBM scales with *live tokens*
    instead of ``batch x max_len``; decode attention gathers K/V through
    the table (``kernels.paged_attention`` — Pallas on TPU, a gather oracle
    elsewhere that is bitwise identical to the dense layout). Exhausting
    the pool raises a clean error before any page is handed out —
    neighbors' pages are never remapped. ``paged=False`` keeps the dense
    layout; generated tokens are bitwise identical either way.

    With ``scfg.prefix_cache`` (opt-in, paged only) a **cross-request
    prefix cache** rides on the pool: completed prefills insert their
    prompts' full pages into a radix trie keyed on page-token tuples, and
    attach walks the trie, maps every fully-matched resident page into the
    new request's block table read-only (refcount bump), skips that part
    of chunked prefill, and copy-on-writes the first partially-shared page
    (fresh page, donor rows copied on device, divergent tokens prefilled
    over the tail). Retire releases references, never pages a neighbor or
    the trie still holds; under pool pressure the trie evicts its LRU
    entries that no live request reads. Generated tokens stay identical
    with sharing on or off — a shared page holds exactly the K/V the
    request would have prefilled itself.

    **Admission and preemption** (the serving-under-pressure layer):
    ``submit`` returns a ``RequestHandle`` immediately — arrival is
    decoupled from slot attach by a priority admission queue (highest
    priority first, FIFO within a class), and a strictly-higher-priority
    arrival may preempt the lowest-priority occupant when every slot is
    busy. When the page pool exhausts, a victim is chosen by
    ``scfg.preempt_policy`` among requests *younger or lower-priority*
    than the one asking (so preemption can never ping-pong), its pages are
    released, and it is **parked for recompute-resume**: on re-attach the
    prompt re-prefills through the normal chunked path (identical chunk
    grid — and the PrefixCache fast-forward when enabled — writes bitwise
    identical K/V), and the tokens it had already generated are *replayed*
    through ordinary decode dispatches at their original positions (inputs
    forced, outputs discarded) so attention KV and recurrent state are
    recomputed by exactly the ops the uninterrupted run executed. A
    resumed stream is therefore **bitwise identical** to an ample-pool
    run, greedy or sampled (``benchmarks/run.py --check`` forces a
    preemption and asserts it). Recurrent/hybrid archs follow the PR 6
    ``done=0`` rule: resume re-runs state over every prompt token. If no
    victim is eligible the requester parks itself; only a request that
    could never fit even alone fails — with its partial allocation fully
    released first (nothing leaks). ``cancel`` frees a request's pages
    mid-stream without touching co-resident slots; ``stream`` /
    ``stream_async`` yield tokens as they flush.

    Sampling: greedy argmax by default (bitwise-stable). With
    ``greedy=False``, temperature/top-k sampling runs inside the decode and
    prefill-chunk steps from per-request base PRNG keys carried on device
    (``fold_in(PRNGKey(sample_seed), request_tag)``, written into the
    slot's key row at attach), folded with the sampled position each step
    (stateless — nothing to reset on slot reuse) — a request's stream
    depends only on (params, prompt, request_id, sample_seed), never on
    the slot it lands in, co-resident traffic, or a preemption/resume
    cycle in the middle of it.

    Token readback is **deferred and batched**: decode steps and prefill
    completions append on-device token arrays to a pending list, and one
    ``jax.device_get`` of the whole pending batch runs when a request is
    about to complete its ``max_new`` budget, every ``eos_check_every``
    ticks when ``eos_id`` is set (EOS needs token values), or on
    ``drain()``. Retirement is budget-based AND EOS-based (generated tokens
    past an EOS are dropped at flush time).

    Monitoring goes through ``repro.session``: pass a ``PerfSession`` and
    every decode dispatch is a visit of its ``decode`` region and every
    prefill chunk a visit of its ``prefill`` region, each with its own
    derived StepProfile — the report shows prefill and decode factor
    regressions separately. With no session (or a null backend) the
    scheduler runs fully uninstrumented at zero cost.

    Resilience: the scheduler is **self-healing** under injected or real
    faults. Detection is layered — an on-device NaN/Inf sentinel rides
    every decode/prefill readback (one (B,) bool per flush), optional
    per-page checksums (``checksum_pages``) validate shared pages at
    prefix attach, and an optional per-dispatch watchdog
    (``watchdog_deadline_s``) catches wedged dispatch paths. Recovery is
    unified: a faulted request RETRIES through the park/recompute-resume
    path with capped exponential backoff (its stream stays bitwise
    identical to an unfaulted run), exhausting ``max_retries``
    QUARANTINES it (terminal "failed", pages freed and scrubbed,
    co-residents untouched), and ``shed_queue_depth`` sheds the
    lowest-priority waiter at admission under sustained pressure.
    Every recovery action is a visit of the session's ``recovery``
    region and is counted in ``kv_cache_stats()["recovery"]``. A seeded
    ``repro.serve.faults.FaultInjector`` (``fault_injector=``) drives
    chaos schedules through these exact paths.
    """

    def __init__(self, cfg, mesh, scfg: ServeConfig, params, session=None,
                 fault_injector=None):
        from repro.session import PerfSession, SessionConfig

        self.cfg, self.mesh, self.scfg = cfg, mesh, scfg
        self.params = params
        # chunked recurrences re-chunk internally at ssm/xlstm chunk: a
        # prefill chunk larger than that must tile it exactly
        for inner in (cfg.ssm.chunk if cfg.ssm else None,
                      cfg.xlstm.chunk if cfg.xlstm else None):
            if inner and scfg.prefill_chunk > inner and scfg.prefill_chunk % inner:
                raise ValueError(
                    f"prefill_chunk={scfg.prefill_chunk} must be <= the "
                    f"recurrent chunk {inner} or a multiple of it"
                )
        if scfg.paged and scfg.max_len % scfg.page_size:
            raise ValueError(
                f"paged serving needs max_len ({scfg.max_len}) divisible by "
                f"page_size ({scfg.page_size}) so the paged and dense layouts "
                f"stay bitwise interchangeable"
            )
        # default: off, but env-activatable (TALP_ENABLE=1) like every other
        # entry point; the caller owns finalize() (also via self.session)
        self.session = session if session is not None else PerfSession(
            SessionConfig(app_name="serve", backend="null")
        )
        if scfg.spec_decode:
            # the verify chunk is spec_k draft tokens + the committed input
            # token, scored in one multi-token dispatch — it tiles the
            # recurrent inner chunk under the same rule as prefill chunks
            for inner in (cfg.ssm.chunk if cfg.ssm else None,
                          cfg.xlstm.chunk if cfg.xlstm else None):
                verify_c = scfg.spec_k + 1
                if inner and verify_c > inner and verify_c % inner:
                    raise ValueError(
                        f"spec_k+1={verify_c} (the verify chunk) must be <= "
                        f"the recurrent chunk {inner} or a multiple of it"
                    )
        decode_fn, prefill_fn, self._cow_copy, verify_fn = _serve_step_fns(
            cfg, mesh, scfg.step_statics(),
        )
        self.decode = self.session.wrap_step(
            decode_fn,
            region="decode",
            derive=True,
            num_devices=mesh.devices.size,
            # observe the sampled tokens only: blocking on the donated cache
            # tuple would serialize the decode pipeline
            observe=lambda out: {"outputs": out[0]},
        )
        self.prefill = self.session.wrap_step(
            prefill_fn,
            region="prefill",
            derive=True,
            num_devices=mesh.devices.size,
            observe=lambda out: {"outputs": out[0]},
        )
        # batched speculative verify shares the decode session region: a
        # spec tick IS the decode tick, just K+1 tokens wide
        self.verify = None
        if verify_fn is not None:
            self.verify = self.session.wrap_step(
                verify_fn,
                region="decode",
                derive=True,
                num_devices=mesh.devices.size,
                observe=lambda out: {"outputs": out[0]},
            )
        # paged KV: shared pool + per-slot block tables + free-list
        # allocator. Tables are host-authored (numpy, -1 = unallocated) and
        # mirrored to device lazily — one small upload per tick at most,
        # only when an allocation or a free actually changed them.
        if scfg.paged:
            self._max_pages = scfg.max_len // scfg.page_size
            n_pages = scfg.num_pages
            if n_pages is None:
                n_pages = scfg.batch * self._max_pages
            self._alloc: PageAllocator | None = PageAllocator(n_pages)
            self._tables = np.full((scfg.batch, self._max_pages), -1, np.int32)
            self._slot_pages: list[list[int]] = [[] for _ in range(scfg.batch)]
            self._tables_dirty = True
            self._tables_dev = None
            self.caches = T.init_cache(
                cfg, scfg.batch, scfg.max_len, paged=True,
                page_size=scfg.page_size, num_pages=n_pages,
            )
            self._prefix: PrefixCache | None = (
                PrefixCache(scfg.page_size, self._alloc,
                            capacity=scfg.prefix_trie_capacity)
                if scfg.prefix_cache else None
            )
        else:
            self._alloc = None
            self._prefix = None
            self.caches = T.init_cache(cfg, scfg.batch, scfg.max_len)
        # per-slot sampling key rows, carried on device. In sampled mode the
        # attach overwrites the slot's row with the REQUEST's key
        # (fold_in(base_key, request_tag)), and each sampling step folds that
        # with the sampled position — so a request's stream is a pure
        # function of (params, prompt, request_id, sample_seed), independent
        # of slot placement (a preempted request may resume elsewhere),
        # co-resident traffic, and the overlap schedule (greedy never reads
        # the keys)
        self._base_key = jax.random.PRNGKey(scfg.sample_seed)
        self.rng_keys = jax.random.split(self._base_key, scfg.batch)
        # fresh-state template for slot reuse: unlike attention KV (stale
        # lines are masked by cache_len/kv_len), recurrent state has no
        # positional masking, so a reattached slot must have its carries
        # restored to their INITIAL values — which are not all zero (sLSTM's
        # stabilizer m starts at -1e30). One batch-1 leaf per recurrent
        # cache entry, broadcast into the reused slots' rows at attach.
        self._fresh_state = [
            None if "attn" in _cache_path_name(path) else leaf
            for path, leaf in jax.tree_util.tree_flatten_with_path(
                T.init_cache(cfg, 1, 1)
            )[0]
        ]
        self._has_recurrent = any(l is not None for l in self._fresh_state)
        self._dirty: set[int] = set()  # slots whose state may be non-fresh
        self.tokens = jnp.zeros((scfg.batch, 1), jnp.int32)
        self.queue: list[dict] = []    # admission queue: priority, FIFO within
        self.active: list[dict | None] = [None] * scfg.batch   # decoding slots
        self.pos = np.zeros(scfg.batch, np.int32)              # per-slot position
        self.completed: list[dict] = []
        self.cancelled: list[dict] = []   # cancelled mid-stream
        self.failed: list[dict] = []      # fatal pool pressure (unwound clean)
        self._parked: list[dict] = []     # preempted, awaiting recompute-resume
        self._by_id: dict = {}            # request_id -> req (handles, cancel)
        self._seq = 0                     # admission order: FIFO within a class
        # recompute-resume replay: slot -> generated tokens still to re-feed
        # through decode at their original positions (outputs discarded)
        self._replay: dict[int, list[int]] = {}
        # in-flight prefills: FIFO of {"req","slot","prompt","done"}
        self._prefills: list[dict] = []
        self._prefilling: list[dict | None] = [None] * scfg.batch
        # next-token seeds {slot: device scalar} applied in ONE scatter/tick;
        # keyed by slot so a retired request's still-queued seed can never
        # race the reattached request's seed in the scatter
        self._seeds: dict[int, Any] = {}
        # pending readbacks: (device tokens (n,1), device bad-sentinel (n,),
        # row->request map); flushed in a single device_get
        self._pending: list[tuple[Any, Any, list[dict | None]]] = []
        # speculative decode: per-slot next input token, host-side. Spec
        # ticks trade the deferred-readback pipeline for width — the accept
        # count must reach the host before the next tick can plan drafts,
        # so each verify dispatch reads back immediately (a few scalars)
        # and amortizes the sync over up to spec_k+1 tokens.
        self._last_tok = np.zeros(scfg.batch, np.int32)
        # -- fault injection + recovery state --------------------------
        # ``faults`` is a repro.serve.faults.FaultInjector (or None); the
        # scheduler polls it once per tick and applies due events through
        # the same paths real faults would corrupt
        self.faults = fault_injector
        self.shed: list[dict] = []        # load-shed at admission
        self._fault_nan_slots: set[int] = set()   # poison next decode dispatch
        # request ids with a poisoned row dispatched but not yet flushed:
        # ineligible for further nan/hang targeting (a second poison in
        # that window would be swallowed by the retry already in flight)
        self._fault_nan_inflight: set = set()
        self._fault_mask_zero = jnp.zeros((scfg.batch,), bool)
        # transient allocator spikes: (release_tick, pages) — released in
        # _apply_faults even after the injector drains, and force-released
        # by drain() so chaos runs can never leak pool pages
        self._spike_holds: list[tuple[int, list[int]]] = []
        self._hang_pending: float = 0.0   # injected dispatch delay (s)
        self._hang_slot: int | None = None
        # physical pages the injector corrupted: scrubbed (zeroed on
        # device) when their holder retries/quarantines, so a recycled
        # page can never leak NaNs into its next owner's masked tail
        self._corrupted_pages: set[int] = set()
        self._page_edit = None            # lazily-built jitted page edits
        self._fingerprint = None
        if scfg.checksum_pages:
            from repro.serve import faults as _F
            self._fingerprint = _F.page_fingerprint_step()
        self.stats = {
            "ticks": 0, "decode_steps": 0, "prefill_chunks": 0,
            "readbacks": 0,
            # overlap accounting: "overlap_ticks" counts ticks where a
            # prefill was in flight alongside >=1 decoding slot (proof the
            # two phases actually co-existed); "decode_after_prefill_ticks"
            # counts ticks whose decode dispatch only happened AFTER prefill
            # work ran in the same tick — i.e. the decode pipeline waited on
            # a prefill. The overlap guarantee benchmarks/serve_throughput.py
            # asserts is overlap_ticks > 0 and decode_after_prefill_ticks
            # == 0; the stop-the-world baseline trips the latter.
            "overlap_ticks": 0, "decode_after_prefill_ticks": 0,
            # pressure accounting (the serving-under-load counters surfaced
            # by kv_cache_stats()["pressure"] and launch/serve.py)
            "preemptions": 0, "resumes": 0, "cancellations": 0,
            "pages_freed_by_preempt": 0, "evictions_for_preempt": 0,
            "peak_queue_depth": 0,
            # recovery accounting (kv_cache_stats()["recovery"]): what the
            # self-healing layer actually did — retries taken, backoff
            # ticks served, quarantines, load-sheds, checksum mismatches
            # caught at prefix attach, watchdog trips
            "retries": 0, "backoff_total_ticks": 0, "quarantined": 0,
            "shed": 0, "checksum_failures": 0, "watchdog_trips": 0,
            # speculation accounting (kv_cache_stats()["speculation"]):
            # drafted/accepted/rejected count DRAFT tokens only (the
            # committed input token of each verify chunk is not a draft);
            # spec_emitted counts newly-emitted tokens (excludes resume/
            # retry replay tokens re-verified through the same dispatches)
            "spec_dispatches": 0, "spec_drafted": 0, "spec_accepted": 0,
            "spec_rejected": 0, "spec_emitted": 0,
        }

    def submit(self, prompt_tokens, request_id, max_new: int = 32,
               priority: int = 0) -> RequestHandle:
        """Admit a request; returns a ``RequestHandle`` immediately (arrival
        is decoupled from slot attach by the admission queue). ``priority``
        orders admission — higher first, FIFO within a class — and bounds
        preemption: a request can only ever evict strictly-lower-priority
        or strictly-younger work."""
        prompt = list(prompt_tokens)
        if max_new < 1:
            # the first generated token falls out of the prefill logits
            # unconditionally, so a zero budget is unsatisfiable
            raise ValueError(f"request {request_id!r}: max_new must be >= 1")
        # cache writes past max_len would be silently dropped by the masked
        # scatter (mode="drop") — garbage tokens with no error — so reject
        # oversized requests at the door. The last decode writes position
        # prompt_len + max_new - 2 (the final sampled token is never fed
        # back), hence the -1 slack; an empty prompt gets no prefill token,
        # so all max_new tokens come from decode writes at 0..max_new-1.
        need = len(prompt) + max(max_new - 1, 0) if prompt else max_new
        if need > self.scfg.max_len:
            raise ValueError(
                f"request {request_id!r} needs {need} cache positions "
                f"(prompt {len(prompt)}, max_new {max_new}) but "
                f"max_len={self.scfg.max_len}"
            )
        if self._alloc is not None:
            # a request that cannot fit even with the pool to itself would
            # otherwise park forever under the preemption policy (and the
            # admission queue hides the old immediate RuntimeError) — reject
            # it at the door like the max_len check above
            pages = -(-need // self.scfg.page_size)
            if pages > self._alloc.num_pages:
                raise ValueError(
                    f"request {request_id!r} needs {pages} page(s) "
                    f"(prompt {len(prompt)}, max_new {max_new}, page_size "
                    f"{self.scfg.page_size}) but the pool only holds "
                    f"{self._alloc.num_pages}; raise ServeConfig.num_pages "
                    f"(--num-pages)"
                )
        req = {
            "id": request_id, "prompt": prompt, "max_new": max_new,
            "generated": [], "_pending": 0, "priority": int(priority),
            "_seq": self._seq, "_tag": _request_tag(request_id),
            "_status": "queued", "_cancelled": False,
            "_retries": 0, "_not_before": 0,
        }
        self._seq += 1
        self.queue.append(req)
        self._by_id[request_id] = req
        if (self.scfg.shed_queue_depth is not None
                and len(self.queue) > self.scfg.shed_queue_depth):
            # sustained pressure: shed the lowest-priority youngest waiter
            # (possibly this arrival) with a clear terminal status rather
            # than queueing without bound — the shed handle reports "shed"
            # immediately, it never raises
            victim = min(self.queue, key=lambda r: (r["priority"], -r["_seq"]))
            self.queue.remove(victim)
            victim["_status"] = "shed"
            self.shed.append(victim)
            self.stats["shed"] += 1
            self.session.event("recovery")
        self.stats["peak_queue_depth"] = max(
            self.stats["peak_queue_depth"],
            len(self.queue) + len(self._parked),
        )
        return RequestHandle(self, req)

    def cancel(self, request_id) -> bool:
        """Cancel mid-stream: remove the request from whichever pool holds
        it (admission queue, parked set, in-flight prefill, or a decoding
        slot), release its pages, and close its stream. Prefix-trie pins
        and co-resident slots are untouched — their token streams are
        bitwise unaffected. Tokens already flushed stay on the handle;
        dispatched-but-unflushed rows are dropped at the next flush.
        Returns True if the request was still live."""
        req = self._by_id.get(request_id)
        if req is None or req["_status"] in _TERMINAL:
            return False
        req["_cancelled"] = True
        if req in self.queue:
            self.queue.remove(req)
        elif req in self._parked:
            self._parked.remove(req)
        else:
            slot = self._slot_of(req)
            if slot is not None:
                self._detach(slot)
        req["_status"] = "cancelled"
        self.cancelled.append(req)
        self.stats["cancellations"] += 1
        return True

    def flush(self) -> None:
        """Materialize pending tokens now (streaming callers; batch callers
        can keep relying on the automatic flush boundaries)."""
        self._flush()

    def stream(self, request_id, *, timeout: int | None = None):
        """Generator of ``request_id``'s tokens, driving the scheduler:
        each iteration steps and flushes until new tokens land. Ends when
        the request completes (or is cancelled / fails / is shed).
        Co-resident requests advance as a side effect, exactly as in a
        plain step loop — several interleaved ``stream`` consumers are
        fine. ``timeout`` bounds the scheduler ticks spent waiting
        BETWEEN tokens: when the request makes no progress for that many
        ticks (a stalled scheduler, a wedged dispatch with the watchdog
        off), ``TimeoutError`` is raised instead of spinning forever."""
        req = self._by_id.get(request_id)
        if req is None:
            raise KeyError(f"unknown request {request_id!r}")
        limit = timeout if timeout is not None else 100_000
        sent, idle = 0, 0
        while True:
            while sent < len(req["generated"]):
                idle = 0
                yield req["generated"][sent]
                sent += 1
            if req["_status"] in _TERMINAL:
                return
            if idle >= limit:
                if timeout is not None:
                    raise TimeoutError(
                        f"request {request_id!r} made no progress in "
                        f"{idle} scheduler ticks (status "
                        f"{req['_status']!r})"
                    )
                # insurance against a scheduling livelock
                raise RuntimeError(
                    f"request {request_id!r} stalled in stream() "
                    f"(status {req['_status']!r})"
                )
            self.step()
            self._flush()
            idle += 1

    async def stream_async(self, request_id):
        """Async variant of ``stream``: yields control to the event loop
        between ticks, so several ``stream_async`` consumers (one per
        request) interleave over one scheduler — whichever consumer runs
        next drives the shared tick, and every slot advances."""
        import asyncio

        req = self._by_id.get(request_id)
        if req is None:
            raise KeyError(f"unknown request {request_id!r}")
        sent = 0
        while True:
            while sent < len(req["generated"]):
                yield req["generated"][sent]
                sent += 1
            if req["_status"] in _TERMINAL:
                return
            self.step()
            self._flush()
            await asyncio.sleep(0)

    # -- attach / prefill ------------------------------------------------

    def _free(self, slot: int) -> bool:
        return self.active[slot] is None and self._prefilling[slot] is None

    def _attach(self) -> None:
        if self.scfg.preempt_policy != "never":
            self._preempt_for_priority()
        if not (self.queue or self._parked):
            return
        order = lambda r: (-r["priority"], r["_seq"])
        self.queue.sort(key=order)    # stable: FIFO within a priority class
        self._parked.sort(key=order)
        reused: list[int] = []
        for slot in range(self.scfg.batch):
            if not self._free(slot):
                continue
            req = self._next_admittable()
            if req is None:
                break
            if not self._attach_one(slot, req, reused):
                break  # attach-time pool pressure: try again next tick
        if reused:
            self._reset_slots(reused)

    def _next_admittable(self) -> dict | None:
        """Best waiter across the admission queue and the parked set, on
        (priority desc, arrival seq asc) — a parked request keeps its
        original seq, so at equal priority it naturally outranks younger
        queued arrivals. Parked candidates must also pass the resume gate
        (enough free or trie-reclaimable pages for prompt + history), so a
        resume cannot immediately thrash back out — and the retry
        backoff gate (``_ready``), so a retrying request serves its
        backoff before it may re-attach."""
        order = lambda r: (-r["priority"], r["_seq"])
        parked = next(
            (r for r in self._parked
             if self._ready(r) and self._resume_fits(r)),
            None,
        )
        queued = self.queue[0] if self.queue else None
        if parked is not None and (
            queued is None or order(parked) <= order(queued)
        ):
            self._parked.remove(parked)
            return parked
        if queued is not None:
            return self.queue.pop(0)
        return None

    def _ready(self, req: dict) -> bool:
        """Retry backoff gate: a retrying request stays parked until its
        ``_not_before`` tick passes (capped exponential backoff set by
        ``_fault_retry``)."""
        return req["_not_before"] <= self.stats["ticks"]

    def _resume_fits(self, req) -> bool:
        if self._alloc is None:
            return True
        need = len(req["prompt"]) + max(len(req["generated"]), 1)
        need = -(-need // self.scfg.page_size)
        avail = self._alloc.free_pages
        if self._prefix is not None:
            avail += self._prefix.reclaimable()
        return avail >= need

    def _attach_one(self, slot: int, req: dict, reused: list[int]) -> bool:
        """Attach ``req`` to the free ``slot``; False on attach-time pool
        pressure (the request is put back where it came from, fully
        unwound). A request with generated history is a recompute-resume:
        the prompt re-prefills on the normal chunk grid and the history is
        scheduled for decode replay. A retrying request (fault recovery)
        rides the identical path: the clean history replays, the faulted
        suffix recomputes — which is why a retried stream is bitwise
        identical to an unfaulted run."""
        resume = req["_status"] in ("preempted", "retrying")
        self.pos[slot] = 0
        if slot in self._dirty:
            reused.append(slot)
        self._dirty.add(slot)
        if not self.scfg.greedy:
            # the slot's sampling key row becomes the REQUEST's key, so a
            # resumed request keeps its exact stream in any slot
            self.rng_keys = self.rng_keys.at[slot].set(
                jax.random.fold_in(self._base_key, req["_tag"])
            )
        if not req["prompt"]:
            # nothing to prefill: decode from an empty cache off a constant
            # BOS-like seed; on resume, replay the WHOLE history (the seed
            # token regenerates generated[0], which is discarded)
            if self.scfg.spec_decode:
                self._last_tok[slot] = 0
            else:
                self._seeds[slot] = 0
            if req["generated"]:
                self._replay[slot] = list(req["generated"])
            self.active[slot] = req
            req["_status"] = "decoding"
            if resume:
                self.stats["resumes"] += 1
            return True
        # drop any stale seed a just-retired request left queued
        self._seeds.pop(slot, None)
        task = {"req": req, "slot": slot, "done": 0,
                "prompt": np.asarray(req["prompt"], np.int32)}
        if self._prefix is not None:
            try:
                task["done"] = self._attach_prefix(slot, req)
            except _PoolPressure as e:
                # unwind the partial page mapping — a failed attach leaks
                # nothing — and put the request back
                self._release_slot_pages(slot)
                if e.fatal:
                    req["_status"] = "failed"
                    self.failed.append(req)
                    raise RuntimeError(
                        f"{e.msg} [kv_cache_stats: {self.kv_cache_stats()}]"
                    ) from None
                if resume:
                    self._parked.append(req)
                else:
                    req["_status"] = "queued"
                    self.queue.append(req)
                return False
        req["_status"] = "prefilling"
        if resume:
            self.stats["resumes"] += 1
        self._prefilling[slot] = task
        self._prefills.append(task)
        return True

    # -- preemption (serving under memory pressure) ----------------------

    def _occupant(self, slot: int) -> dict | None:
        task = self._prefilling[slot]
        return self.active[slot] or (task["req"] if task else None)

    def _slot_of(self, req: dict) -> int | None:
        for slot in range(self.scfg.batch):
            if self._occupant(slot) is req:
                return slot
        return None

    def _detach(self, slot: int) -> None:
        """Pull whatever occupies ``slot`` off the batch: drop its
        in-flight prefill task, clear the slot, release its pages and its
        per-slot decode state. The request dict itself is untouched —
        callers decide where it goes next (parked, cancelled,
        quarantined)."""
        task = self._prefilling[slot]
        if task is not None:
            self._prefills.remove(task)
            self._prefilling[slot] = None
        self.active[slot] = None
        self._release_slot_pages(slot)
        self._seeds.pop(slot, None)
        self._replay.pop(slot, None)

    def _preempt_for_priority(self) -> None:
        """A strictly-higher-priority waiter stuck behind a fully-busy
        batch evicts the lowest-priority occupant — one per tick (attach
        runs every tick), so a burst of high-priority arrivals drains the
        batch incrementally instead of thrashing it in one go."""
        waiters = [r["priority"] for r in self.queue]
        waiters += [r["priority"] for r in self._parked
                    if self._ready(r) and self._resume_fits(r)]
        if not waiters or any(
            self._free(s) for s in range(self.scfg.batch)
        ):
            return
        top = max(waiters)
        occ = [
            (r["priority"], -r["_seq"], slot)
            for slot in range(self.scfg.batch)
            if (r := self._occupant(slot)) is not None and r["priority"] < top
        ]
        if occ:
            self._preempt(min(occ)[2])  # lowest priority, youngest tiebreak

    def _pick_victim(self, requester: dict) -> int | None:
        """Pool-pressure victim for ``requester``, by ``preempt_policy``.
        Only strictly lower-priority — or equal-priority strictly younger —
        occupants are eligible, so preemption is a strict order and can
        never ping-pong (the oldest highest-priority request always makes
        progress). Slots holding no pages are skipped: evicting them frees
        nothing."""
        rp, rs = requester["priority"], requester["_seq"]
        cand = []
        for slot in range(self.scfg.batch):
            occ = self._occupant(slot)
            if occ is None or occ is requester or not self._slot_pages[slot]:
                continue
            if occ["priority"] < rp or (
                occ["priority"] == rp and occ["_seq"] > rs
            ):
                cand.append((slot, occ))
        if not cand:
            return None
        policy = self.scfg.preempt_policy
        if policy == "pages":        # free the most memory per eviction
            key = lambda c: -len(self._slot_pages[c[0]])
        elif policy == "progress":   # least work lost to recompute
            key = lambda c: len(c[1]["generated"]) + c[1]["_pending"]
        else:                        # "priority": cheapest class first, then
            key = lambda c: (        # most pages, then least progress
                c[1]["priority"],
                -len(self._slot_pages[c[0]]),
                len(c[1]["generated"]) + c[1]["_pending"],
            )
        return min(cand, key=key)[0]

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``'s request for recompute-resume: flush first (its
        generated history must be complete on the host — replay re-feeds
        it), release every page it holds, and park it. The prefix trie
        keeps its own pins, so a preempted request's shared prompt pages
        stay cached for its resume (and for everyone else)."""
        self._flush()
        req = self._occupant(slot)
        if req is None:
            return  # the flush retired it — pressure already relieved
        with self.session.region("preempt"):
            freed = len(self._slot_pages[slot]) if self._alloc else 0
            self._detach(slot)
            req["_status"] = "preempted"
            self._parked.append(req)
            self.stats["preemptions"] += 1
            self.stats["pages_freed_by_preempt"] += freed

    def _handle_pressure(self, slot: int, e: _PoolPressure) -> None:
        """An allocation for ``slot``'s own request failed even after trie
        eviction and victim preemption. Non-fatal: park the requester
        itself (pressure relieves as older/higher-priority work retires).
        Fatal: unwind everything the request holds — nothing leaks — and
        surface the exhaustion."""
        if not e.fatal:
            self._preempt(slot)
            return
        req = self._occupant(slot)
        self._detach(slot)
        if req is not None:
            req["_status"] = "failed"
            self.failed.append(req)
        raise RuntimeError(
            f"{e.msg} [kv_cache_stats: {self.kv_cache_stats()}]"
        ) from None

    def _reset_slots(self, slots: list[int]) -> None:
        """Restore reused slots' recurrent-state cache rows (SSM/conv/xLSTM
        carries) to their initial values before the new request runs.
        Attention KV needs no reset — stale lines never enter the visible
        window (dense: cache_len masking; paged: freed pages leave the
        block table) — and the sampling keys are stateless (folded with
        the position per step), but recurrent state carries
        unconditionally, so without this the first prefill chunk (or
        decode step) of a reattached slot would continue from the retired
        request's final state."""
        if not self._has_recurrent:
            return
        idx = jnp.asarray(slots, jnp.int32)
        with compat.use_mesh(self.mesh):
            flat, treedef = jax.tree_util.tree_flatten(self.caches)
            leaves = [
                leaf if fresh is None
                else leaf.at[:, idx].set(fresh.astype(leaf.dtype))
                for leaf, fresh in zip(flat, self._fresh_state)
            ]
        self.caches = jax.tree_util.tree_unflatten(treedef, leaves)

    # -- fault recovery (retry / quarantine / injection) -----------------

    def _fault_retry(self, req: dict) -> None:
        """Send ``req`` through the retry path: flush (its generated
        history must be complete on the host — resume replays it), detach
        it from its slot (pages freed, injector-corrupted pages scrubbed
        on the way out), and park it with a capped-exponential-backoff
        ready tick. The re-attach rides the same recompute-resume path as
        preemption — prompt re-prefill on the original chunk grid, decode
        replay with forced inputs — so the retried stream is bitwise
        identical to an unfaulted run, greedy and sampled. A request that
        exhausts ``max_retries`` is quarantined instead."""
        self._flush()
        if req["_status"] in _TERMINAL or (
            req["_status"] == "retrying" and req in self._parked
        ):
            return  # already resolved (or already parked for retry)
        if req["_retries"] >= self.scfg.max_retries:
            self._quarantine(req)
            return
        slot = self._slot_of(req)
        if slot is not None:
            self._detach(slot)
        elif req in self.queue:
            self.queue.remove(req)
        elif req in self._parked:
            self._parked.remove(req)
        req["_retries"] += 1
        backoff = min(
            self.scfg.retry_backoff_cap,
            self.scfg.retry_backoff_base << (req["_retries"] - 1),
        )
        req["_not_before"] = self.stats["ticks"] + backoff
        req["_status"] = "retrying"
        self._parked.append(req)
        self.stats["retries"] += 1
        self.stats["backoff_total_ticks"] += backoff
        self.session.event("recovery")

    def _quarantine(self, req: dict) -> None:
        """Retries exhausted: the request ends in terminal ``failed``
        status (surfaced on its handle exactly like a fatal pool
        exhaustion), its pages are freed, and every co-resident stream is
        untouched — a request the hardware keeps poisoning is a cheap
        rejection, never a scheduler crash."""
        slot = self._slot_of(req)
        if slot is not None:
            self._detach(slot)
        elif req in self.queue:
            self.queue.remove(req)
        elif req in self._parked:
            self._parked.remove(req)
        req["_status"] = "failed"
        self.failed.append(req)
        self.stats["quarantined"] += 1
        self.session.event("recovery")

    def _scrub_slot(self, slot: int) -> None:
        """Zero (on device) any injector-corrupted page ``slot`` still
        maps, just before its pages return to the free list. The free
        list recycles pages verbatim and attention's additive masking
        propagates NaN even from masked rows — a NaN page handed to the
        next request would poison it. Scrubbed through the same jitted
        page-edit step the injector corrupts with."""
        dirty = [p for p in self._slot_pages[slot]
                 if p in self._corrupted_pages]
        if not dirty:
            return
        if self._page_edit is None:
            from repro.serve import faults as _F
            self._page_edit = _F.page_edit_step("zero")
        with compat.use_mesh(self.mesh):
            for p in dirty:
                self.caches = self._page_edit(
                    self.caches, jnp.asarray(p, jnp.int32)
                )
        self._corrupted_pages.difference_update(dirty)

    def _apply_faults(self) -> None:
        """Release expired allocator spikes and apply every due injector
        event (chaos runs only — ``self.faults`` is None otherwise). An
        event with no applicable target this tick is deferred, so every
        scheduled fault eventually lands while work is live; targets are
        chosen by the event's seeded picks, so a rerun of the same
        schedule hits the same victims."""
        tick = self.stats["ticks"]
        if self._spike_holds:
            expired = [h for h in self._spike_holds if h[0] <= tick]
            if expired:
                self._spike_holds = [
                    h for h in self._spike_holds if h[0] > tick
                ]
                for _, pages in expired:
                    self._alloc.release(pages)
        if self.faults is None:
            return
        for e in self.faults.due(tick):
            if e.kind == "nan":
                cand = self._fault_decode_slots(e.request_id)
                if not cand:
                    self.faults.defer(e, tick)
                    continue
                victim = cand[e.pick % len(cand)]
                if victim in self._fault_nan_slots:
                    # already poisoned this tick: two NaNs in one dispatch
                    # are indistinguishable from one — defer so every
                    # scheduled injection poisons a distinct dispatch
                    self.faults.defer(e, tick)
                    continue
                self._fault_nan_slots.add(victim)
                self.faults.record(e.kind)
            elif e.kind == "hang":
                cand = self._fault_decode_slots(e.request_id)
                if not cand:
                    self.faults.defer(e, tick)
                    continue
                self._hang_slot = cand[e.pick % len(cand)]
                self._hang_pending = self.faults.fcfg.hang_s
                self.faults.record(e.kind)
            elif e.kind == "alloc_spike":
                if self._alloc is None or self._alloc.free_pages == 0:
                    self.faults.defer(e, tick)
                    continue
                n = min(self.faults.fcfg.spike_pages,
                        self._alloc.free_pages)
                pages = self._alloc.alloc(n, owner="fault-injector")
                self._spike_holds.append(
                    (tick + self.faults.fcfg.spike_ticks, pages)
                )
                self.faults.record(e.kind)
            elif e.kind == "page_corrupt":
                if self._alloc is None:
                    continue  # dense layout: no pages to corrupt; drop
                mode = self.faults.fcfg.corrupt_mode
                cand = self._fault_page_candidates(mode, e.request_id)
                if not cand:
                    self.faults.defer(e, tick)
                    continue
                page = cand[e.pick2 % len(cand)]
                from repro.serve import faults as _F
                with compat.use_mesh(self.mesh):
                    self.caches = _F.page_edit_step(mode)(
                        self.caches, jnp.asarray(page, jnp.int32)
                    )
                if mode == "nan":
                    # a NaN page must be scrubbed before recycling; flipped
                    # bits stay finite and are fully overwritten/masked for
                    # the next owner, so they need no scrub
                    self._corrupted_pages.add(page)
                self.faults.record(e.kind)

    def _fault_decode_slots(self, request_id) -> list[int]:
        """Slots a decode-dispatch fault (nan/hang) can target: actively
        decoding, not replaying history (a replay row's output is
        discarded — poisoning it would be invisible), optionally pinned
        to one request id (quarantine tests)."""
        return [
            s for s in range(self.scfg.batch)
            if (r := self.active[s]) is not None
            and r["_status"] == "decoding"
            and s not in self._replay
            and r["id"] not in self._fault_nan_inflight
            and (request_id is None or r["id"] == request_id)
        ]

    def _fault_page_candidates(self, mode: str, request_id) -> list[int]:
        """Physical pages a corruption can hit. ``nan`` mode targets an
        UNSHARED page of a decoding slot — the victim's own sentinel
        catches it on its next attention read, nobody else maps the page.
        ``bitflip`` mode targets a trie-cached page no slot currently
        maps (trie pin only) — finite garbage that only the checksum
        validation at the next prefix share can catch."""
        if mode == "nan":
            return [
                p
                for s in self._fault_decode_slots(request_id)
                for p in self._slot_pages[s]
                if self._alloc.refs.get(p) == 1
            ]
        if self._prefix is None:
            return []
        mapped = {p for pages in self._slot_pages for p in pages}
        cand = []
        stack = list(self._prefix.root.children.values())
        while stack:
            node = stack.pop()
            if node.page not in mapped and self._alloc.refs.get(
                node.page
            ) == 1:
                cand.append(node.page)
            stack.extend(node.children.values())
        return sorted(cand)

    # -- paged-pool bookkeeping ------------------------------------------

    def _alloc_pages(self, n: int, req: dict) -> list[int]:
        """Allocate for ``req``, escalating under pool pressure: (1) evict
        LRU prefix-trie entries no live request reads, (2) preempt a victim
        chosen by ``scfg.preempt_policy`` (strictly younger or
        lower-priority than the requester), repeat. If the pool is still
        short, raise ``_PoolPressure`` — non-fatal parks the requester for
        recompute-resume; fatal (policy "never", or nobody else holds
        anything reclaimable) unwinds and surfaces as RuntimeError with the
        full kv/sharing accounting."""
        while True:
            if self._prefix is not None and n > self._alloc.free_pages:
                freed = self._prefix.evict_for(n - self._alloc.free_pages)
                self.stats["evictions_for_preempt"] += freed
            if n <= self._alloc.free_pages:
                return self._alloc.alloc(n, owner=req["id"])
            victim = (
                self._pick_victim(req)
                if self.scfg.preempt_policy != "never" else None
            )
            if victim is not None:
                self._preempt(victim)
                continue
            others_hold = any(
                self._slot_pages[s]
                for s in range(self.scfg.batch)
                if (occ := self._occupant(s)) is not None and occ is not req
            )
            reclaim = (
                self._prefix.reclaimable() if self._prefix is not None else 0
            )
            # an injected allocator spike holds pages that WILL come back
            # in a few ticks: never a fatal exhaustion — park and wait it
            # out (same transient-pressure semantics as a co-tenant burst)
            fatal = not self._spike_holds and (
                self.scfg.preempt_policy == "never"
                or (not others_hold and reclaim == 0)
            )
            raise _PoolPressure(
                fatal,
                f"paged KV pool exhausted: request {req['id']!r} needs {n} "
                f"more page(s) but only {self._alloc.free_pages} of "
                f"{self._alloc.num_pages} are free and no victim is "
                f"eligible (preempt_policy={self.scfg.preempt_policy!r}); "
                f"raise ServeConfig.num_pages (--num-pages) or retire "
                f"requests sooner",
            )

    def _ensure_pages(self, slot: int, last_pos: int, req: dict) -> None:
        """Grow ``slot``'s block table so position ``last_pos`` (inclusive)
        is backed by a physical page; no-op when already covered (and in
        dense mode).

        Pages are acquired ONE AT A TIME so each gets the full
        ``_alloc_pages`` escalation (trie eviction, victim preemption)
        before the next is requested; when the pool runs dry mid-grow —
        a multi-page speculative accept is the common trigger — the pages
        already taken are unwound page-by-page (freed, table row restored
        to -1) before the pressure propagates, so a failed grow can never
        leak a partial allocation."""
        if self._alloc is None:
            return
        need = last_pos // self.scfg.page_size + 1
        have = len(self._slot_pages[slot])
        if need <= have:
            return
        added: list[int] = []
        try:
            for j in range(have, need):
                page = self._alloc_pages(1, req)[0]
                self._tables[slot, j] = page
                self._slot_pages[slot].append(page)
                added.append(page)
                self._tables_dirty = True
        except _PoolPressure:
            for page in reversed(added):
                self._slot_pages[slot].pop()
                self._tables[slot, len(self._slot_pages[slot])] = -1
                self._alloc.release([page])
                self._tables_dirty = True
            raise

    def _attach_prefix(self, slot: int, req) -> int:
        """Map the trie's longest cached prefix of ``req``'s prompt into
        ``slot``'s block table at attach. Fully-matched pages are mapped
        read-only (refcount bump — their prefill is skipped entirely); a
        partially-matched page is copy-on-write: a fresh page is
        allocated, the donor's rows are copied on device, and the
        divergent tokens are prefilled over its tail. At least one prompt
        token is always left to prefill — the final chunk's logits sample
        the request's first generated token. Returns the prefill
        fast-forward (prompt tokens already backed by mapped pages).

        Hybrid/recurrent archs still share matched pages (the memory win)
        but skip no compute: recurrent state has no positional masking,
        so the full prompt must run through the stack regardless.
        Re-prefilling a shared page writes bitwise-identical K/V (same
        tokens, same positions, same chunk grid as the original), so
        concurrent readers of the shared page are unharmed."""
        prompt = req["prompt"]
        psize = self.scfg.page_size
        chain, donor, donor_rows = self._prefix.match(prompt)
        if self._fingerprint is not None:
            chain, donor, donor_rows = self._verify_chain(
                chain, donor, donor_rows
            )
        st = self._prefix.stats
        if self._has_recurrent:
            for j, node in enumerate(chain):
                self._alloc.share([node.page])
                self._tables[slot, j] = node.page
                self._slot_pages[slot].append(node.page)
                self._prefix._touch(node)
            if chain:
                self._tables_dirty = True
                st["hits"] += 1
                st["hit_tokens"] += len(chain) * psize
                st["pages_shared"] += len(chain)
            else:
                st["misses"] += 1
            return 0
        use = len(chain) * psize + donor_rows
        use = min(use, len(prompt) - 1)
        if use <= 0:
            st["misses"] += 1
            return 0
        n_full, cow_rows = divmod(use, psize)
        # the leave-one-token clamp can demote the last fully-matched page
        # to the copy-on-write donor (prompt ends exactly on its boundary)
        cow_donor = None
        if cow_rows:
            cow_donor = chain[n_full] if n_full < len(chain) else donor
        for node in chain[:n_full]:
            self._alloc.share([node.page])
            self._tables[slot, len(self._slot_pages[slot])] = node.page
            self._slot_pages[slot].append(node.page)
            self._prefix._touch(node)
        if cow_donor is not None:
            new = self._alloc_pages(1, req)[0]
            self._tables[slot, len(self._slot_pages[slot])] = new
            self._slot_pages[slot].append(new)
            self._prefix._touch(cow_donor)
            with compat.use_mesh(self.mesh):
                self.caches = self._cow_copy(
                    self.caches,
                    jnp.asarray(cow_donor.page, jnp.int32),
                    jnp.asarray(new, jnp.int32),
                )
            st["cow_copies"] += 1
        self._tables_dirty = True
        st["hits"] += 1
        st["hit_tokens"] += use
        st["prefill_tokens_skipped"] += use
        st["pages_shared"] += n_full
        return use

    def _verify_chain(self, chain, donor, donor_rows):
        """Per-page checksum validation at sharing time
        (``ServeConfig.checksum_pages``): recompute each matched page's
        content fingerprint and compare against the value recorded at
        trie insert. A mismatch (bit rot, a fault-injector bit flip —
        values can stay finite, so the NaN sentinel alone cannot catch
        it) evicts the damaged node's whole subtree and truncates the
        match just before it: the request re-prefills those tokens fresh
        instead of reading corrupt K/V, and no future request can match
        the poisoned entry again."""
        with compat.use_mesh(self.mesh):
            for j, node in enumerate(chain):
                if node.checksum is None:
                    continue
                now = int(self._fingerprint(
                    self.caches, jnp.asarray(node.page, jnp.int32)
                ))
                if now != node.checksum:
                    self.stats["checksum_failures"] += 1
                    self._prefix.evict_subtree(node)
                    self.session.event("recovery")
                    return chain[:j], None, 0
            if donor is not None and donor.checksum is not None:
                now = int(self._fingerprint(
                    self.caches, jnp.asarray(donor.page, jnp.int32)
                ))
                if now != donor.checksum:
                    self.stats["checksum_failures"] += 1
                    self._prefix.evict_subtree(donor)
                    self.session.event("recovery")
                    donor, donor_rows = None, 0
        return chain, donor, donor_rows

    def _release_slot_pages(self, slot: int) -> None:
        if self._alloc is None or not self._slot_pages[slot]:
            return
        if self._corrupted_pages:
            self._scrub_slot(slot)
        self._alloc.release(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self._tables[slot, :] = -1
        self._tables_dirty = True

    def _tables_device(self):
        """Device mirror of the block tables. ``-1`` sentinels are uploaded
        intact: every read path clips them to page 0 (and masks by
        cache_len), while the write path's ``phys_page >= 0`` guard drops
        any write to an unallocated page — a scheduler bug can then never
        scribble on whoever owns physical page 0. The ``.copy()`` matters:
        a zero-copy upload would alias the host table the allocator
        mutates under in-flight dispatches."""
        if self._tables_dirty:
            self._tables_dev = jnp.asarray(self._tables.copy())
            self._tables_dirty = False
        return self._tables_dev

    def kv_cache_stats(self) -> dict:
        """KV-memory accounting for benchmarks and reports.

        ``kv_bytes`` is the attention-cache HBM footprint as allocated
        (dense: the full (B, max_len) buffers; paged: the pool). Paged
        additionally reports live-token peaks and pool utilization."""
        attn_bytes = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.caches)[0]:
            name = _cache_path_name(path)
            if "attn" in name:
                attn_bytes += leaf.size * leaf.dtype.itemsize
        out = {"layout": "paged" if self.scfg.paged else "dense",
               "kv_bytes": int(attn_bytes)}
        if self._alloc is not None:
            per_page = attn_bytes / max(self._alloc.num_pages, 1)
            out.update(
                page_size=self.scfg.page_size,
                num_pages=self._alloc.num_pages,
                pages_in_use=self._alloc.used,
                peak_used_pages=self._alloc.peak_used,
                peak_live_kv_bytes=int(self._alloc.peak_used * per_page),
                pool_utilization=round(
                    self._alloc.peak_used / max(self._alloc.num_pages, 1), 4
                ),
                refcounted_pages=len(self._alloc.refs),
                shared_pages=self._alloc.shared_pages,
            )
            if self._prefix is not None:
                st = self._prefix.stats
                lookups = st["hits"] + st["misses"]
                out["prefix_cache"] = {
                    "trie_pages": self._prefix.size,
                    "hits": st["hits"],
                    "misses": st["misses"],
                    "hit_rate": round(st["hits"] / lookups, 4) if lookups else 0.0,
                    "hit_tokens": st["hit_tokens"],
                    "prefill_tokens_skipped": st["prefill_tokens_skipped"],
                    "pages_saved_by_sharing": st["pages_shared"],
                    "cow_copies": st["cow_copies"],
                    "inserted_pages": st["inserted_pages"],
                    "evicted_pages": st["evicted_pages"],
                }
        out["pressure"] = {
            k: self.stats[k]
            for k in ("preemptions", "resumes", "cancellations",
                      "pages_freed_by_preempt", "evictions_for_preempt",
                      "peak_queue_depth")
        }
        out["pressure"]["queued"] = len(self.queue)
        out["pressure"]["parked"] = len(self._parked)
        # recovery accounting: what the self-healing layer did (all zeros
        # outside chaos/fault conditions — the block is always present so
        # bench artifacts and dashboards have a stable shape)
        out["recovery"] = {
            k: self.stats[k]
            for k in ("retries", "backoff_total_ticks", "quarantined",
                      "shed", "checksum_failures", "watchdog_trips")
        }
        if self.faults is not None:
            out["recovery"]["injected"] = dict(self.faults.counters)
        # speculation accounting (always present, like "recovery": stable
        # artifact shape whether or not spec decoding ran). drafted/
        # accepted/rejected count drafter proposals only; acceptance_rate
        # is the fraction of proposals verification kept, and
        # tokens_per_dispatch is the end-to-end win (1.0 = plain decode)
        drafted = self.stats["spec_drafted"]
        dispatches = self.stats["spec_dispatches"]
        out["speculation"] = {
            "enabled": self.scfg.spec_decode,
            "drafted": drafted,
            "accepted": self.stats["spec_accepted"],
            "rejected": self.stats["spec_rejected"],
            "acceptance_rate": (
                round(self.stats["spec_accepted"] / drafted, 4)
                if drafted else 0.0
            ),
            "mean_accepted_len": (
                round(self.stats["spec_accepted"] / dispatches, 4)
                if dispatches else 0.0
            ),
            "verify_dispatches": dispatches,
            "tokens_per_dispatch": (
                round(self.stats["spec_emitted"] / dispatches, 4)
                if dispatches else 0.0
            ),
        }
        return out

    def _dispatch_prefill_chunk(self) -> None:
        """Dispatch one ``prefill_chunk``-token chunk for the oldest
        in-flight prefill (asynchronous: no host sync here)."""
        task = self._prefills[0]
        C = self.scfg.prefill_chunk
        prompt, start = task["prompt"], task["done"]
        L = min(C, len(prompt) - start)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :L] = prompt[start : start + L]
        if self.scfg.paged:
            # back the chunk's positions [start, start+L) with pool pages
            # before anything writes them; pool pressure here may preempt a
            # victim, park this request, or (fatal) unwind and raise —
            # either way this chunk does not dispatch
            try:
                self._ensure_pages(task["slot"], start + L - 1, task["req"])
            except _PoolPressure as e:
                self._handle_pressure(task["slot"], e)
                return
        args = (
            self.params, jnp.asarray(chunk),
            jnp.asarray([start], jnp.int32), jnp.asarray([L], jnp.int32),
            jnp.asarray(task["slot"], jnp.int32), self.caches,
        )
        if self.scfg.paged:
            args += (self._tables_device(),)
        next_tok, bad, self.caches = self.prefill(*args, self.rng_keys)
        task["done"] = start + L
        self.stats["prefill_chunks"] += 1
        if task["done"] >= len(prompt):
            # prefill complete: next_tok is the request's FIRST generated
            # token — it joins the deferred readback like any decode output,
            # and seeds the slot's decode input (device-side, next tick)
            slot, req = task["slot"], task["req"]
            if self._prefix is not None:
                # cache the prompt's full pages for future requests: shared
                # pages re-touch their nodes, fresh/CoW pages insert new
                # ones (each pinned with a trie-owned reference); with
                # checksum_pages on, fingerprint each full prompt page now
                # — its content is final (decode writes land past the
                # prompt) and every future share validates against it
                checks = None
                if self._fingerprint is not None:
                    n_full = len(req["prompt"]) // self.scfg.page_size
                    checks = [
                        int(self._fingerprint(
                            self.caches, jnp.asarray(p, jnp.int32)
                        ))
                        for p in self._slot_pages[slot][:n_full]
                    ]
                self._prefix.insert(req["prompt"], self._slot_pages[slot],
                                    checksums=checks)
            self._prefills.remove(task)
            self._prefilling[slot] = None
            self.active[slot] = req
            req["_status"] = "decoding"
            self.pos[slot] = len(prompt)
            if req["generated"]:
                # recompute-resume: the chunk grid above rebuilt the prompt
                # KV bitwise; the re-sampled first token is generated[0]
                # again, already on the host — discard it and schedule the
                # rest of the history for decode replay (inputs forced,
                # outputs discarded)
                if self.scfg.spec_decode:
                    self._last_tok[slot] = req["generated"][0]
                else:
                    self._seeds[slot] = req["generated"][0]
                if len(req["generated"]) > 1:
                    self._replay[slot] = list(req["generated"][1:])
            elif self.scfg.spec_decode:
                # spec mode has no deferred-readback pipeline (the accept
                # count syncs every tick anyway): materialize the first
                # token here — this is the TTFT point regardless
                tok_h, bad_h = jax.device_get([next_tok, bad])
                self.stats["readbacks"] += 1
                if bool(bad_h[0]):
                    # poisoned prefill: nothing was emitted — retry from
                    # the (empty) clean history via the standard path
                    self._fault_nan_inflight.discard(req["id"])
                    self._fault_retry(req)
                    return
                req["generated"].append(int(tok_h[0]))
                self._last_tok[slot] = int(tok_h[0])
                eos = self.scfg.eos_id
                if req["max_new"] <= 1 or (
                        eos is not None and int(tok_h[0]) == eos):
                    req["_status"] = "done"
                    self.completed.append(req)
                    self.active[slot] = None
                    self._release_slot_pages(slot)
            else:
                req["_pending"] += 1
                self._pending.append(
                    (next_tok.reshape(1, 1), bad.reshape(1), [req])
                )
                self._seeds[slot] = next_tok[0]

    def _apply_seeds(self) -> None:
        """All newly seeded slots in ONE vectorized device-side scatter —
        no per-slot host round-trips. ``_seeds`` is keyed by slot (newest
        seed wins), so the scatter indices are unique by construction."""
        if not self._seeds:
            return
        seeds, self._seeds = self._seeds, {}
        slots = jnp.asarray(list(seeds), jnp.int32)
        toks = jnp.stack(
            [jnp.asarray(t, jnp.int32).reshape(()) for t in seeds.values()]
        )
        self.tokens = self.tokens.at[slots, 0].set(toks)

    # -- readback --------------------------------------------------------

    def _flush(self) -> None:
        """Materialize all pending tokens in ONE host transfer; retire
        requests that hit their budget or emitted EOS. The NaN/Inf
        sentinel rides the same transfer: a flagged row's token is
        garbage (sampled from poisoned logits) — it is dropped, and so is
        every LATER row of the same request in this flush (tokens decoded
        downstream of the poison are finite but wrong), then the request
        goes through ``_fault_retry`` instead of streaming poison."""
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        host = jax.device_get(
            [[toks, bad] for toks, bad, _ in pending]
        )  # single transfer
        self.stats["readbacks"] += 1
        poisoned: list[dict] = []
        poisoned_ids: set = set()
        for (toks, bad), (_, _, reqmap) in zip(host, pending):
            for row, req in enumerate(reqmap):
                if req is None:
                    continue
                req["_pending"] -= 1
                if bool(bad[row]):
                    # the poison landed on the host: the request is
                    # targetable again once its retry resolves
                    self._fault_nan_inflight.discard(req["id"])
                if req["_cancelled"]:
                    continue  # cancelled mid-stream: drop the dispatched row
                if bool(bad[row]) or req["id"] in poisoned_ids:
                    if req["id"] not in poisoned_ids:
                        poisoned_ids.add(req["id"])
                        poisoned.append(req)
                    continue
                req["generated"].append(int(toks[row, 0]))
        eos = self.scfg.eos_id
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            done = len(req["generated"]) >= req["max_new"]
            if eos is not None and eos in req["generated"]:
                # early stop: drop anything decoded past the EOS between
                # flush boundaries
                req["generated"] = req["generated"][: req["generated"].index(eos) + 1]
                done = True
            if done:
                req["_status"] = "done"
                self.completed.append(req)
                self.active[slot] = None
                self._release_slot_pages(slot)
                self._replay.pop(slot, None)
        # after retirement (a poisoned request cannot be done — its bad
        # rows never appended): all pending rows are drained above, and a
        # parked request dispatches nothing, so no stale poisoned row can
        # surface in a later flush
        for req in poisoned:
            if req["_status"] not in _TERMINAL:
                self._fault_retry(req)

    def drain(self) -> None:
        """Run the scheduler to quiescence: every queued, parked,
        prefilling and decoding request completes (the admission queue and
        parked set are serviced through ordinary ``step`` ticks — drain is
        exactly "keep serving until the work is gone"), then flush the last
        readbacks. Cancelled requests' dispatched-but-unflushed rows are
        materialized and dropped on the way."""
        live = (
            self.queue + self._parked
            + [r for r in self.active if r is not None]
            + [t["req"] for t in self._prefills]
        )
        # generous tick budget: prefill chunks + decode budget per request,
        # with headroom for preemption/replay rounds (bounded — the oldest
        # highest-priority request always makes progress) plus fault-
        # recovery slack: each request may burn its full retry budget
        # (each retry is one more recompute round plus its backoff), and
        # injected allocator spikes stall everyone for spike_ticks
        rounds = len(live) + 2 + self.scfg.max_retries
        budget = 64 + rounds * sum(
            r["max_new"] + len(r["prompt"]) // max(self.scfg.prefill_chunk, 1)
            + len(r["prompt"]) + 1
            for r in live
        )
        budget += len(live) * self.scfg.max_retries * (
            self.scfg.retry_backoff_cap + 1
        )
        if self.faults is not None:
            budget += 64 + self.faults.fcfg.spike_ticks * (
                self.faults.fcfg.n_alloc_spike + 1
            )
        ticks = 0
        while (self.queue or self._parked or self._prefills
               or any(r is not None for r in self.active)):
            self.step()
            ticks += 1
            if ticks > budget:
                raise RuntimeError(
                    f"drain() reached no quiescence after {ticks} ticks: "
                    f"queued={len(self.queue)} parked={len(self._parked)} "
                    f"active={sum(r is not None for r in self.active)} "
                    f"prefilling={len(self._prefills)} "
                    f"[kv_cache_stats: {self.kv_cache_stats()}]"
                )
        self._flush()
        if self._spike_holds and self._alloc is not None:
            # the workload finished while an injected spike still held
            # pool pages: give them back — a chaos run must end with the
            # same zero-leak guarantee as any other drain
            for _, pages in self._spike_holds:
                self._alloc.release(pages)
            self._spike_holds = []

    # -- speculative decode (draft + batched verify) ---------------------

    def _plan_drafts(self) -> dict[int, dict]:
        """Per decoding slot, the draft window for this tick's verify
        dispatch. Recompute-resume/retry replay tokens come FIRST — they
        are true history, so verification accepts them bitwise and replay
        rides the speculative path at up to ``spec_k+1`` tokens per
        dispatch instead of one. Fresh proposals from the n-gram drafter
        are only appended once the replay queue fits entirely in the
        window (the drafter's input is the full history, which ends at
        the replay queue's end). The drafter budget is clamped so
        accepted-and-emitted tokens can never exceed the request's
        ``max_new`` (at most ``n_draft + 1`` new emissions per dispatch)
        and the deepest K/V write stays at ``max_len - 1`` (a deeper
        write would be silently dropped by the masked scatter)."""
        K = self.scfg.spec_k
        plans: dict[int, dict] = {}
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            replay = self._replay.get(slot, [])
            drafts = [int(t) for t in replay[:K]]
            n_replay = len(drafts)
            n_draft = 0
            if n_replay == len(replay):
                remaining = req["max_new"] - len(req["generated"])
                budget = min(
                    K - n_replay,
                    max(remaining - 1, 0),
                    max(self.scfg.max_len - 1 - int(self.pos[slot])
                        - n_replay, 0),
                )
                if budget > 0:
                    drafts += draft_tokens(
                        req["prompt"] + req["generated"], budget,
                        min_match=self.scfg.spec_min_match,
                    )
                    n_draft = len(drafts) - n_replay
            plans[slot] = {
                "drafts": drafts, "n_replay": n_replay, "n_draft": n_draft,
            }
        return plans

    def _spec_tick(self, chunks_at_tick_start: int) -> None:
        """The speculative replacement for the one-token decode dispatch:
        plan per-slot draft windows, back every window with physical pages
        (multi-page accepts cross page boundaries — ``_ensure_pages``
        unwinds page-by-page on pool pressure), then score all windows in
        ONE batched verify dispatch and commit each slot's longest
        accepted prefix. Rejected positions need no KV rollback: their
        writes are masked scatters that the next dispatch overwrites at
        the same positions before any read can see them — rollback is
        simply not advancing ``pos``. The accept counts must reach the
        host before the next tick can draft, so the dispatch reads back
        immediately (a few small arrays), amortized over up to
        ``spec_k+1`` tokens."""
        plans = self._plan_drafts()
        if self.scfg.paged:
            for slot, plan in plans.items():
                req = self.active[slot]
                if req is None:
                    continue  # a pressure round below evicted this slot
                try:
                    self._ensure_pages(
                        slot, int(self.pos[slot]) + len(plan["drafts"]), req
                    )
                except _PoolPressure as e:
                    self._handle_pressure(slot, e)
        decoding = list(self.active)
        plans = {s: p for s, p in plans.items() if decoding[s] is not None}
        if bool(self._prefills) and plans:
            self.stats["overlap_ticks"] += 1
        if not plans:
            return
        B, C = self.scfg.batch, self.scfg.spec_k + 1
        chunk = np.zeros((B, C), np.int32)
        length = np.zeros(B, np.int32)
        for slot, plan in plans.items():
            drafts = plan["drafts"]
            chunk[slot, 0] = self._last_tok[slot]
            chunk[slot, 1:1 + len(drafts)] = drafts
            length[slot] = 1 + len(drafts)
        pos_now = jnp.asarray(self.pos.copy())
        fault_mask = self._fault_mask_zero
        if self._fault_nan_slots:
            m = np.zeros(self.scfg.batch, bool)
            m[list(self._fault_nan_slots)] = True
            for s in self._fault_nan_slots:
                if decoding[s] is not None:
                    self._fault_nan_inflight.add(decoding[s]["id"])
            self._fault_nan_slots.clear()
            fault_mask = jnp.asarray(m)
        t0 = time.perf_counter()
        if self._hang_pending:
            time.sleep(self._hang_pending)
            self._hang_pending = 0.0
        out_dev, acc_dev, bad_dev, self.caches = self.verify(
            self.params, jnp.asarray(chunk), pos_now,
            jnp.asarray(length), self.caches, self._tables_device(),
            self.rng_keys, fault_mask,
        )
        dispatch_s = time.perf_counter() - t0
        self.stats["decode_steps"] += 1
        self.stats["spec_dispatches"] += 1
        if self.stats["prefill_chunks"] > chunks_at_tick_start:
            self.stats["decode_after_prefill_ticks"] += 1
        out, acc, bad = jax.device_get([out_dev, acc_dev, bad_dev])
        self.stats["readbacks"] += 1
        poisoned: list[dict] = []
        eos = self.scfg.eos_id
        for slot, plan in plans.items():
            req = decoding[slot]
            if bool(bad[slot]):
                # poisoned verify: nothing committed for this slot (pos
                # untouched, replay queue untouched) — the whole window
                # recomputes after the retry
                self._fault_nan_inflight.discard(req["id"])
                if not req["_cancelled"]:
                    poisoned.append(req)
                continue
            n_acc = int(acc[slot])
            emitted = [int(t) for t in out[slot, : n_acc + 1]]
            self.pos[slot] += n_acc + 1
            self._last_tok[slot] = emitted[-1]
            acc_draft = max(0, n_acc - plan["n_replay"])
            self.stats["spec_drafted"] += plan["n_draft"]
            self.stats["spec_accepted"] += acc_draft
            self.stats["spec_rejected"] += plan["n_draft"] - acc_draft
            # replay outputs are tokens already in ``generated`` — pop
            # them off the queue instead of double-counting
            new_toks = emitted
            if slot in self._replay:
                hist = self._replay[slot]
                n_hist = min(len(hist), len(emitted))
                del hist[:n_hist]
                if not hist:
                    del self._replay[slot]
                new_toks = emitted[n_hist:]
            if req["_cancelled"]:
                continue
            req["generated"].extend(new_toks)
            self.stats["spec_emitted"] += len(new_toks)
            done = len(req["generated"]) >= req["max_new"]
            if eos is not None and eos in req["generated"]:
                # EOS inside the accepted window: truncate right after it
                req["generated"] = (
                    req["generated"][: req["generated"].index(eos) + 1]
                )
                done = True
            if done:
                req["_status"] = "done"
                self.completed.append(req)
                self.active[slot] = None
                self._release_slot_pages(slot)
                self._replay.pop(slot, None)
        if (self.scfg.watchdog_deadline_s is not None
                and dispatch_s > self.scfg.watchdog_deadline_s):
            self.stats["watchdog_trips"] += 1
            self.session.event("recovery")
            victim, self._hang_slot = self._hang_slot, None
            req = self.active[victim] if victim is not None else None
            if req is not None and req["_status"] not in _TERMINAL:
                self._fault_retry(req)
        for req in poisoned:
            if req["_status"] not in _TERMINAL:
                self._fault_retry(req)

    # -- the tick --------------------------------------------------------

    def step(self) -> int:
        """One scheduler tick: decode dispatch for all decoding slots, then
        at most one prefill chunk dispatch. Returns #busy slots."""
        self.stats["ticks"] += 1
        self._attach()
        if self.faults is not None or self._spike_holds:
            self._apply_faults()
        chunks_at_tick_start = self.stats["prefill_chunks"]
        with compat.use_mesh(self.mesh):
            if not self.scfg.overlap:
                # stop-the-world baseline: complete every pending prefill
                # before this tick's decode may proceed
                while self._prefills:
                    self._dispatch_prefill_chunk()
                if self._seeds:
                    self._apply_seeds()
                    jax.block_until_ready(self.tokens)
            else:
                self._apply_seeds()  # seeds collected since last tick
            if self.scfg.spec_decode:
                # speculative tick: draft windows + ONE batched verify
                # replace the one-token decode dispatch entirely (page
                # ensuring moves inside — the window's extent is per-plan)
                self._spec_tick(chunks_at_tick_start)
                decoding: list[dict | None] = [None] * self.scfg.batch
            elif self.scfg.paged:
                # this step writes each active slot's K/V at pos[slot]: back
                # any page boundary being crossed BEFORE snapshotting the
                # active set — pool pressure here can preempt (remove) a
                # victim slot mid-loop, or park the requesting slot itself
                for slot in range(self.scfg.batch):
                    req = self.active[slot]
                    if req is not None:
                        try:
                            self._ensure_pages(slot, int(self.pos[slot]), req)
                        except _PoolPressure as e:
                            self._handle_pressure(slot, e)
            if not self.scfg.spec_decode:
                decoding = list(self.active)
            if bool(self._prefills) and any(r is not None for r in decoding):
                self.stats["overlap_ticks"] += 1
            if any(r is not None for r in decoding):
                active = np.asarray([r is not None for r in decoding])
                if self.scfg.paged:
                    args = (jnp.asarray(active), self.caches,
                            self._tables_device())
                else:
                    args = (jnp.asarray(active), self.caches)
                # snapshot pos: jnp.asarray can zero-copy alias an aligned
                # numpy buffer on CPU, and the async decode would then read
                # the ``self.pos`` mutations below (and next tick's attach
                # resets) instead of this tick's values
                pos_now = jnp.asarray(self.pos.copy())
                fault_mask = self._fault_mask_zero
                if self._fault_nan_slots:
                    # injected logit poison for this dispatch only: the
                    # masked slots' logits become NaN ahead of the
                    # sentinel (the all-False mask every normal tick is a
                    # bitwise no-op select)
                    m = np.zeros(self.scfg.batch, bool)
                    m[list(self._fault_nan_slots)] = True
                    for s in self._fault_nan_slots:
                        if decoding[s] is not None:
                            self._fault_nan_inflight.add(decoding[s]["id"])
                    self._fault_nan_slots.clear()
                    fault_mask = jnp.asarray(m)
                t0 = time.perf_counter()
                if self._hang_pending:
                    # injected dispatch hang (a wedged host thread): burn
                    # wall time where the watchdog measures it
                    time.sleep(self._hang_pending)
                    self._hang_pending = 0.0
                self.tokens, bad_dev, self.caches = self.decode(
                    self.params, self.tokens, pos_now,
                    *args, self.rng_keys, fault_mask,
                )
                dispatch_s = time.perf_counter() - t0
                self.stats["decode_steps"] += 1
                if self.stats["prefill_chunks"] > chunks_at_tick_start:
                    # prefill work ran before this tick's decode dispatch:
                    # the decode pipeline waited on it
                    self.stats["decode_after_prefill_ticks"] += 1
                self.pos[active] += 1
                # recompute-resume replay: a replaying slot's output is a
                # token already in its ``generated`` history — discard it
                # (None row, no _pending) instead of double-counting it
                reqmap = [
                    None if (r is not None and s in self._replay) else r
                    for s, r in enumerate(decoding)
                ]
                self._pending.append((self.tokens, bad_dev, reqmap))
                for req in reqmap:
                    if req is not None:
                        req["_pending"] += 1
                if (self.scfg.watchdog_deadline_s is not None
                        and dispatch_s > self.scfg.watchdog_deadline_s):
                    # the dispatch call itself blew its deadline (a wedged
                    # dispatch path; in chaos runs, the injected hang).
                    # The late tokens are kept — identity is preserved —
                    # and the hung slot's request retries so a recurring
                    # wedge cannot stall its stream forever
                    self.stats["watchdog_trips"] += 1
                    self.session.event("recovery")
                    victim, self._hang_slot = self._hang_slot, None
                    req = (
                        self.active[victim] if victim is not None else None
                    )
                    if req is not None and req["_status"] not in _TERMINAL:
                        self._fault_retry(req)
                # advance the forced-input schedule: the popped history
                # token overrides the sampled output as next tick's input
                # for its slot; when the list empties, the NEXT output is
                # the first genuinely new token and is kept
                for slot in list(self._replay):
                    if decoding[slot] is not None:
                        hist = self._replay[slot]
                        self._seeds[slot] = hist.pop(0)
                        if not hist:
                            del self._replay[slot]
            if self.scfg.overlap and self._prefills:
                self._dispatch_prefill_chunk()
        flush_due = any(
            req is not None
            and len(req["generated"]) + req["_pending"] >= req["max_new"]
            for req in self.active
        )
        if (self.scfg.eos_id is not None and self._pending
                and self.stats["ticks"] % self.scfg.eos_check_every == 0):
            flush_due = True
        if flush_due:
            self._flush()
        return sum(
            1 for slot in range(self.scfg.batch) if not self._free(slot)
        )
