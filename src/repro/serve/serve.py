"""Serving: prefill + batched decode with sharded KV caches.

``serve_step`` (one new token against a KV cache of ``seq_len``) is what the
``decode_*`` / ``long_*`` dry-run shapes lower, per the assignment spec.
Caches shard like activations: batch over ("pod","data"), kv-heads over
"model" where divisible (megatron) else replicated; recurrent states shard
over their head/inner dims.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.distributed import sharding as SH
from repro.layers.common import LogicalConstraints
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    batch: int
    temperature: float = 1.0
    greedy: bool = True


def cache_pspec_tree(cfg, mesh, caches):
    """PartitionSpecs for the stacked cache pytree.

    Attention KV caches are the serving-memory wall (command-r decode_32k:
    343 GB). Sharding priority: batch over ("pod","data") when divisible;
    kv-heads over "model" when divisible, else the **sequence** dim over
    "model" (decode attention over a seq-sharded cache = partial softmax +
    tiny all-reduces — the GSPMD-native flash-decode layout)."""
    rules = SH.activation_rules(cfg, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)

    def batch_ax(b: int):
        return SH.divisible_batch_axes(mesh, b)

    kv_div = cfg.n_kv_heads % model == 0 and model > 1
    inner = rules["inner"]
    ssm_heads = (
        "model"
        if cfg.ssm and cfg.ssm.n_heads(cfg.d_model) % model == 0 and model > 1
        else None
    )

    def f(path_leaf):
        path, leaf = path_leaf
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p) for p in path)
        nd = len(leaf.shape)
        b = leaf.shape[1] if nd >= 2 else 1
        batch = batch_ax(b)
        if "attn" in name:  # (R, B, Smax, Hkv, hd)
            if kv_div:
                return P(None, batch, None, "model", None)
            return P(None, batch, "model" if model > 1 else None, None, None)
        if "mamba" in name and nd == 4:  # conv (R, B, K-1, C)
            return P(None, batch, None, inner)
        if "mamba" in name and nd == 5:  # ssm (R, B, h, p, n)
            return P(None, batch, ssm_heads, None, None)
        return P(*([None, batch] + [None] * (nd - 2)))

    paths = jax.tree_util.tree_flatten_with_path(caches)[0]
    specs = [f(pl) for pl in paths]
    treedef = jax.tree_util.tree_structure(caches)
    return jax.tree_util.tree_unflatten(treedef, specs)


def serve_cache_pspecs(cfg, mesh, batch: int, max_len: int):
    caches = jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))
    return cache_pspec_tree(cfg, mesh, caches)


def make_prefill_step(cfg, mesh):
    lc = LogicalConstraints(mesh, SH.activation_rules(cfg, mesh))

    def prefill_step(params, batch, caches):
        logits, new_caches = T.prefill(params, batch, cfg, caches, lc)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_caches

    return prefill_step


def make_decode_step(cfg, mesh):
    lc = LogicalConstraints(mesh, SH.activation_rules(cfg, mesh))

    def decode_step(params, tokens, pos, caches):
        """tokens: (B,1) int32; pos: () int32 current position."""
        logits, new_caches = T.decode_step(params, tokens, pos, cfg, caches, lc)
        next_tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(jnp.int32)
        return next_tok, new_caches

    return decode_step


def make_encoder_step(cfg, mesh):
    """Encoder-only archs have no decode; "prefill" = full forward."""
    lc = LogicalConstraints(mesh, SH.activation_rules(cfg, mesh))

    def encoder_step(params, batch):
        logits, _ = T.apply_logits(params, batch, cfg, lc)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return encoder_step


# ---------------------------------------------------------------------------
# simple continuous-batching scheduler (example/serving driver)
# ---------------------------------------------------------------------------


class BatchScheduler:
    """Greedy slot-based continuous batching: fixed B decode slots; finished
    sequences are replaced by queued requests (prefill on attach).

    Token readback is **deferred and batched**: a decode step only appends
    the on-device token array to a pending list (keeping the dispatch
    pipeline free of host round-trips), and one ``jax.device_get`` of the
    whole pending batch runs when a request is about to complete (or on
    ``drain()``). Completion is count-based (``max_new``), so the host never
    needs token *values* mid-flight — N decode steps cost one transfer
    instead of N.

    Monitoring goes through ``repro.session``: pass a ``PerfSession`` and
    every decode dispatch is a visit of its ``decode`` region with the step
    observed and the static StepProfile derived from the compiled decode
    step; with no session (or a null backend) the scheduler runs fully
    uninstrumented at zero cost.
    """

    def __init__(self, cfg, mesh, scfg: ServeConfig, params, session=None):
        from repro.session import PerfSession, SessionConfig

        self.cfg, self.mesh, self.scfg = cfg, mesh, scfg
        self.params = params
        # default: off, but env-activatable (TALP_ENABLE=1) like every other
        # entry point; the caller owns finalize() (also via self.session)
        self.session = session if session is not None else PerfSession(
            SessionConfig(app_name="serve", backend="null")
        )
        self.decode = self.session.wrap_step(
            jax.jit(make_decode_step(cfg, mesh), donate_argnums=(3,)),
            region="decode",
            derive=True,
            num_devices=mesh.devices.size,
            # observe the sampled tokens only: blocking on the donated cache
            # tuple would serialize the decode pipeline
            observe=lambda out: {"outputs": out[0]},
        )
        self.caches = T.init_cache(cfg, scfg.batch, scfg.max_len)
        self.tokens = jnp.zeros((scfg.batch, 1), jnp.int32)
        self.queue: list[dict] = []
        self.active: list[dict | None] = [None] * scfg.batch
        self.pos = 0
        self.completed: list[dict] = []
        # pending readbacks: (device tokens of one step, slot->request map
        # at that step); flushed in a single device_get
        self._pending: list[tuple[Any, list[dict | None]]] = []

    def submit(self, prompt_tokens, request_id, max_new: int = 32) -> None:
        self.queue.append(
            {"id": request_id, "prompt": prompt_tokens, "max_new": max_new,
             "generated": [], "_pending": 0}
        )

    def _attach(self) -> None:
        for slot in range(self.scfg.batch):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.active[slot] = req
                tok = req["prompt"][-1] if len(req["prompt"]) else 0
                self.tokens = self.tokens.at[slot, 0].set(int(tok))

    def _flush(self) -> None:
        """Materialize all pending tokens in ONE host transfer and retire
        any requests that reached their budget."""
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        host = jax.device_get([toks for toks, _ in pending])  # single transfer
        for toks, (_, slots) in zip(host, pending):
            for slot, req in enumerate(slots):
                if req is None:
                    continue
                req["generated"].append(int(toks[slot, 0]))
                req["_pending"] -= 1
        for slot, req in enumerate(self.active):
            if req is not None and len(req["generated"]) >= req["max_new"]:
                self.completed.append(req)
                self.active[slot] = None

    def drain(self) -> None:
        """Flush outstanding readbacks (end of serving loop / inspection)."""
        self._flush()

    def step(self) -> int:
        """One decode step for the whole batch; returns #active."""
        self._attach()
        if all(a is None for a in self.active):
            return 0
        with compat.use_mesh(self.mesh):
            self.tokens, self.caches = self.decode(
                self.params, self.tokens, jnp.asarray(self.pos, jnp.int32), self.caches
            )
        self.pos += 1
        self._pending.append((self.tokens, list(self.active)))
        flush_due = False
        for req in self.active:
            if req is None:
                continue
            req["_pending"] += 1
            if len(req["generated"]) + req["_pending"] >= req["max_new"]:
                flush_due = True
        if flush_due:
            self._flush()
        return sum(1 for req in self.active if req is not None)
