"""Open-loop traffic harness for the serving stack.

A workload here is a pure function of its :class:`TrafficConfig`: every
arrival time, prompt, output budget, priority and scheduled cancellation
comes out of one seeded ``np.random.default_rng``, so two runs with the
same config replay bit-for-bit — which is what lets CI compare goodput
and tail latency across commits (the paper's continuous-monitoring
thesis applied to load, not just correctness).

Two arrival processes, both in the scheduler-tick domain (open loop: the
workload does not slow down when the server falls behind — queueing is
the point):

  ``poisson``  arrivals per tick ~ Poisson(rate): the memoryless baseline
  ``burst``    a Markov-modulated Poisson process: a two-state chain
               (calm/burst) where each tick the state flips with
               probability 1/mean_len and arrivals draw from that state's
               rate (``rate`` calm, ``rate * burst_mult`` bursting) —
               the arrival pattern a fixed FIFO trace can never model,
               and the one that actually exercises admission queueing
               and preemption under pool pressure.

:func:`replay` drives a ``BatchScheduler`` through a workload — submits
at arrival ticks, fires scheduled mid-stream cancellations, runs to
quiescence — and reports goodput, TTFT percentiles, queue depth and the
scheduler's pressure counters in the shape ``BENCH_serve.json`` carries.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Everything a workload is; hash the fields, hash the traffic."""

    n_requests: int = 16
    seed: int = 0
    # arrival process (tick domain)
    arrival: str = "poisson"          # "poisson" | "burst"
    rate: float = 0.5                 # mean arrivals per tick (calm state)
    burst_mult: float = 6.0           # burst-state rate = rate * burst_mult
    burst_mean_len: float = 4.0       # mean ticks a burst lasts
    calm_mean_len: float = 12.0       # mean ticks between bursts
    # mixed prompt/output length distributions: a short/long mixture
    # (chat-style short turns + document-style long prompts)
    prompt_short: tuple[int, int] = (4, 16)
    prompt_long: tuple[int, int] = (24, 48)
    long_frac: float = 0.25           # probability a prompt is long
    max_new_short: tuple[int, int] = (4, 12)
    max_new_long: tuple[int, int] = (16, 32)
    long_out_frac: float = 0.25
    # priority classes drawn by weight (higher = more important; the
    # scheduler admits by (priority, arrival) and preempts strictly-lower)
    priorities: tuple[int, ...] = (0, 1, 2)
    priority_weights: tuple[float, ...] = (0.7, 0.2, 0.1)
    # scheduled mid-stream cancellations: this fraction of requests cancel
    # ``cancel_delay`` ticks after arrival (clients hanging up mid-answer)
    cancel_frac: float = 0.0
    cancel_delay: tuple[int, int] = (2, 10)
    vocab_lo: int = 4
    vocab_hi: int = 256

    def __post_init__(self):
        if self.arrival not in ("poisson", "burst"):
            raise ValueError(
                f"arrival must be poisson|burst, got {self.arrival!r}"
            )
        if len(self.priorities) != len(self.priority_weights):
            raise ValueError("priorities and priority_weights differ in length")


@dataclasses.dataclass(frozen=True)
class TrafficRequest:
    """One generated request: where it arrives, what it asks, how it ends."""

    request_id: int
    arrival_tick: int
    prompt: tuple[int, ...]
    max_new: int
    priority: int
    cancel_tick: int | None = None    # absolute tick; None = runs to budget


def _uniform_int(rng, lo_hi) -> int:
    lo, hi = lo_hi
    return int(rng.integers(lo, hi + 1))


def generate_workload(tcfg: TrafficConfig) -> list[TrafficRequest]:
    """The workload as a pure function of its config.

    Ticks advance one at a time; each tick draws the arrival count from
    the current state's Poisson rate (constant for ``poisson``, chain-
    modulated for ``burst``) until ``n_requests`` have been emitted.
    """
    rng = np.random.default_rng(tcfg.seed)
    out: list[TrafficRequest] = []
    tick = 0
    bursting = False
    while len(out) < tcfg.n_requests:
        if tcfg.arrival == "burst":
            mean = tcfg.burst_mean_len if bursting else tcfg.calm_mean_len
            if rng.random() < 1.0 / max(mean, 1.0):
                bursting = not bursting
            lam = tcfg.rate * (tcfg.burst_mult if bursting else 1.0)
        else:
            lam = tcfg.rate
        for _ in range(int(rng.poisson(lam))):
            if len(out) >= tcfg.n_requests:
                break
            is_long = rng.random() < tcfg.long_frac
            plen = _uniform_int(
                rng, tcfg.prompt_long if is_long else tcfg.prompt_short
            )
            prompt = tuple(
                int(t) for t in rng.integers(tcfg.vocab_lo, tcfg.vocab_hi,
                                             size=plen)
            )
            max_new = _uniform_int(
                rng,
                tcfg.max_new_long if rng.random() < tcfg.long_out_frac
                else tcfg.max_new_short,
            )
            prio = int(rng.choice(tcfg.priorities,
                                  p=np.asarray(tcfg.priority_weights)
                                  / sum(tcfg.priority_weights)))
            cancel = None
            if rng.random() < tcfg.cancel_frac:
                cancel = tick + _uniform_int(rng, tcfg.cancel_delay)
            out.append(TrafficRequest(
                request_id=len(out), arrival_tick=tick, prompt=prompt,
                max_new=max_new, priority=prio, cancel_tick=cancel,
            ))
        tick += 1
    return out


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile on a pre-sorted list (no numpy float fuzz in
    the artifact: the value reported is a value that was measured)."""
    if not sorted_vals:
        return 0.0
    k = max(0, min(len(sorted_vals) - 1,
                   int(np.ceil(q / 100.0 * len(sorted_vals))) - 1))
    return sorted_vals[k]


def replay(sched, workload: list[TrafficRequest], *,
           max_ticks: int | None = None, faults=None) -> dict:
    """Drive ``sched`` through ``workload`` and measure it.

    Open loop: request ``r`` is submitted at the top of scheduler tick
    ``r.arrival_tick`` regardless of how far behind the server is, and
    scheduled cancellations fire at their tick whether or not the stream
    ever attached. After the last arrival the scheduler runs to
    quiescence via ``drain()``.

    Goodput counts only tokens of requests that COMPLETED — work spent on
    streams that were later cancelled or failed is throughput, not
    goodput.

    Chaos mode: pass ``faults`` (a ``repro.serve.faults.FaultInjector``,
    itself a pure function of its ``FaultConfig`` seed) to compose a
    seeded fault schedule with the seeded workload — the scheduler
    applies due injections tick by tick and the metrics grow the
    recovery accounting (goodput-under-faults is what the nightly chaos
    soak records). The same (TrafficConfig, FaultConfig) pair replays
    bit-for-bit.
    """
    if faults is not None:
        sched.faults = faults
    workload = sorted(workload, key=lambda r: (r.arrival_tick, r.request_id))
    cancels = sorted(
        ((r.cancel_tick, r.request_id) for r in workload
         if r.cancel_tick is not None),
    )
    horizon = max((r.arrival_tick for r in workload), default=0)
    budget = max_ticks if max_ticks is not None else (
        horizon + 64 + 4 * sum(r.max_new + len(r.prompt) for r in workload)
    )
    if faults is not None and max_ticks is None:
        # chaos slack: retries recompute work and serve backoff, spikes
        # stall the pool for a few ticks each
        budget += sum(
            (r.max_new + len(r.prompt)) * sched.scfg.max_retries
            for r in workload
        ) + faults.fcfg.spike_ticks * (faults.fcfg.n_alloc_spike + 1)
    submit_t: dict[int, float] = {}
    ttft: dict[int, float] = {}
    depths: list[int] = []
    next_arrival = 0
    next_cancel = 0
    tick = 0
    t0 = time.perf_counter()
    # one "traffic" region visit spans the whole replay, so monitored runs
    # report the load phase next to the scheduler's prefill/decode/preempt
    # regions (session policy: all instrumentation through PerfSession)
    with sched.session.region("traffic"):
        while tick < budget:
            while (next_arrival < len(workload)
                   and workload[next_arrival].arrival_tick <= tick):
                r = workload[next_arrival]
                sched.submit(list(r.prompt), request_id=r.request_id,
                             max_new=r.max_new, priority=r.priority)
                submit_t[r.request_id] = time.perf_counter()
                next_arrival += 1
            while (next_cancel < len(cancels)
                   and cancels[next_cancel][0] <= tick):
                sched.cancel(cancels[next_cancel][1])
                next_cancel += 1
            done_arriving = next_arrival >= len(workload)
            live = (sched.queue or sched._parked or sched._prefills
                    or any(s is not None for s in sched.active))
            if done_arriving and next_cancel >= len(cancels) and not live:
                break
            sched.step()
            now = time.perf_counter()
            depths.append(len(sched.queue) + len(sched._parked))
            for req in sched.active:
                if req is not None and req["id"] not in ttft:
                    # the request just cleared prefill: its first token is
                    # in flight — TTFT is wall-clock from its submit() call
                    ttft[req["id"]] = now - submit_t[req["id"]]
            tick += 1
        sched.drain()
    wall = time.perf_counter() - t0

    good_tokens = sum(len(r["generated"]) for r in sched.completed)
    cancelled_tokens = sum(len(r["generated"]) for r in sched.cancelled)
    lat = sorted(ttft[r["id"]] for r in sched.completed if r["id"] in ttft)
    stats = sched.kv_cache_stats()
    press = stats.get("pressure", {})
    return {
        "requests": len(workload),
        "completed": len(sched.completed),
        "cancelled": len(sched.cancelled),
        "failed": len(sched.failed),
        "shed": len(sched.shed),
        "ticks": tick,
        "wall_s": round(wall, 4),
        "good_tokens": good_tokens,
        "cancelled_tokens": cancelled_tokens,
        "goodput_tokens_per_sec": round(good_tokens / max(wall, 1e-9), 2),
        "ttft_p50_s": round(_percentile(lat, 50), 4),
        "ttft_p95_s": round(_percentile(lat, 95), 4),
        "ttft_p99_s": round(_percentile(lat, 99), 4),
        "ttft_max_s": round(lat[-1] if lat else 0.0, 4),
        "queue_depth_peak": max(depths, default=0),
        "queue_depth_mean": round(sum(depths) / max(len(depths), 1), 2),
        "preemptions": press.get("preemptions", 0),
        "resumes": press.get("resumes", 0),
        "cancellations": press.get("cancellations", 0),
        "evictions_for_preempt": press.get("evictions_for_preempt", 0),
        "peak_queue_depth": press.get("peak_queue_depth", 0),
        "recovery": stats.get("recovery", {}),
        "kv": stats,
        "sched_stats": dict(sched.stats),
        "generated": {str(r["id"]): r["generated"] for r in sched.completed},
    }


__all__ = ["TrafficConfig", "TrafficRequest", "generate_workload", "replay"]
