"""Normalization layers."""

from __future__ import annotations

import jax.numpy as jnp

from repro.layers.common import ParamSpec


def rmsnorm_params(d: int, name: str = "scale") -> dict:
    return {name: ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(x, scale, eps: float = 1e-6, zero_centered: bool = False):
    """RMSNorm; ``zero_centered`` uses (1+scale) gemma-style."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * (var + eps) ** -0.5
    s = scale.astype(jnp.float32)
    if zero_centered:
        s = 1.0 + s
    return (y * s).astype(dt)


def layernorm_params(d: int) -> dict:
    return {
        "scale": ParamSpec((d,), ("embed",), init="ones"),
        "bias": ParamSpec((d,), ("embed",), init="zeros"),
    }


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * (var + eps) ** -0.5
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)
