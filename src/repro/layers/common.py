"""Parameter declaration + logical sharding substrate.

Flax-free functional module system: a layer declares its parameters as a
pytree of ``ParamSpec`` (shape + *logical* axis names + initializer). The
materializer turns that into (a) an init function and (b) a
``PartitionSpec`` pytree by mapping logical axes to mesh axes through the
arch's sharding rules (distributed/sharding.py). Keeping shardings logical
at the layer level is what lets one model definition serve every
(arch x mesh x strategy) combination in the dry-run.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[Any, ...]  # logical axis name (or None) per dim
    init: str = "normal"      # normal | zeros | ones | scaled
    scale: float | None = None
    dtype: Any = None         # None -> config param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_param_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _tree_map(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_param_spec)


def init_params(tree, key, param_dtype=jnp.float32):
    """Materialize a ParamSpec tree into arrays (deterministic per path)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_param_spec)
    keys = jax.random.split(key, max(len(leaves), 1))

    def make(spec: ParamSpec, k):
        dtype = spec.dtype or param_dtype
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        fan_in = spec.shape[0] if spec.shape else 1
        scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [make(s, k) for s, k in zip(leaves, keys)]
    )


def abstract_params(tree, param_dtype=jnp.float32):
    """ShapeDtypeStruct tree — for dry-run lowering without allocation."""
    return _tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or param_dtype), tree
    )


def param_pspecs(tree, rules: dict[str, Any], mesh=None):
    """Map logical axes -> mesh axes. ``rules[name]`` may be a mesh axis
    name, a tuple of axes, or None (replicated). With ``mesh`` given, each
    dim keeps only the longest prefix of its mapped axes whose product
    divides the dim size (explicit pjit in_shardings require exact
    divisibility — e.g. a (5248,) conv bias cannot shard 256 ways)."""
    sizes = (
        dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else None
    )

    def to_pspec(spec: ParamSpec):
        axes = []
        used: set[str] = set()
        for dim, name in zip(spec.shape, spec.logical):
            ax = rules.get(name) if name is not None else None
            # one mesh axis may appear only once per PartitionSpec
            if ax is not None:
                flat = (ax,) if isinstance(ax, str) else tuple(ax)
                flat = tuple(a for a in flat if a not in used)
                if sizes is not None:
                    keep = []
                    prod = 1
                    for a in flat:
                        nxt = prod * sizes.get(a, 1)
                        if dim % nxt == 0:
                            keep.append(a)
                            prod = nxt
                        else:
                            break
                    flat = tuple(keep)
                used.update(flat)
                ax = flat if flat else None
                if ax is not None and len(ax) == 1:
                    ax = ax[0]
            axes.append(ax)
        return P(*axes)

    return _tree_map(to_pspec, tree)


def count_params(tree) -> int:
    # pure-python product: jnp.prod overflows int32 on billion-param shapes
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=is_param_spec):
        if isinstance(leaf, ParamSpec):
            n = 1
            for d in leaf.shape:
                n *= d
            total += n
        else:
            total += leaf.size
    return total


def spec_bytes(tree, param_dtype=jnp.float32) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=is_param_spec):
        if isinstance(leaf, ParamSpec):
            n = 1
            for d in leaf.shape:
                n *= d
            total += n * jnp.dtype(leaf.dtype or param_dtype).itemsize
    return total


# ---------------------------------------------------------------------------
# activation sharding constraints
# ---------------------------------------------------------------------------


class LogicalConstraints:
    """Applies with_sharding_constraint through logical rules; no-op leaves
    un-mapped axes replicated. Threaded through the model as ``lc``."""

    def __init__(self, mesh, rules: dict[str, Any] | None):
        self.mesh = mesh
        self.rules = rules or {}

    def pspec(self, *logical_axes) -> P:
        axes = []
        used: set[str] = set()
        for name in logical_axes:
            ax = self.rules.get(name) if name is not None else None
            if ax is not None:
                flat = (ax,) if isinstance(ax, str) else tuple(ax)
                flat = tuple(a for a in flat if a not in used)
                used.update(flat)
                ax = (flat if len(flat) > 1 else (flat[0] if flat else None)) or None
            axes.append(ax)
        return P(*axes)

    def __call__(self, x, *logical_axes):
        if self.mesh is None or not self.rules:
            return x
        return jax.lax.with_sharding_constraint(
            x, self.pspec_for(x.shape, *logical_axes)
        )

    def pspec_for(self, shape, *logical_axes) -> P:
        """Shape-aware pspec: per dim, keep the longest prefix of mapped
        mesh axes whose product divides the dim size (batch=32 over a
        ("data","model") mapping degrades to ("data",) instead of failing)."""
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        axes = []
        used: set[str] = set()
        for dim, name in zip(shape, logical_axes):
            ax = self.rules.get(name) if name is not None else None
            if ax is None:
                axes.append(None)
                continue
            flat = (ax,) if isinstance(ax, str) else tuple(ax)
            flat = tuple(a for a in flat if a not in used)
            keep = []
            prod = 1
            for a in flat:
                nxt = prod * sizes.get(a, 1)
                if dim % nxt == 0:
                    keep.append(a)
                    prod = nxt
                else:
                    break
            used.update(keep)
            if not keep:
                axes.append(None)
            elif len(keep) == 1:
                axes.append(keep[0])
            else:
                axes.append(tuple(keep))
        return P(*axes)

    def group_count(self, logical_name: str, dim: int) -> int:
        """Largest product of a prefix of the mapped mesh axes that divides
        ``dim`` (the shape-aware analogue of axis_size; used by MoE grouped
        dispatch so microbatched runs keep per-shard-local sorting)."""
        if self.mesh is None:
            return 1
        ax = self.rules.get(logical_name)
        if ax is None:
            return 1
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        flat = (ax,) if isinstance(ax, str) else tuple(ax)
        prod = 1
        for a in flat:
            nxt = prod * sizes.get(a, 1)
            if dim % nxt == 0:
                prod = nxt
            else:
                break
        return prod

    def axis_size(self, logical_name: str) -> int:
        """Product of mesh-axis sizes a logical axis maps to (1 if unmapped)."""
        if self.mesh is None:
            return 1
        ax = self.rules.get(logical_name)
        if ax is None:
            return 1
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        flat = (ax,) if isinstance(ax, str) else tuple(ax)
        n = 1
        for a in flat:
            n *= sizes.get(a, 1)
        return n


NULL_CONSTRAINTS = LogicalConstraints(None, None)
