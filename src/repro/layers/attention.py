"""Attention: GQA + RoPE + sliding window + softcap; flash-style chunked
computation in pure JAX (bounded memory at 32k+ sequence lengths — also the
oracle for the Pallas flash kernel); KV-cache decode path.

Shapes follow (batch, seq, heads, head_dim) throughout.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.layers.common import LogicalConstraints, NULL_CONSTRAINTS, ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float, rotary_frac: float = 1.0):
    rot = int(head_dim * rotary_frac) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, theta: float = 10000.0, rotary_frac: float = 1.0):
    """x: (B,S,H,D); positions: (B,S) int32. Interleaved-pair convention."""
    d = x.shape[-1]
    inv, rot = rope_frequencies(d, theta, rotary_frac)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    y = jnp.stack([y1, y2], axis=-1).reshape(x.shape[:-1] + (rot,))
    if rot < d:
        y = jnp.concatenate([y, x[..., rot:].astype(jnp.float32)], axis=-1)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# masking
# ---------------------------------------------------------------------------


def block_mask(
    q_pos, k_pos, *, causal: bool, window: int | None, kv_len: Any | None = None
):
    """(…,Sq,Sk) boolean visibility. ``kv_len`` masks unwritten cache slots."""
    m = jnp.ones(q_pos.shape[-1:] + k_pos.shape[-1:], dtype=bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        m = m & (kp <= qp)
    if window is not None and window > 0:
        m = m & (kp > qp - window)
    if kv_len is not None:
        m = m & (k_pos[..., None, :] < kv_len)
    return m


# ---------------------------------------------------------------------------
# flash-style chunked attention (pure JAX)
# ---------------------------------------------------------------------------


def _attend_block(q, k, v, mask, scale, softcap):
    """q:(B,G,Hkv,Sq,D) k:(B,Hkv,Sk,D) v:(B,Hkv,Sk,D) mask:(Sq,Sk) or (B,1,1,Sq,Sk).
    Returns partial (o, m, l) in fp32 with m the TRUE masked row max
    (NEG_INF for fully-masked rows). Returning a 0-sentinel here instead
    poisons the cross-block running max whenever real scores are very
    negative: max(m_true<0, 0)=0 underflows the rescale factor exp(m-0)
    to zero, collapsing l and producing silently-wrong outputs + NaN
    gradients (found via the launcher's NaN at seq>q_chunk). The
    0-sentinel is only safe INSIDE this block as the exp stabilizer."""
    s = jnp.einsum("bghqd,bhkd->bghqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s *= scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)  # (B,G,Hkv,Sq) true masked max
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)  # exp stabilizer only
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bghqk,bhkd->bghqd", p, v.astype(jnp.float32))
    return o, m, l


def flash_attention(
    q, k, v,
    *,
    q_positions, k_positions,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    kv_len=None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    causal_skip: bool = False,
):
    """Online-softmax chunked attention.

    q: (B,Sq,Hq,D); k,v: (B,Sk,Hkv,D); GQA via Hq = G*Hkv.
    ``kv_len``: () or (B,) valid key length (keys at positions >= kv_len are
    masked — the chunked-prefill path attends a prompt chunk against the
    partially written KV cache this way).
    ``causal_skip`` bounds the kv scan per q-chunk (skips fully-future
    blocks) — a beyond-paper compute optimization toggled by the perf pass.
    Returns (B,Sq,Hq,D) in q.dtype.
    """
    B, Sq, Hq, D = q.shape
    if kv_len is not None:
        # normalize to broadcast against the (B,1,1,qc,kc) block mask
        kv_len = jnp.asarray(kv_len).reshape(-1, 1, 1, 1, 1)
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    # pad seq dims to chunk multiples
    q = _pad_axis(q, 1, nq * q_chunk)
    k = _pad_axis(k, 1, nk * kv_chunk)
    v = _pad_axis(v, 1, nk * kv_chunk)
    q_positions = _pad_axis(q_positions, 1, nq * q_chunk, value=-(10**9))
    k_positions = _pad_axis(k_positions, 1, nk * kv_chunk, value=10**9)

    qg = q.reshape(B, nq, q_chunk, Hkv, G, D).transpose(1, 0, 4, 3, 2, 5)  # (nq,B,G,Hkv,qc,D)
    kg = k.reshape(B, nk, kv_chunk, Hkv, D).transpose(1, 0, 3, 2, 4)       # (nk,B,Hkv,kc,D)
    vg = v.reshape(B, nk, kv_chunk, Hkv, D).transpose(1, 0, 3, 2, 4)
    qp = q_positions.reshape(B, nq, q_chunk).transpose(1, 0, 2)            # (nq,B,qc)
    kp = k_positions.reshape(B, nk, kv_chunk).transpose(1, 0, 2)

    def q_body(_, qs):
        qi, qblk, qpos = qs

        @functools.partial(jax.checkpoint, policy=None)
        def kv_step(carry, ki):
            o, m, l = carry
            kblk, vblk, kpos = kg[ki], vg[ki], kp[ki]
            # barrier: stop XLA hoisting the (nq x nk x qc x kc) mask out of
            # both chunk loops (a multi-GB loop-invariant tensor otherwise)
            qpos_b, kpos_b = jax.lax.optimization_barrier((qpos, kpos))
            mask = block_mask(
                qpos_b[:, None, None, :], kpos_b[:, None, None, :],
                causal=causal, window=window, kv_len=kv_len,
            )  # (B,1,1,qc,kc)
            ob, mb, lb = _attend_block(qblk, kblk, vblk, mask, scale, softcap)
            m_new = jnp.maximum(m, mb)
            a = jnp.exp(m - m_new)
            b = jnp.exp(mb - m_new)
            o = o * a[..., None] + ob * b[..., None]
            l = l * a + lb * b
            return (o, m_new, l), None

        o0 = jnp.zeros((B, G, Hkv, q_chunk, D), jnp.float32)
        m0 = jnp.full((B, G, Hkv, q_chunk), NEG_INF)
        l0 = jnp.zeros((B, G, Hkv, q_chunk), jnp.float32)
        (o, m, l), _ = jax.lax.scan(kv_step, (o0, m0, l0), jnp.arange(nk))
        o = o / jnp.maximum(l[..., None], 1e-30)
        return None, o

    if causal_skip and causal and q_chunk == kv_chunk and nq == nk:
        # ---- static causal block skipping ----
        # Enumerate only the visible (qi, ki<=qi) block pairs (and within
        # the sliding window when set). The savings are STATIC: the scan
        # trip count shrinks, so both real hardware and the HLO counter
        # analysis see the reduced compute/traffic (a lax.cond skip would
        # hide it from both the roofline and the MXU pipeline).
        wb = None
        if window is not None and window > 0:
            wb = -(-window // kv_chunk) + 1  # visible kv blocks per q block
        pairs_qi, pairs_ki = [], []
        for qi in range(nq):
            lo = 0 if wb is None else max(0, qi - wb + 1)
            for ki in range(lo, qi + 1):
                pairs_qi.append(qi)
                pairs_ki.append(ki)
        # segment boundaries + final pair indices computed on the python
        # lists (constants may be tracers under jax.checkpoint re-tracing)
        final_idx = [i for i, (q_, k_) in enumerate(zip(pairs_qi, pairs_ki))
                     if k_ == q_]
        seg_start_list = []
        prev = -1
        for q_idx in pairs_qi:
            seg_start_list.append(q_idx != prev)
            prev = q_idx
        seg_start = jnp.asarray(seg_start_list)
        pairs_qi = jnp.asarray(pairs_qi, jnp.int32)
        pairs_ki = jnp.asarray(pairs_ki, jnp.int32)

        def pair_step(carry, inp):
            o, m, l = carry
            qi, ki, start = inp
            qblk, qpos = qg[qi], qp[qi]
            kblk, vblk, kpos = kg[ki], vg[ki], kp[ki]
            o = jnp.where(start, 0.0, o)
            m = jnp.where(start, NEG_INF, m)
            l = jnp.where(start, 0.0, l)
            qpos_b, kpos_b = jax.lax.optimization_barrier((qpos, kpos))
            mask = block_mask(
                qpos_b[:, None, None, :], kpos_b[:, None, None, :],
                causal=causal, window=window, kv_len=kv_len,
            )
            ob, mb, lb = _attend_block(qblk, kblk, vblk, mask, scale, softcap)
            m_new = jnp.maximum(m, mb)
            a = jnp.exp(m - m_new)
            bfac = jnp.exp(mb - m_new)
            o = o * a[..., None] + ob * bfac[..., None]
            l = l * a + lb * bfac
            # emit the normalized block every pair; only the last pair of a
            # segment is kept (static gather below). Carrying the full
            # output array instead would be saved per iteration by the
            # scan's VJP — a 5x traffic regression (measured).
            finished = (o / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
            return (o, m_new, l), finished

        @functools.partial(jax.checkpoint, policy=None)
        def pair_step_ckpt(carry, inp):
            return pair_step(carry, inp)

        o0 = jnp.zeros((B, G, Hkv, q_chunk, D), jnp.float32)
        m0 = jnp.full((B, G, Hkv, q_chunk), NEG_INF)
        l0 = jnp.zeros((B, G, Hkv, q_chunk), jnp.float32)
        _, ys = jax.lax.scan(
            pair_step_ckpt, (o0, m0, l0), (pairs_qi, pairs_ki, seg_start)
        )
        outs = ys[jnp.asarray(final_idx, jnp.int32)]  # (nq, B,G,Hkv,qc,D)
        out = outs.transpose(1, 0, 4, 3, 2, 5).reshape(B, nq * q_chunk, Hq, D)
        return out[:, :Sq].astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), qg, qp))
    # (nq,B,G,Hkv,qc,D) -> (B, nq*qc, Hkv*G, D)
    out = outs.transpose(1, 0, 4, 3, 2, 5).reshape(B, nq * q_chunk, Hq, D)
    return out[:, :Sq].astype(q.dtype)


def _pad_axis(x, axis, size, value=0):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def decode_attention_paged(
    q, k_pages, v_pages, block_tables, *, q_position, cache_len,
    window: int | None = None, softcap: float | None = None,
    impl: str = "auto",
):
    """Single-position attention against a paged KV pool.

    q: (B,1,Hq,D); k_pages/v_pages: (P, page_size, Hkv, D) shared pool;
    block_tables: (B, n_logical) int32 — logical page j of slot b lives in
    physical page ``block_tables[b, j]`` (-1 = unallocated). Routed through
    ``repro.kernels.paged_attention`` (Pallas on TPU, gather oracle
    elsewhere); the reference path is bitwise identical to
    ``decode_attention`` over the equivalent dense cache."""
    from repro.kernels.paged_attention.ops import paged_attention

    return paged_attention(
        q, k_pages, v_pages, block_tables,
        q_position=q_position, cache_len=cache_len,
        window=window, softcap=softcap, impl=impl,
    )


def decode_attention(
    q, k_cache, v_cache, *, q_position, cache_len,
    window: int | None = None, softcap: float | None = None,
):
    """Single-position attention against a cache.

    q: (B,1,Hq,D); caches: (B,Smax,Hkv,D); cache_len: () or (B,) valid length
    (positions [0, cache_len) are real; q_position = cache_len typically).
    """
    B, _, Hq, D = q.shape
    _, Sk, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, 1, Hkv, G, D).transpose(0, 3, 2, 1, 4)  # (B,G,Hkv,1,D)
    kg = k_cache.transpose(0, 2, 1, 3)  # (B,Hkv,Sk,D)
    vg = v_cache.transpose(0, 2, 1, 3)
    s = jnp.einsum("bghqd,bhkd->bghqk", qg.astype(jnp.float32), kg.astype(jnp.float32))
    s *= scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(Sk)[None, None, None, None, :]
    qpos = jnp.asarray(q_position).reshape(-1, 1, 1, 1, 1)
    mask = kpos < jnp.asarray(cache_len).reshape(-1, 1, 1, 1, 1)
    if window is not None and window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bghqk,bhkd->bghqd", p, vg.astype(jnp.float32))
    return o.transpose(0, 3, 2, 1, 4).reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# the attention block (projections + rope + attend)
# ---------------------------------------------------------------------------


def attention_params(cfg) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    p = {
        "wq": ParamSpec((d, hq * hd), ("embed", "qkv")),
        "wk": ParamSpec((d, hkv * hd), ("embed", "kv")),
        "wv": ParamSpec((d, hkv * hd), ("embed", "kv")),
        "wo": ParamSpec(
            (hq * hd, d), ("qkv", "embed_out"),
            scale=1.0 / (math.sqrt(hq * hd) * math.sqrt(2 * cfg.n_layers)),
        ),
    }
    if cfg.attn_bias:
        p["bq"] = ParamSpec((hq * hd,), ("qkv",), init="zeros")
        p["bk"] = ParamSpec((hkv * hd,), ("kv",), init="zeros")
        p["bv"] = ParamSpec((hkv * hd,), ("kv",), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        p["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return p


def attention_block(
    params, x, cfg, *,
    positions, lc: LogicalConstraints = NULL_CONSTRAINTS,
    causal=True, window=None, cache=None, cache_len=None,
    seq_mask=None, cache_attend=False, block_tables=None,
):
    """Returns (out, new_cache). ``cache``: dict(k=(B,Smax,Hkv,D), v=...),
    dict(k_pages=(P,page,Hkv,D), v_pages=...) for the paged layout (then
    ``block_tables`` (B, n_logical) maps each row's logical pages to
    physical pool pages), or None for full-sequence (training / prefill
    without cache) mode.

    ``positions`` is (B,S) and doubles as the per-slot cache write index —
    each batch row writes its k/v at its own offsets (continuous batching:
    slots sit at different sequence positions). ``seq_mask`` (B,S) bool
    suppresses cache writes for masked entries (padding in a prefill chunk,
    inactive slots in a batched decode step). ``cache_attend`` switches the
    S>1 path from in-chunk attention (full prefill from position 0) to
    attending against the whole written cache (chunked prefill continuing
    at positions[:,0] > 0 — earlier chunks live in the cache)."""
    from repro.layers.norms import rmsnorm

    B, S, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    compute = cfg.compute_dtype

    q = (x @ params["wq"].astype(compute)).reshape(B, S, hq, hd)
    k = (x @ params["wk"].astype(compute)).reshape(B, S, hkv, hd)
    v = (x @ params["wv"].astype(compute)).reshape(B, S, hkv, hd)
    if cfg.attn_bias:
        q = q + params["bq"].reshape(1, 1, hq, hd).astype(compute)
        k = k + params["bk"].reshape(1, 1, hkv, hd).astype(compute)
        v = v + params["bv"].reshape(1, 1, hkv, hd).astype(compute)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if cfg.rope_theta:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary)
    q = lc(q, "batch", "seq_q", "heads", None)
    k = lc(k, "batch", "seq_kv", "kv_heads", None)
    v = lc(v, "batch", "seq_kv", "kv_heads", None)

    new_cache = None
    if cache is not None and "k_pages" in cache:
        # paged layout: write through the block table into the shared pool,
        # then attend through the table. Rows own disjoint physical pages
        # (allocator invariant), so the flattened-pool scatter cannot
        # collide across slots; entries that are masked, unallocated
        # (table -1) or out of logical range push their write index past
        # the pool end and are dropped.
        k_pool, v_pool = cache["k_pages"], cache["v_pages"]
        Pp, psize = k_pool.shape[0], k_pool.shape[1]
        n_logical = block_tables.shape[1]
        pos0 = positions[:, 0] if positions.ndim == 2 else positions
        page_idx = positions // psize
        phys_page = jnp.take_along_axis(
            block_tables, jnp.clip(page_idx, 0, n_logical - 1), axis=1
        )
        flat_pos = phys_page * psize + positions % psize
        valid = (phys_page >= 0) & (page_idx < n_logical)
        if seq_mask is not None:
            valid &= seq_mask
        write_idx = jnp.where(valid, flat_pos, Pp * psize)
        kc = k_pool.reshape(Pp * psize, hkv, hd).at[write_idx].set(
            k.astype(k_pool.dtype), mode="drop"
        )
        vc = v_pool.reshape(Pp * psize, hkv, hd).at[write_idx].set(
            v.astype(v_pool.dtype), mode="drop"
        )
        new_cache = {
            "k_pages": kc.reshape(k_pool.shape),
            "v_pages": vc.reshape(v_pool.shape),
        }
        if S == 1:
            o = decode_attention_paged(
                q, new_cache["k_pages"], new_cache["v_pages"], block_tables,
                q_position=pos0, cache_len=cache_len,
                window=window, softcap=cfg.attn_softcap,
                impl=cfg.paged_attn_impl,
            )
        else:
            # chunked prefill: attend the block table directly (multi-token
            # paged read — Pallas streams just the slot's pages on TPU; the
            # reference path gathers and runs the dense cache_attend flash
            # verbatim, keeping paged-vs-dense tokens bitwise identical)
            from repro.kernels.paged_attention.ops import (
                paged_prefill_attention,
            )

            o = paged_prefill_attention(
                q, new_cache["k_pages"], new_cache["v_pages"], block_tables,
                q_positions=positions, cache_len=cache_len,
                causal=causal, window=window, softcap=cfg.attn_softcap,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                impl=cfg.paged_attn_impl,
            )
    elif cache is not None:
        # write current k/v at each row's own positions, then attend against
        # the cache. A masked (B,S) scatter replaces the old scalar
        # dynamic_update_slice: slots at different positions write to
        # different offsets in ONE op, and masked entries (padding /
        # inactive decode slots) are dropped instead of scribbling on live
        # cache lines (write index pushed out of bounds + mode="drop").
        Smax = cache["k"].shape[1]
        pos0 = positions[:, 0] if positions.ndim == 2 else positions
        write_pos = positions
        if seq_mask is not None:
            write_pos = jnp.where(seq_mask, positions, Smax)
        b_idx = jnp.arange(B)[:, None]
        kc = cache["k"].at[b_idx, write_pos].set(
            k.astype(cache["k"].dtype), mode="drop"
        )
        vc = cache["v"].at[b_idx, write_pos].set(
            v.astype(cache["v"].dtype), mode="drop"
        )
        new_cache = {"k": kc, "v": vc}
        if S == 1:
            o = decode_attention(
                q, kc, vc, q_position=pos0, cache_len=cache_len,
                window=window, softcap=cfg.attn_softcap,
            )
        elif cache_attend:
            # chunked prefill: this chunk's queries see every cache line
            # written so far (earlier chunks + this one), bounded by
            # cache_len, under the usual causal/window visibility
            k_positions = jnp.broadcast_to(jnp.arange(Smax)[None, :], (B, Smax))
            o = flash_attention(
                q, kc, vc, q_positions=positions, k_positions=k_positions,
                causal=causal, window=window, softcap=cfg.attn_softcap,
                kv_len=cache_len,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                causal_skip=False,
            )
        else:  # full prefill from position 0: in-chunk attention
            o = flash_attention(
                q, k, v, q_positions=positions,
                k_positions=positions, causal=causal, window=window,
                softcap=cfg.attn_softcap,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                causal_skip=cfg.causal_skip,
            )
    else:
        o = flash_attention(
            q, k, v, q_positions=positions, k_positions=positions,
            causal=causal, window=window, softcap=cfg.attn_softcap,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            causal_skip=cfg.causal_skip,
        )
    o = lc(o, "batch", "seq_q", "heads", None)
    out = o.reshape(B, S, hq * hd) @ params["wo"].astype(compute)
    return out, new_cache
