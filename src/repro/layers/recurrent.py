"""Recurrent sequence mixers: Mamba2 (SSD) and xLSTM (mLSTM / sLSTM).

Both implement the *chunkwise-parallel* training form (quadratic only within
a chunk, linear across chunks — the property that makes long_500k feasible)
plus a single-token recurrent form for decode. The chunkwise and recurrent
forms are cross-validated in tests (same output up to fp tolerance).

TPU adaptation notes (DESIGN.md §3/§5):
  * channels/heads are independent -> the inner dim shards over the
    "model" mesh axis with zero intra-scan communication (the SSM analogue
    of tensor parallelism);
  * chunk length is MXU-friendly (128/256) so the intra-chunk einsums hit
    the systolic array;
  * xLSTM stabilizers follow the exponent-shift formulation (running max
    carried across chunks).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.layers.common import LogicalConstraints, NULL_CONSTRAINTS, ParamSpec


def _segsum(x):
    """x: (..., L). Returns (..., L, L) with out[i,j] = sum_{k=j+1..i} x_k
    for i >= j, -inf otherwise (log-space causal decay matrix)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def causal_conv1d(x, w, b=None, state=None, valid_len=None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). ``state``: (B,K-1,C)
    carry for decode; returns (y, new_state).

    ``valid_len``: (B,) number of valid leading positions per row (the rest
    of ``x`` is padding). The carried-out state then ends at each row's own
    valid end instead of the padded end, so a padded prefill chunk leaves
    exactly the state a tight chunk would have left (valid_len == 0 keeps
    the incoming state untouched — frozen inactive decode slots)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    if b is not None:
        y = y + b[None, None, :]
    if K <= 1:
        return y, None
    if valid_len is None:
        new_state = xp[:, -(K - 1):, :]
    else:
        # last K-1 positions of each row's valid history: xp[l : l+K-1]
        # (xp = [K-1 carried/padded] + [x], so valid history ends at K-1+l)
        idx = valid_len[:, None] + jnp.arange(K - 1)[None, :]
        new_state = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    return y, new_state


# ===========================================================================
# Mamba2 / SSD
# ===========================================================================


def mamba2_params(cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.d_inner(d)
    h = s.n_heads(d)
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return {
        "in_proj": ParamSpec(
            (d, 2 * d_inner + 2 * s.n_groups * s.d_state + h), ("embed", "inner_all")
        ),
        "conv_w": ParamSpec((s.d_conv, conv_ch), (None, "inner"), scale=0.5),
        "conv_b": ParamSpec((conv_ch,), ("inner",), init="zeros"),
        "A_log": ParamSpec((h,), ("ssm_heads",), init="ones"),
        "D": ParamSpec((h,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "norm": ParamSpec((d_inner,), ("inner",), init="ones"),
        "out_proj": ParamSpec(
            (d_inner, d), ("inner", "embed_out"),
            scale=1.0 / (math.sqrt(d_inner) * math.sqrt(2 * cfg.n_layers)),
        ),
    }


def _ssd_chunked(x, dt, A, B, C, chunk, init_state=None):
    """SSD chunkwise scan.

    x: (b,s,h,p)  dt: (b,s,h)  A: (h,) negative  B,C: (b,s,g,n)
    Returns (y: (b,s,h,p), final_state: (b,h,p,n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[-2], B.shape[-1]
    rep = h // g
    L = min(chunk, s)
    nc = s // L
    assert nc * L == s, (s, L)

    xc = x.reshape(b, nc, L, h, p)
    dtc = dt.reshape(b, nc, L, h)
    Bc = jnp.repeat(B.reshape(b, nc, L, g, n), rep, axis=3)  # (b,nc,L,h,n)
    Cc = jnp.repeat(C.reshape(b, nc, L, g, n), rep, axis=3)

    dA = dtc * A[None, None, None, :]              # (b,nc,L,h) log decay
    dA_cs = jnp.cumsum(dA, axis=2)                 # within-chunk cumulative

    # intra-chunk (quadratic in L)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))          # (b,nc,h,L,L)
    scores = jnp.einsum("bclhn,bcjhn->bchlj", Cc, Bc) * Lmat
    y_intra = jnp.einsum("bchlj,bcjh,bcjhp->bclhp", scores, dtc, xc)

    # per-chunk summary state: S_c = sum_j exp(dA_end - dA_j) dt_j B_j x_j
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)        # (b,nc,L,h)
    S_chunk = jnp.einsum("bcjh,bcjh,bcjhn,bcjhp->bchpn",
                         decay_to_end, dtc, Bc, xc)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                  # (b,nc,h)

    # inter-chunk recurrence
    def step(state, inp):
        S_c, dec, Cc_c, dA_cs_c = inp
        # contribution of the carried state to this chunk's outputs
        decay_in = jnp.exp(dA_cs_c)                            # (b,L,h)
        y_prev = jnp.einsum("blhn,blh,bhpn->blhp", Cc_c, decay_in, state)
        state = state * dec[..., None, None] + S_c
        return state, y_prev

    state0 = (
        init_state
        if init_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    xs = (
        S_chunk.transpose(1, 0, 2, 3, 4),
        chunk_decay.transpose(1, 0, 2),
        Cc.transpose(1, 0, 2, 3, 4),
        dA_cs.transpose(1, 0, 2, 3),
    )
    final_state, y_prev = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    y = y_intra + y_prev.transpose(1, 0, 2, 3, 4).reshape(b, nc, L, h, p)
    return y.reshape(b, s, h, p), final_state


def mamba2_block(
    params, x, cfg, lc: LogicalConstraints = NULL_CONSTRAINTS, cache=None,
    seq_mask=None,
):
    """Returns (out, new_cache). cache: {"conv": (B,K-1,C), "ssm": (B,h,p,n)}.

    ``seq_mask`` (B,S) bool marks valid positions; masked positions advance
    neither the conv nor the SSM state (dt is zeroed, so the decay is
    exp(0)=1 and the input contribution 0 — exact state freeze). Used by
    chunked prefill padding and inactive continuous-batching decode slots."""
    s = cfg.ssm
    Bsz, S, d = x.shape
    d_inner = s.d_inner(d)
    h = s.n_heads(d)
    p = s.head_dim
    g, n = s.n_groups, s.d_state
    compute = cfg.compute_dtype

    proj = x @ params["in_proj"].astype(compute)
    z, xconv_in, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner + 2 * g * n], axis=-1
    )
    conv_state = cache["conv"] if cache is not None else None
    xconv, new_conv = causal_conv1d(
        xconv_in, params["conv_w"].astype(compute),
        params["conv_b"].astype(compute), state=conv_state,
        valid_len=None if seq_mask is None else jnp.sum(seq_mask, axis=1),
    )
    xconv = jax.nn.silu(xconv)
    xs, B_, C_ = jnp.split(xconv, [d_inner, d_inner + g * n], axis=-1)
    xs = lc(xs, "batch", None, "inner").reshape(Bsz, S, h, p)
    B_ = B_.reshape(Bsz, S, g, n).astype(jnp.float32)
    C_ = C_.reshape(Bsz, S, g, n).astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B,S,h)
    if seq_mask is not None:
        dt = dt * seq_mask[..., None]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (h,) negative

    if cache is not None and S == 1:
        # recurrent single step
        state = cache["ssm"].astype(jnp.float32)  # (B,h,p,n)
        dA = jnp.exp(dt[:, 0, :] * A[None, :])    # (B,h)
        Bh = jnp.repeat(B_[:, 0], h // g, axis=1)  # (B,h,n)
        Ch = jnp.repeat(C_[:, 0], h // g, axis=1)
        xf = xs[:, 0].astype(jnp.float32)         # (B,h,p)
        state = state * dA[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt[:, 0], Bh, xf
        )
        y = jnp.einsum("bhn,bhpn->bhp", Ch, state)[:, None]  # (B,1,h,p)
        new_ssm = state
    else:
        init = cache["ssm"].astype(jnp.float32) if cache is not None else None
        y, new_ssm = _ssd_chunked(
            xs.astype(jnp.float32), dt, A, B_, C_, chunk=s.chunk, init_state=init
        )
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_inner).astype(compute)

    # gated RMSNorm (mamba2 style)
    from repro.layers.norms import rmsnorm

    y = y * jax.nn.silu(z)
    y = rmsnorm(y, params["norm"], cfg.norm_eps)
    y = lc(y, "batch", None, "inner")
    out = y @ params["out_proj"].astype(compute)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": new_ssm}
    return out, new_cache


def mamba2_cache(cfg, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_inner = s.d_inner(cfg.d_model)
    h = s.n_heads(cfg.d_model)
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, h, s.head_dim, s.d_state), jnp.float32),
    }


# ===========================================================================
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory)
# ===========================================================================


def mlstm_params(cfg) -> dict:
    x = cfg.xlstm
    d = cfg.d_model
    di = x.d_inner(d)
    h = cfg.n_heads
    return {
        "up_proj": ParamSpec((d, 2 * di), ("embed", "inner_all")),
        "conv_w": ParamSpec((x.d_conv, di), (None, "inner"), scale=0.5),
        "conv_b": ParamSpec((di,), ("inner",), init="zeros"),
        "wq": ParamSpec((di, di), ("inner", "inner_q")),
        "wk": ParamSpec((di, di), ("inner", "inner_q")),
        "wv": ParamSpec((di, di), ("inner", "inner_q")),
        "w_if": ParamSpec((di, 2 * h), ("inner", None), scale=0.02),
        "b_i": ParamSpec((h,), (None,), init="zeros"),
        "b_f": ParamSpec((h,), (None,), init="ones"),  # forget-bias init > 0
        "norm": ParamSpec((di,), ("inner",), init="ones"),
        "down_proj": ParamSpec(
            (di, d), ("inner", "embed_out"),
            scale=1.0 / (math.sqrt(di) * math.sqrt(2 * cfg.n_layers)),
        ),
    }


def _mlstm_chunked(q, k, v, log_i, log_f, chunk, init=None):
    """Stabilized chunkwise mLSTM.

    q,k,v: (b,s,h,p); log_i/log_f: (b,s,h). Returns (y, (C,n,m) final).
    Linear-attention-with-gates; stabilizer m = running max exponent.
    """
    b, s, h, p = q.shape
    L = min(chunk, s)
    nc = s // L
    qc = q.reshape(b, nc, L, h, p)
    kc = k.reshape(b, nc, L, h, p)
    vc = v.reshape(b, nc, L, h, p)
    li = log_i.reshape(b, nc, L, h)
    lf = log_f.reshape(b, nc, L, h)
    lf_cs = jnp.cumsum(lf, axis=2)                          # (b,nc,L,h)

    # log weight of source j surviving to target t within chunk:
    # D[t,j] = sum_{k=j+1..t} lf_k + li_j
    D = _segsum(lf.transpose(0, 1, 3, 2)) + li.transpose(0, 1, 3, 2)[:, :, :, None, :]
    # (b,nc,h,L,L) log-space

    if init is None:
        C0 = jnp.zeros((b, h, p, p), jnp.float32)
        n0 = jnp.zeros((b, h, p), jnp.float32)
        m0 = jnp.full((b, h), -jnp.inf)
    else:
        C0, n0, m0 = init

    def step(carry, inp):
        C, n, m = carry
        qq, kk, vv, DD, lf_cs_c, li_c = inp
        # inter: carried state contributes with decay exp(lf_cs) relative to m
        b_decay = lf_cs_c  # (b,L,h) log decay from chunk start to t
        # stabilizer for this chunk: max over (m + decay, max_j D[t,j])
        m_intra = jnp.max(DD, axis=-1)                      # (b,h,L)
        m_new_t = jnp.maximum(
            m[:, :, None] + b_decay.transpose(0, 2, 1), m_intra
        )  # (b,h,L)
        # intra contribution
        w_intra = jnp.exp(DD - m_new_t[..., None])          # (b,h,L,L)
        s_qk = jnp.einsum("blhp,bjhp->bhlj", qq, kk) / math.sqrt(p)
        y_num = jnp.einsum("bhlj,bhlj,bjhp->blhp", s_qk, w_intra, vv)
        y_den = jnp.einsum("bhlj,bhlj->bhl", s_qk, w_intra)
        # inter contribution
        w_inter = jnp.exp(m[:, :, None] + b_decay.transpose(0, 2, 1) - m_new_t)
        y_num = y_num + jnp.einsum(
            "blhp,bhl,bhpo->blho", qq, w_inter, C
        ) / math.sqrt(p)
        y_den = y_den + jnp.einsum("blhp,bhl,bhp->bhl", qq, w_inter, n) / math.sqrt(p)
        den = jnp.maximum(jnp.abs(y_den), jnp.exp(-m_new_t))  # xlstm denom floor
        y = y_num / den.transpose(0, 2, 1)[..., None]
        # state update to end of chunk
        tot = lf_cs_c[:, -1, :]                              # (b,h)
        m_end = jnp.maximum(m + tot, jnp.max(DD[:, :, -1, :], axis=-1))
        # source weights surviving to chunk end
        w_end = jnp.exp(
            (lf_cs_c[:, -1:, :] - lf_cs_c + li_c) - m_end[:, None, :]
        )  # (b,L,h)
        C = C * jnp.exp(m + tot - m_end)[..., None, None] + jnp.einsum(
            "blh,blhp,blho->bhpo", w_end, kk, vv
        )
        n = n * jnp.exp(m + tot - m_end)[..., None] + jnp.einsum(
            "blh,blhp->bhp", w_end, kk
        )
        return (C, n, m_end), y

    xs = (
        qc.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
        kc.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
        vc.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
        D.transpose(1, 0, 2, 3, 4),
        lf_cs.transpose(1, 0, 2, 3),
        li.transpose(1, 0, 2, 3),
    )
    (C, n, m), ys = jax.lax.scan(step, (C0, n0, m0), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, (C, n, m)


def mlstm_block(
    params, x, cfg, lc: LogicalConstraints = NULL_CONSTRAINTS, cache=None,
    seq_mask=None,
):
    """``seq_mask`` (B,S): masked positions get input gate -inf and forget
    gate 0 (log-space), so (C, n, m) pass through unchanged — exact state
    freeze for chunk padding / inactive decode slots."""
    xl = cfg.xlstm
    B, S, d = x.shape
    di = xl.d_inner(d)
    h = cfg.n_heads
    p = di // h
    compute = cfg.compute_dtype

    up = x @ params["up_proj"].astype(compute)
    xm, z = jnp.split(up, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = causal_conv1d(
        xm, params["conv_w"].astype(compute), params["conv_b"].astype(compute),
        state=conv_state,
        valid_len=None if seq_mask is None else jnp.sum(seq_mask, axis=1),
    )
    xc = jax.nn.silu(xc)
    q = (xc @ params["wq"].astype(compute)).reshape(B, S, h, p)
    k = (xc @ params["wk"].astype(compute)).reshape(B, S, h, p)
    v = (xm @ params["wv"].astype(compute)).reshape(B, S, h, p)
    gates = xm @ params["w_if"].astype(compute)
    gi, gf = jnp.split(gates.astype(jnp.float32), 2, axis=-1)  # (B,S,h)
    log_i = gi + params["b_i"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(gf + params["b_f"].astype(jnp.float32))
    if seq_mask is not None:
        m3 = seq_mask[..., None]
        log_i = jnp.where(m3, log_i, -1e30)  # no input at masked positions
        log_f = jnp.where(m3, log_f, 0.0)    # and no decay: state passes through

    if cache is not None and S == 1:
        C, n, m = cache["C"], cache["n"], cache["m"]
        li, lf = log_i[:, 0], log_f[:, 0]                    # (B,h)
        m_new = jnp.maximum(lf + m, li)
        C = C * jnp.exp(lf + m - m_new)[..., None, None] + jnp.exp(li - m_new)[
            ..., None, None
        ] * jnp.einsum("bhp,bho->bhpo", k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32))
        n = n * jnp.exp(lf + m - m_new)[..., None] + jnp.exp(li - m_new)[
            ..., None
        ] * k[:, 0].astype(jnp.float32)
        qf = q[:, 0].astype(jnp.float32) / math.sqrt(p)
        num = jnp.einsum("bhp,bhpo->bho", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", qf, n)), jnp.exp(-m_new))
        y = (num / den[..., None])[:, None]                  # (B,1,h,p)
        if seq_mask is not None:
            # gate masking alone leaks into C/n when m is still at its
            # -1e30 init (exp(li - m_new) == 1 there): freeze explicitly
            keep = seq_mask[:, 0]
            C = jnp.where(keep[:, None, None, None], C, cache["C"])
            n = jnp.where(keep[:, None, None], n, cache["n"])
            m_new = jnp.where(keep[:, None], m_new, cache["m"])
        new_state = (C, n, m_new)
    else:
        init = (cache["C"], cache["n"], cache["m"]) if cache is not None else None
        y, new_state = _mlstm_chunked(q, k, v, log_i, log_f, xl.chunk, init=init)

    from repro.layers.norms import rmsnorm

    y = y.reshape(B, S, di).astype(compute)
    y = rmsnorm(y, params["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    y = lc(y, "batch", None, "inner")
    out = y @ params["down_proj"].astype(compute)
    new_cache = None
    if cache is not None:
        new_cache = {
            "conv": new_conv.astype(cache["conv"].dtype),
            "C": new_state[0], "n": new_state[1], "m": new_state[2],
        }
    return out, new_cache


def mlstm_cache(cfg, batch: int, dtype) -> dict:
    xl = cfg.xlstm
    di = xl.d_inner(cfg.d_model)
    h = cfg.n_heads
    p = di // h
    return {
        "conv": jnp.zeros((batch, xl.d_conv - 1, di), dtype),
        "C": jnp.zeros((batch, h, p, p), jnp.float32),
        "n": jnp.zeros((batch, h, p), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_params(cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    return {
        "w_in": ParamSpec((d, 4 * d), ("embed", "inner_all")),  # i,f,z,o pre-acts
        "r": ParamSpec((h, d // h, 4 * (d // h)), (None, None, None), scale=0.02),
        "b": ParamSpec((4 * d,), (None,), init="zeros"),
        "norm": ParamSpec((d,), ("embed",), init="ones"),
    }


def slstm_cell(carry, w, h_heads, d_head):
    """One sLSTM step. carry: (c,n,hprev,m) each (B,h,dh); w: (B,4*d)."""
    c, n, hprev, m = carry
    B = w.shape[0]
    nh = h_heads
    wi, wf, wz, wo = jnp.split(w, 4, axis=-1)

    def heads(t):
        return t.reshape(B, nh, d_head)

    i_t = heads(wi).astype(jnp.float32)
    f_t = heads(wf).astype(jnp.float32)
    z_t = jnp.tanh(heads(wz).astype(jnp.float32))
    o_t = jax.nn.sigmoid(heads(wo).astype(jnp.float32))
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c = f_p * c + i_p * z_t
    n = f_p * n + i_p
    h_new = o_t * c / jnp.maximum(n, 1e-6)
    return (c, n, h_new, m_new)


def slstm_block(
    params, x, cfg, lc: LogicalConstraints = NULL_CONSTRAINTS, cache=None,
    seq_mask=None,
):
    """``seq_mask`` (B,S): the cell carry passes through unchanged at masked
    positions (chunk padding / inactive decode slots)."""
    B, S, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    compute = cfg.compute_dtype
    w_all = x @ params["w_in"].astype(compute) + params["b"].astype(compute)

    if cache is not None:
        carry0 = (cache["c"], cache["n"], cache["h"], cache["m"])
    else:
        zeros = jnp.zeros((B, nh, dh), jnp.float32)
        carry0 = (zeros, zeros, zeros, jnp.full((B, nh, dh), -1e30))

    r = params["r"].astype(jnp.float32)

    def advance(carry, w_t):
        _, _, hprev, _ = carry
        rec = jnp.einsum("bhd,hdk->bhk", hprev, r).reshape(B, 4 * d)
        return slstm_cell(carry, w_t.astype(jnp.float32) + rec, nh, dh)

    def step(carry, inp):
        if seq_mask is None:
            carry = advance(carry, inp)
        else:
            w_t, keep = inp
            new = advance(carry, w_t)
            keep = keep[:, None, None]
            carry = tuple(jnp.where(keep, nw, od) for nw, od in zip(new, carry))
        return carry, carry[2]

    xs = w_all.transpose(1, 0, 2)
    if seq_mask is not None:
        xs = (xs, seq_mask.transpose(1, 0))
    if S == 1 and cache is not None:
        carry, h_seq = step(carry0, jax.tree_util.tree_map(lambda t: t[0], xs))
        ys = h_seq[:, None]                                  # (B,1,nh,dh)
    else:
        carry, hs = jax.lax.scan(step, carry0, xs)
        ys = hs.transpose(1, 0, 2, 3)                        # (B,S,nh,dh)

    from repro.layers.norms import rmsnorm

    y = rmsnorm(ys.reshape(B, S, d).astype(compute), params["norm"], cfg.norm_eps)
    new_cache = None
    if cache is not None:
        new_cache = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    return y, new_cache


def slstm_cache(cfg, batch: int) -> dict:
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, nh, dh), -1e30)}
