"""Feed-forward layers: dense (SwiGLU/GeGLU/GELU) and Mixture-of-Experts.

MoE uses sort-based capacity dispatch (MegaBlocks/MaxText-style, adapted to
a dense-shape TPU formulation):
  router top-k -> flatten (token, expert) pairs -> sort by expert ->
  scatter into a per-expert capacity buffer (E, C, d) -> batched expert
  matmuls (einsum over the expert dim, sharded over the "expert" logical
  axis = EP) -> combine with routing weights.

Dropped tokens (beyond capacity) fall through via the residual connection —
the paper-standard "token dropping" behaviour; capacity_factor controls it.
The router also returns per-expert token counts: the monitor's
**expert load-balance** factor (DESIGN.md §3) reads exactly this.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.layers.common import LogicalConstraints, NULL_CONSTRAINTS, ParamSpec


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------


def mlp_params(cfg, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.act in ("swiglu", "geglu"):
        return {
            "wi_gate": ParamSpec((d, f), ("embed", "mlp")),
            "wi_up": ParamSpec((d, f), ("embed", "mlp")),
            "wo": ParamSpec((f, d), ("mlp", "embed_out"),
                            scale=1.0 / (math.sqrt(f) * math.sqrt(2 * cfg.n_layers))),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed_out"),
                        scale=1.0 / (math.sqrt(f) * math.sqrt(2 * cfg.n_layers))),
    }


def _act(name: str):
    return {
        "swiglu": jax.nn.silu,
        "geglu": lambda x: jax.nn.gelu(x, approximate=True),
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def mlp_block(params, x, cfg, lc: LogicalConstraints = NULL_CONSTRAINTS):
    compute = cfg.compute_dtype
    act = _act(cfg.act)
    if "wi_gate" in params:
        g = x @ params["wi_gate"].astype(compute)
        u = x @ params["wi_up"].astype(compute)
        h = act(g) * u
    else:
        h = act(x @ params["wi"].astype(compute))
    h = lc(h, "batch", "seq_mlp", "mlp")
    return h @ params["wo"].astype(compute)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def moe_params(cfg) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff, m.n_experts
    p = {
        "router": ParamSpec((d, e), ("embed", None), scale=0.02),
        "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wi_up": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed_out"),
                        scale=1.0 / (math.sqrt(f) * math.sqrt(2 * cfg.n_layers))),
    }
    if m.n_shared_experts:
        p["shared"] = mlp_params(cfg, d_ff=m.d_ff * m.n_shared_experts)
    return p


def router_topk(logits, k: int, normalize: bool):
    """logits: (N,E) fp32. Returns (weights (N,k), experts (N,k))."""
    weights, experts = jax.lax.top_k(logits, k)
    if normalize:
        weights = jax.nn.softmax(weights, axis=-1)
    else:
        weights = jax.nn.softmax(logits, axis=-1)
        weights = jnp.take_along_axis(weights, experts, axis=-1)
    return weights, experts


def _dispatch_group(xt, logits, E, K, C, normalize, compute):
    """Dispatch one token group (runs under vmap over groups).

    xt: (n, d); logits: (n, E). Returns (xbuf (E,C,d), st, sw, keep, slot,
    expert_counts) — everything needed to combine after expert compute.
    """
    n = xt.shape[0]
    weights, experts = router_topk(logits, K, normalize)   # (n,K)
    pair_expert = experts.reshape(-1)                      # (n*K,)
    pair_token = jnp.repeat(jnp.arange(n), K)
    pair_weight = weights.reshape(-1)
    order = jnp.argsort(pair_expert)                       # local sort only
    se, st, sw = pair_expert[order], pair_token[order], pair_weight[order]
    # position within expert segment (arange/bincount formulation: cumsum-of-
    # ones and searchsorted lower to giant reduce-windows at scale)
    expert_counts = jnp.zeros((E,), jnp.int32).at[pair_expert].add(1)
    first_idx = jnp.cumsum(expert_counts) - expert_counts
    pos_in_expert = jnp.arange(n * K, dtype=jnp.int32) - first_idx[se]
    keep = pos_in_expert < C
    slot = se * C + jnp.where(keep, pos_in_expert, 0)
    src = xt[st].astype(compute) * keep[:, None].astype(compute)
    xbuf = jnp.zeros((E * C, xt.shape[1]), compute).at[slot].add(src)
    return xbuf.reshape(E, C, -1), st, sw, keep, slot, expert_counts


def moe_block(params, x, cfg, lc: LogicalConstraints = NULL_CONSTRAINTS):
    """x: (B,S,d). Returns (out, aux) with aux["expert_load"]: (E,) counts.

    GShard-style grouped dispatch: tokens are split into G groups aligned
    with the data shards; sort/scatter stay *within* a group (no cross-shard
    sort), and the only cross-device movement is the (G, E, C, d) buffer
    resharding from group-major (data) to expert-major (model) — the MoE
    all-to-all, inserted by GSPMD from the sharding constraints.
    """
    m = cfg.moe
    B, S, d = x.shape
    N = B * S
    E, K = m.n_experts, m.top_k
    compute = cfg.compute_dtype

    G = lc.group_count("batch", B)
    n_loc = N // G
    C = m.capacity(n_loc)

    xt = lc(x, "batch", None, None).reshape(G, n_loc, d)
    xt = lc(xt, "batch", None, None)
    logits = (xt @ params["router"].astype(compute)).astype(jnp.float32)

    xbuf, st, sw, keep, slot, counts = jax.vmap(
        lambda xg, lg: _dispatch_group(xg, lg, E, K, C, m.normalize_topk, compute)
    )(xt, logits)
    # dispatch all-to-all: group-major -> expert-major
    xbuf = lc(xbuf, "batch", "experts", None, None)   # (G,E,C,d)

    act = _act("swiglu" if m.gated else "gelu")
    g = jnp.einsum("gecd,edf->gecf", xbuf, params["wi_gate"].astype(compute))
    if m.gated:
        u = jnp.einsum("gecd,edf->gecf", xbuf, params["wi_up"].astype(compute))
        h = act(g) * u
    else:
        h = act(g)
    h = lc(h, "batch", "experts", None, "expert_mlp")
    ybuf = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(compute))
    # combine all-to-all: expert-major -> group-major
    ybuf = lc(ybuf, "batch", None, None, None)

    def _combine(yb, st_g, sw_g, keep_g, slot_g):
        y = yb.reshape(E * C, d)[slot_g]
        y = y * (sw_g * keep_g).astype(compute)[:, None]
        return jnp.zeros((n_loc, d), compute).at[st_g].add(y)

    out = jax.vmap(_combine)(ybuf, st, sw, keep, slot)    # (G, n_loc, d)
    out = lc(out, "batch", None, None).reshape(N, d)

    if m.n_shared_experts:
        out = out + mlp_block(
            params["shared"], x.reshape(N, d), cfg, lc=NULL_CONSTRAINTS
        )

    expert_load = jnp.sum(counts, axis=0).astype(jnp.float32)  # (E,)
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=(0, 1))
    ce = expert_load / jnp.maximum(jnp.sum(expert_load), 1.0)
    lb_loss = E * jnp.sum(me * ce)                             # switch-style
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"expert_load": expert_load, "moe_lb_loss": lb_loss, "moe_z_loss": z_loss}
    return out.reshape(B, S, d), aux
