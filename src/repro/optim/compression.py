"""Gradient compression for the cross-pod (DCN) hop.

int8 block quantization with per-block scales: gradients are compressed
before the pod-level all-reduce (4x fewer DCN bytes for bf16 grads / 2x for
f32->int8+scale) and decompressed after. Stochastic rounding keeps the
estimator unbiased. Used by train.train_step when
``TrainConfig.compress_dcn_grads`` is set; the dry-run shows the DCN
collective bytes shrinking accordingly (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to(x, mult):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x, n


def compress_int8(g, key=None):
    """g: any-shape float array -> (q: int8 (nblocks, BLOCK), scale: f32
    (nblocks,), meta). Stochastic rounding when a key is given."""
    flat = g.astype(jnp.float32).reshape(-1)
    flat, true_n = _pad_to(flat, BLOCK)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    x = blocks / scale[:, None]
    if key is not None:
        noise = jax.random.uniform(key, x.shape) - 0.5
        q = jnp.clip(jnp.round(x + noise), -127, 127).astype(jnp.int8)
    else:
        q = jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8)
    return q, scale, (g.shape, true_n)


def decompress_int8(q, scale, meta, dtype=jnp.float32):
    shape, true_n = meta
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:true_n]
    return flat.reshape(shape).astype(dtype)
