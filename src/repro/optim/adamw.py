"""AdamW with fp32 master weights over bf16 params (ZeRO-3 native: optimizer
state inherits the parameter sharding, so sharded params => sharded state)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    keep_master: bool = True  # fp32 master copy of bf16 params


def adamw_init(params, cfg: AdamWConfig):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
    }
    if cfg.keep_master:
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params
        )
    return state


def global_norm(tree) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) if cfg.grad_clip else 1.0
    lr = cfg.lr * lr_scale

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        g32 = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mhat = m / b1c
        vhat = v / b2c
        base = master if master is not None else p.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * base)
        return new.astype(p.dtype), m, v, new

    masters = state.get("master")
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    flat_master = (
        jax.tree_util.tree_leaves(masters) if masters is not None else [None] * len(flat_p)
    )
    outs = [upd(*t) for t in zip(flat_p, flat_g, flat_m, flat_v, flat_master)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_state = {
        "step": step,
        "m": jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]),
        "v": jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs]),
    }
    if masters is not None:
        new_state["master"] = jax.tree_util.tree_unflatten(
            treedef, [o[3] for o in outs]
        )
    stats = {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
    return new_params, new_state, stats


def optimizer_pspecs(param_pspecs, cfg: AdamWConfig):
    """Optimizer state shardings mirror parameter shardings."""
    from jax.sharding import PartitionSpec as P

    state = {
        "step": P(),
        "m": param_pspecs,
        "v": param_pspecs,
    }
    if cfg.keep_master:
        state["master"] = param_pspecs
    return state
