"""repro — TALP-Pages for JAX.

The public instrumentation surface is ``repro.session`` (one facade, three
pluggable collector backends, zero-code-change activation via
``TALP_ENABLE=1``); everything else lives in focused subpackages
(``repro.core`` collection/reporting internals, ``repro.train``,
``repro.serve``, ``repro.launch``, ...).

Convenience re-exports (resolved lazily so ``import repro`` stays free):

    repro.start(...)      -> a started PerfSession (off unless env enables)
    repro.PerfSession     -> repro.session.PerfSession
    repro.SessionConfig   -> repro.session.SessionConfig
"""

from __future__ import annotations

import importlib

_SESSION_EXPORTS = ("start", "PerfSession", "SessionConfig", "null_session")

__all__ = [*_SESSION_EXPORTS, "session"]


def __getattr__(name: str):
    if name in _SESSION_EXPORTS:
        return getattr(importlib.import_module("repro.session"), name)
    if name == "session":
        return importlib.import_module("repro.session")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
