"""Distributed train step.

* gradient accumulation as a ``lax.scan`` over microbatches (XLA's
  latency-hiding scheduler overlaps each microbatch's gradient psum with the
  next microbatch's backward);
* AdamW with fp32 master + ZeRO-3-style sharded optimizer state;
* optional int8 gradient compression for the cross-pod (DCN) hop;
* emits the monitor's per-step observables: real-token counts per data
  shard (data load balance) and MoE expert loads (expert load balance) —
  the paper's on-the-fly measurements, produced by the step itself at
  O(shards + experts) extra bytes.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import sharding as SH
from repro.layers.common import LogicalConstraints, param_pspecs
from repro.models import transformer as T
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    optimizer_pspecs,
)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    accum_steps: int = 1
    warmup_steps: int = 100
    total_steps: int = 10_000
    compress_dcn_grads: bool = False


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: Any

    def tree(self):
        return {"params": self.params, "opt_state": self.opt_state, "step": self.step}


def init_state(cfg, tcfg: TrainConfig, key) -> TrainState:
    from repro.layers.common import init_params

    params = init_params(T.model_params(cfg), key, cfg.param_dtype)
    opt = adamw_init(params, tcfg.optimizer)
    return TrainState(params=params, opt_state=opt, step=jnp.zeros((), jnp.int32))


def train_state_pspecs(cfg, mesh, tcfg: TrainConfig):
    rules = SH.param_rules(cfg, mesh)
    pp = param_pspecs(T.model_params(cfg), rules, mesh)
    return {
        "params": pp,
        "opt_state": optimizer_pspecs(pp, tcfg.optimizer),
        "step": jax.sharding.PartitionSpec(),
    }


def _tokens_per_shard(labels, n_shards: int):
    """Real (non-pad) token count per data shard — the data-LB observable.
    labels: (B,S); the batch dim is sharded over exactly ``n_shards``."""
    B = labels.shape[0]
    if n_shards <= 1 or B % n_shards:
        return jnp.sum(labels >= 0).reshape(1).astype(jnp.float32)
    g = labels.reshape(n_shards, B // n_shards, -1)
    return jnp.sum(g >= 0, axis=(1, 2)).astype(jnp.float32)


def make_train_step(cfg, mesh, tcfg: TrainConfig):
    """Returns train_step(state_tree, batch) -> (state_tree, metrics).

    batch: {"tokens": (A, B, S), "labels": (A, B, S)[, "frontend": (A,B,P,d)]}
    where A = accum_steps (A=1 means the leading dim is still present).
    """
    lc = LogicalConstraints(mesh, SH.activation_rules(cfg, mesh))
    n_data_shards = SH.data_shards(mesh)
    # grad-accumulation carry must shard like the params — otherwise the
    # f32 accumulator materializes replicated (30B params -> 122 GB/device)
    grad_pspecs = param_pspecs(T.model_params(cfg), SH.param_rules(cfg, mesh), mesh)

    def constrain_grads(g):
        if mesh is None:
            return g
        return jax.tree_util.tree_map(
            lambda x, p: jax.lax.with_sharding_constraint(x, p), g, grad_pspecs
        )

    def loss_fn(params, mb):
        loss, aux = T.forward(params, mb, cfg, lc)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params, opt_state, step = state["params"], state["opt_state"], state["step"]

        def micro(carry, mb):
            gsum, lsum = carry
            (loss, aux), grads = grad_fn(params, mb)
            gsum = constrain_grads(jax.tree_util.tree_map(jnp.add, gsum, grads))
            keep = {
                k: aux[k]
                for k in ("expert_load", "tokens", "moe_lb_loss")
                if k in aux
            }
            keep["tokens_per_shard"] = _tokens_per_shard(mb["labels"], n_data_shards)
            return (gsum, lsum + loss), keep

        zeros = constrain_grads(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        ))
        A = batch["labels"].shape[0]
        if A == 1:
            (grads, loss_sum), aux = micro(
                (zeros, 0.0),
                jax.tree_util.tree_map(lambda x: x[0], batch),
            )
        else:
            (grads, loss_sum), auxs = jax.lax.scan(micro, (zeros, 0.0), batch)
            aux = jax.tree_util.tree_map(lambda x: jnp.sum(x, axis=0), auxs)
        inv = 1.0 / A
        grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        loss = loss_sum * inv

        if tcfg.compress_dcn_grads:
            # quantize/dequantize gradients (the DCN all-reduce then moves
            # int8 blocks; on a single-pod mesh this is a numerical no-op
            # knob measured by the §Perf pass)
            from repro.optim import compress_int8, decompress_int8

            def roundtrip(g):
                q, s, meta = compress_int8(g)
                return decompress_int8(q, s, meta, jnp.float32)

            grads = jax.tree_util.tree_map(roundtrip, grads)

        lr_scale = cosine_schedule(
            step, warmup=tcfg.warmup_steps, total=tcfg.total_steps
        )
        new_params, new_opt, stats = adamw_update(
            params, grads, opt_state, tcfg.optimizer, lr_scale
        )
        metrics = {
            "loss": loss,
            "grad_norm": stats["grad_norm"],
            "lr": stats["lr"],
            **(aux or {}),
        }
        new_state = {"params": new_params, "opt_state": new_opt, "step": step + 1}
        return new_state, metrics

    return train_step


def compile_train_step(cfg, mesh, tcfg: TrainConfig, state_tree, example):
    """AOT-lower and compile the sharded train step against ``example``'s
    shapes. Returns ``(compiled, call)``: the compiled executable (what
    ``PerfSession.wrap_step`` derives the StepProfile from) and a callable
    that executes it under the mesh context."""
    from repro import compat

    with compat.use_mesh(mesh):
        jitted = jit_train_step(cfg, mesh, tcfg)(example)
        compiled = jitted.lower(state_tree, example).compile()

    def call(state, batch):
        with compat.use_mesh(mesh):
            return compiled(state, batch)

    return compiled, call


def jit_train_step(cfg, mesh, tcfg: TrainConfig, donate: bool = True):
    """pjit-wrapped step with explicit in/out shardings."""
    from repro import compat

    step_fn = make_train_step(cfg, mesh, tcfg)
    sp = train_state_pspecs(cfg, mesh, tcfg)
    to_sharding = lambda tree: jax.tree_util.tree_map(
        lambda p: compat.named_sharding(mesh, p), tree
    )
    state_sh = to_sharding(sp)
    bp = SH.batch_pspec(cfg, mesh)

    def batch_sharding(batch_tree):
        def f(x):
            # (A, B, ...): microbatch dim replicated, batch dim sharded
            spec = [None, bp[0]] + [None] * (len(x.shape) - 2)
            return compat.named_sharding(mesh, jax.sharding.PartitionSpec(*spec))

        return jax.tree_util.tree_map(f, batch_tree)

    def wrapper(batch_tree):
        return jax.jit(
            step_fn,
            in_shardings=(state_sh, batch_sharding(batch_tree)),
            out_shardings=(state_sh, None),
            donate_argnums=(0,) if donate else (),
        )

    return wrapper
