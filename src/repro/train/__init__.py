from repro.train.train import TrainConfig, TrainState, make_train_step, train_state_pspecs
from repro.train.loop import TrainLoop, LoopConfig

__all__ = [
    "TrainConfig", "TrainState", "make_train_step", "train_state_pspecs",
    "TrainLoop", "LoopConfig",
]
