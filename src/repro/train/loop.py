"""Fault-tolerant training loop with first-class TALP monitoring.

Integration exactly mirrors the paper's GENE-X CI setup (§Integration),
expressed through the one instrumentation surface (``repro.session``): the
loop owns a ``PerfSession`` with an ``initialize`` region (compile +
restore) and a ``train_step`` region (the paper's ``timestep``) attached by
``session.wrap_step`` — which also derives the static StepProfile from the
compiled step and streams the per-step observables (tokens per shard,
expert loads, host heartbeat) into the collector. ``finalize_run(out_dir)``
writes the JSON artifact for TALP-Pages in one call.

Fault tolerance:
  * checkpoint every ``ckpt_every`` steps (async, atomic commit);
  * ``run()`` always restores the latest checkpoint when present — crash =
    restart the process, nothing else (the data pipeline is step-indexed);
  * straggler mitigation hook: when the measured host load balance drops
    below ``straggler_threshold`` the loop calls ``on_straggler`` (real
    deployment: re-shard away from the slow host / alert; tests assert the
    trigger);
  * ``fail_at_step`` injects a crash (used by the restart tests).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import ResourceConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import devices_per_pod
from repro.session import PerfSession, SessionConfig
from repro.train.train import TrainConfig, compile_train_step, init_state


@dataclasses.dataclass
class LoopConfig:
    steps: int = 50
    ckpt_every: int = 0              # 0 = no checkpoints
    ckpt_dir: str = ""
    seed: int = 0
    straggler_threshold: float = 0.8
    monitor_app_name: str = "train"
    monitor_backend: str = "monitor"  # PerfSession backend (env can override)
    lb_sample_every: int = 1
    fail_at_step: int | None = None  # crash injection for restart tests
    host_times_fn: Callable[[int], Any] | None = None  # heartbeat source


class InjectedFailure(RuntimeError):
    pass


_UNSAMPLED = object()  # heartbeat not yet read for the current step


class TrainLoop:
    def __init__(
        self,
        cfg,
        mesh,
        tcfg: TrainConfig,
        data: SyntheticLM,
        loop_cfg: LoopConfig,
        on_straggler: Callable[[int, float], None] | None = None,
    ):
        self.cfg, self.mesh, self.tcfg = cfg, mesh, tcfg
        self.data = data
        self.loop = loop_cfg
        self.on_straggler = on_straggler
        self.straggler_events: list[tuple[int, float]] = []
        n = mesh.devices.size
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.resources = ResourceConfig(
            num_hosts=max(1, n // jax.local_device_count()),
            devices_per_host=min(n, jax.local_device_count()),
            mesh=sizes,
            num_pods=sizes.get("pod", 1),
        )
        self.session = PerfSession(
            SessionConfig(
                app_name=loop_cfg.monitor_app_name,
                backend=loop_cfg.monitor_backend,
                lb_sample_every=loop_cfg.lb_sample_every,
            ),
            self.resources,
        )
        self.ckpt = (
            CheckpointManager(loop_cfg.ckpt_dir) if loop_cfg.ckpt_dir else None
        )
        self.metrics_history: list[dict] = []
        self._cur_step = 0
        self._host_times: Any = _UNSAMPLED

    # ------------------------------------------------------------------

    def run(self) -> "TrainLoop":
        ses = self.session
        ses.start()
        with ses.region("initialize"):
            state, start_step, step_fn = self._initialize()

        try:
            for step in range(start_step, self.loop.steps):
                if self.loop.fail_at_step is not None and step == self.loop.fail_at_step:
                    raise InjectedFailure(f"injected failure at step {step}")
                batch = self.data.batch_at(step)
                self._cur_step = step
                self._host_times = _UNSAMPLED
                state, metrics = step_fn(state, batch)
                # the heartbeat is read post-step by _observe (inside the
                # train_step region); sample it here only when a null
                # backend skipped observation — straggler mitigation is a
                # loop feature, not an instrumentation feature
                if self._host_times is _UNSAMPLED:
                    self._host_times = self._sample_host_times()
                self._check_straggler(step, self._host_times)
                self.metrics_history.append(
                    {"step": step, "loss": float(metrics["loss"])}
                )
                if (
                    self.ckpt
                    and self.loop.ckpt_every
                    and (step + 1) % self.loop.ckpt_every == 0
                ):
                    self.ckpt.save(state, step + 1)
        finally:
            if self.ckpt:
                self.ckpt.wait()
            ses.stop()
        self.final_state = state
        return self

    # ------------------------------------------------------------------

    def _initialize(self):
        key = jax.random.PRNGKey(self.loop.seed)
        state = init_state(self.cfg, self.tcfg, key)
        state_tree = {
            "params": state.params, "opt_state": state.opt_state, "step": state.step
        }
        start = 0
        if self.ckpt and self.ckpt.latest() is not None:
            state_tree, start = self.ckpt.restore(state_tree)
        example = self.data.batch_at(0)
        compiled, call = compile_train_step(
            self.cfg, self.mesh, self.tcfg, state_tree, example
        )
        from repro.models.flops import train_step_model_flops

        step_fn = self.session.wrap_step(
            call,
            region="train_step",
            compiled=compiled,
            num_devices=self.mesh.devices.size,
            devices_per_pod=devices_per_pod(self.mesh),
            model_flops=train_step_model_flops(self.cfg, example["labels"].shape),
            observe=self._observe,
        )
        return state_tree, start, step_fn

    def _sample_host_times(self):
        """Read the per-host heartbeat for the step that just executed."""
        return (
            self.loop.host_times_fn(self._cur_step)
            if self.loop.host_times_fn
            else None
        )

    def _observe(self, out) -> dict:
        """Map one step result to the monitor observables (wrap_step hook;
        runs inside the train_step region, after the step executed)."""
        _state, metrics = out
        host_times = self._host_times = self._sample_host_times()
        return {
            "outputs": metrics,
            "tokens_per_shard": metrics.get("tokens_per_shard"),
            "expert_load": metrics.get("expert_load"),
            "host_times": host_times,
            "pod_size": (
                self.resources.num_hosts // self.resources.num_pods
                if host_times is not None and self.resources.num_pods > 1
                else None
            ),
        }

    def _check_straggler(self, step: int, host_times) -> None:
        if host_times is None:
            return
        arr = np.asarray(host_times, dtype=np.float64).reshape(-1)
        if arr.size < 2 or arr.max() <= 0:
            return
        lb = float(arr.mean() / arr.max())
        if lb < self.loop.straggler_threshold:
            self.straggler_events.append((step, lb))
            if self.on_straggler:
                self.on_straggler(step, lb)

    def finalize_run(self, out_dir: str | None = None):
        """One call for the whole artifact choreography: finalize the
        session's RunRecord and, when a destination resolves (``out_dir``,
        ``TALP_OUT``, or the session config), inject git metadata and save
        into the CI folder layout."""
        return self.session.finalize(out_dir)
