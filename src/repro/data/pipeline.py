"""Data pipeline: deterministic, step-indexed, shardable, resumable.

Restart semantics for fault tolerance: ``batch_at(step)`` is a pure function
of (seed, step), so resuming from a checkpoint at step k replays exactly the
batches k, k+1, … with no pipeline state to persist. Padding fraction is
controllable to exercise the monitor's data-load-balance factor.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab: int
    accum_steps: int = 1
    seed: int = 0
    pad_fraction: float = 0.0   # expected fraction of padded tail per sample
    frontend_tokens: int = 0    # stub patch/frame embeddings prepended
    d_model: int = 0            # for frontend stubs


class SyntheticLM:
    """Synthetic LM token stream (shift-by-one labels, -1 padding)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        text_len = c.seq_len - c.frontend_tokens
        shape = (c.accum_steps, c.global_batch, text_len)
        toks = rng.integers(4, c.vocab, size=shape, dtype=np.int32)
        labels = np.roll(toks, -1, axis=-1).astype(np.int32)
        labels[..., -1] = -1
        if c.pad_fraction > 0:
            # random tail padding per sample -> real-token imbalance
            lens = rng.integers(
                int(text_len * (1 - 2 * c.pad_fraction)), text_len + 1,
                size=shape[:2],
            )
            idx = np.arange(text_len)[None, None, :]
            pad_mask = idx >= lens[..., None]
            toks = np.where(pad_mask, 0, toks)
            labels = np.where(pad_mask, -1, labels)
        out = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        if c.frontend_tokens:
            fe = rng.standard_normal(
                (c.accum_steps, c.global_batch, c.frontend_tokens, c.d_model),
                dtype=np.float32,
            ) * 0.02
            out["frontend"] = jnp.asarray(fe, jnp.bfloat16)
            # frontend positions carry no labels
            pad = np.full(
                (c.accum_steps, c.global_batch, c.frontend_tokens), -1, np.int32
            )
            out["labels"] = jnp.asarray(
                np.concatenate([pad, np.asarray(out["labels"])], axis=-1)
            )
        return out


def batch_specs(cfg, shape, mode: str = "train"):
    """ShapeDtypeStruct stand-ins for every model input (dry-run contract).

    cfg: ModelConfig; shape: InputShape (see configs.shapes).
    """
    import jax.numpy as jnp

    B, S = shape.global_batch, shape.seq_len
    fe = cfg.n_frontend_tokens
    out = {}
    if mode == "train":
        A = 1
        text = S - (fe if cfg.frontend == "vlm" else 0)
        if cfg.frontend == "audio":
            out["frontend"] = jax.ShapeDtypeStruct((A, B, S, cfg.d_model), jnp.bfloat16)
            out["labels"] = jax.ShapeDtypeStruct((A, B, S), jnp.int32)
        elif cfg.frontend == "vlm":
            out["frontend"] = jax.ShapeDtypeStruct((A, B, fe, cfg.d_model), jnp.bfloat16)
            out["tokens"] = jax.ShapeDtypeStruct((A, B, text), jnp.int32)
            out["labels"] = jax.ShapeDtypeStruct((A, B, S), jnp.int32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((A, B, S), jnp.int32)
            out["labels"] = jax.ShapeDtypeStruct((A, B, S), jnp.int32)
    elif mode == "prefill":
        text = S - (fe if cfg.frontend == "vlm" else 0)
        if cfg.frontend == "audio":
            out["frontend"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        elif cfg.frontend == "vlm":
            out["frontend"] = jax.ShapeDtypeStruct((B, fe, cfg.d_model), jnp.bfloat16)
            out["tokens"] = jax.ShapeDtypeStruct((B, text), jnp.int32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    elif mode == "decode":
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    else:
        raise ValueError(mode)
    return out
