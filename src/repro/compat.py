"""JAX version-portability layer.

Every version-gated JAX attribute access in this repo lives HERE and only
here. The CI host pins whatever JAX it pins (0.4.x today); the framework
must run unmodified on old and new releases alike, because a performance
monitor that crashes on the installed toolchain measures nothing
(ISSUE 1 / ROADMAP "as fast as the hardware allows").

Shimmed surfaces, each feature-detected at import time (not version-string
compared — point releases backport features):

* ``make_mesh``        — ``jax.make_mesh`` grew an ``axis_types=`` kwarg and
                         ``jax.sharding.AxisType`` in 0.5+; 0.4.x has
                         neither, and very old releases lack ``jax.make_mesh``
                         entirely (fall back to ``mesh_utils``).
* ``use_mesh``         — the ambient-mesh context: ``jax.sharding.use_mesh``
                         (0.5+) or the classic ``with mesh:`` context
                         manager (0.4.x).
* ``named_sharding``   — trivial today, but isolates the constructor import.
* ``device_put``       — placement with an optional sharding.
* ``cost_analysis`` /
  ``memory_stats``     — ``compiled.cost_analysis()`` returned a one-element
                         list in old JAX and a dict in new JAX;
                         ``memory_analysis()`` raises on some backends.
* ``compiled_text``    — optimized-HLO text of a compiled executable.
* ``pallas`` /
  ``pallas_tpu``       — the Pallas kernel namespaces live under the
                         *experimental* tree, whose layout and availability
                         move between releases (and CPU-only builds may lack
                         the TPU submodule). Kernel code imports the modules
                         through these accessors; everything else must stay
                         behind the ``repro.kernels`` ops wrappers, whose
                         ``impl="reference"`` path needs no Pallas at all.

Policy (recorded for future PRs): new code MUST import these helpers
instead of touching ``jax.sharding.AxisType``-style attributes directly;
the tier-1 suite greps for violations (tests/test_compat.py).
"""

from __future__ import annotations

import contextlib
import inspect
from typing import Any, Sequence

import jax

# ---------------------------------------------------------------------------
# feature detection (once, at import)
# ---------------------------------------------------------------------------

#: jax.sharding.AxisType.Auto on releases that have it, else None.
AXIS_TYPE_AUTO = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)

HAS_AXIS_TYPES = AXIS_TYPE_AUTO is not None


def _make_mesh_accepts_axis_types() -> bool:
    fn = getattr(jax, "make_mesh", None)
    if fn is None:
        return False
    try:
        return "axis_types" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


MAKE_MESH_HAS_AXIS_TYPES = _make_mesh_accepts_axis_types()

try:  # experimental namespace: presence and layout are version-dependent
    from jax.experimental import pallas as _pallas_mod
except Exception:  # pragma: no cover - exercised on builds without Pallas
    _pallas_mod = None
try:
    from jax.experimental.pallas import tpu as _pallas_tpu_mod
except Exception:  # pragma: no cover - e.g. minimal CPU wheels
    _pallas_tpu_mod = None

HAS_PALLAS = _pallas_mod is not None
HAS_PALLAS_TPU = _pallas_tpu_mod is not None


def pallas():
    """The Pallas core module (``pl`` by convention), feature-detected."""
    if _pallas_mod is None:
        raise ImportError(
            "this JAX build has no Pallas; use the kernels' impl='reference' "
            "path (pure jnp oracles) instead of the Pallas kernels"
        )
    return _pallas_mod


def pallas_tpu():
    """The Pallas TPU module (``pltpu`` by convention), feature-detected."""
    if _pallas_tpu_mod is None:
        raise ImportError(
            "this JAX build has no Pallas TPU support; use the kernels' "
            "impl='reference' path instead"
        )
    return _pallas_tpu_mod


def jax_version() -> tuple[int, ...]:
    """Best-effort numeric version tuple (diagnostics only — never use for
    feature gating; feature-detect instead)."""
    out = []
    for part in jax.__version__.split("."):
        digits = "".join(ch for ch in part if ch.isdigit())
        if not digits:
            break
        out.append(int(digits))
    return tuple(out)


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Sequence[Any] | None = None,
):
    """``jax.make_mesh`` portable across the axis_types API change.

    On releases with ``AxisType`` every axis is marked Auto (the classic
    GSPMD behavior this codebase is written against); on older releases
    Auto is the only behavior, so the kwarg is simply omitted.
    """
    fn = getattr(jax, "make_mesh", None)
    if fn is not None:
        if MAKE_MESH_HAS_AXIS_TYPES and HAS_AXIS_TYPES:
            return fn(
                tuple(axis_shapes), tuple(axis_names),
                axis_types=(AXIS_TYPE_AUTO,) * len(tuple(axis_names)),
                **({"devices": devices} if devices is not None else {}),
            )
        return fn(
            tuple(axis_shapes), tuple(axis_names),
            **({"devices": devices} if devices is not None else {}),
        )
    # ancient JAX: no jax.make_mesh at all
    from jax.experimental import mesh_utils

    devs = mesh_utils.create_device_mesh(
        tuple(axis_shapes), devices=list(devices) if devices is not None else None
    )
    return jax.sharding.Mesh(devs, tuple(axis_names))


@contextlib.contextmanager
def use_mesh(mesh):
    """Enter ``mesh`` as the ambient mesh, whatever this JAX calls that.

    ``jax.sharding.use_mesh`` (0.5+) when present, else the classic
    ``with mesh:`` context (0.4.x). ``jax.set_mesh`` is deliberately NOT
    probed: on releases where it is a plain global setter rather than a
    context manager, merely calling it to find out would leak the ambient
    mesh past this block.
    """
    factory = getattr(jax.sharding, "use_mesh", None)
    if factory is not None:
        with factory(mesh):
            yield mesh
        return
    with mesh:
        yield mesh


def named_sharding(mesh, spec):
    """NamedSharding constructor (``spec``: PartitionSpec or axis tuple)."""
    if not isinstance(spec, jax.sharding.PartitionSpec):
        spec = jax.sharding.PartitionSpec(*spec) if isinstance(spec, (tuple, list)) \
            else jax.sharding.PartitionSpec(spec)
    return jax.sharding.NamedSharding(mesh, spec)


def device_put(x, sharding=None):
    """``jax.device_put`` with an optional sharding (None = default device)."""
    if sharding is None:
        return jax.device_put(x)
    return jax.device_put(x, sharding)


# ---------------------------------------------------------------------------
# compiled-executable accessors
# ---------------------------------------------------------------------------


def _is_num(v) -> bool:
    try:
        float(v)
        return True
    except (TypeError, ValueError):
        return False


def cost_analysis(compiled) -> dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across JAX versions.

    Old JAX returns ``[{...}]`` (one dict per partition), new JAX a plain
    dict; some backends raise. Always returns a (possibly empty) flat
    str->float dict.
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    try:
        items = dict(ca).items()
    except (TypeError, ValueError):
        return {}
    return {str(k): float(v) for k, v in items if _is_num(v)}


def memory_stats(compiled) -> dict[str, float]:
    """Normalize ``compiled.memory_analysis()`` (absent/raising on some
    backends) to a flat str->float dict of the stable field names."""
    try:
        ms = compiled.memory_analysis()
    except Exception:
        return {}
    out: dict[str, float] = {}
    for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ms, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def compiled_text(compiled) -> str:
    """Optimized HLO text of a compiled executable.

    Deliberately raises when the accessor is missing or failing instead of
    returning '': an empty string flows into ``analyze_hlo`` as an all-zero
    HloCost — exactly the silent-zero failure mode the call-graph engine
    exists to prevent. Callers that can tolerate absence must catch.
    """
    return compiled.as_text()
