"""jit'd public wrapper for the paged decode-attention kernel.

``paged_attention(...)`` routes to the Pallas kernel on TPU (or in
interpret mode when asked) and to the pure-jnp gather oracle otherwise —
the same ``impl`` contract as ``kernels.flash_attention``. The serving
stack selects the implementation via ``ModelConfig.paged_attn_impl``; the
reference path is the one that is bitwise identical to the dense cache
layout (the paged-vs-dense token-identity guarantee).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.ref import paged_attention_reference


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "impl")
)
def paged_attention(
    q, k_pages, v_pages, block_tables, *,
    q_position, cache_len,
    window: int | None = None,
    softcap: float | None = None,
    impl: str = "auto",  # auto | pallas | interpret | reference
):
    """Single-position attention against a paged KV pool.

    q: (B,1,Hq,D); k_pages/v_pages: (P, page_size, Hkv, D); block_tables:
    (B, n_logical) int32, ``-1`` = unallocated; q_position/cache_len: ()
    or (B,). Returns (B,1,Hq,D) in q.dtype.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    if impl == "reference":
        return paged_attention_reference(
            q, k_pages, v_pages, block_tables,
            q_position=q_position, cache_len=cache_len,
            window=window, softcap=softcap,
        )
    # lazy: the kernel module needs Pallas at import time, and the
    # reference path must stay usable on builds without it
    from repro.kernels.paged_attention.kernel import paged_attention_pallas

    return paged_attention_pallas(
        q, k_pages, v_pages, block_tables,
        q_position=q_position, cache_len=cache_len,
        window=window, softcap=softcap,
        interpret=(impl == "interpret"),
    )
