"""jit'd public wrapper for the paged decode-attention kernel.

``paged_attention(...)`` routes to the Pallas kernel on TPU (or in
interpret mode when asked) and to the pure-jnp gather oracle otherwise —
the same ``impl`` contract as ``kernels.flash_attention``. The serving
stack selects the implementation via ``ModelConfig.paged_attn_impl``; the
reference path is the one that is bitwise identical to the dense cache
layout (the paged-vs-dense token-identity guarantee).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.ref import (
    paged_attention_reference,
    paged_prefill_attention_reference,
)


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "impl")
)
def paged_attention(
    q, k_pages, v_pages, block_tables, *,
    q_position, cache_len,
    window: int | None = None,
    softcap: float | None = None,
    impl: str = "auto",  # auto | pallas | interpret | reference
):
    """Single-position attention against a paged KV pool.

    q: (B,1,Hq,D); k_pages/v_pages: (P, page_size, Hkv, D); block_tables:
    (B, n_logical) int32, ``-1`` = unallocated; q_position/cache_len: ()
    or (B,). Returns (B,1,Hq,D) in q.dtype.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    if impl == "reference":
        return paged_attention_reference(
            q, k_pages, v_pages, block_tables,
            q_position=q_position, cache_len=cache_len,
            window=window, softcap=softcap,
        )
    # lazy: the kernel module needs Pallas at import time, and the
    # reference path must stay usable on builds without it
    from repro.kernels.paged_attention.kernel import paged_attention_pallas

    return paged_attention_pallas(
        q, k_pages, v_pages, block_tables,
        q_position=q_position, cache_len=cache_len,
        window=window, softcap=softcap,
        interpret=(impl == "interpret"),
    )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "q_chunk", "kv_chunk",
                     "impl"),
)
def paged_prefill_attention(
    q, k_pages, v_pages, block_tables, *,
    q_positions, cache_len,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    impl: str = "auto",  # auto | pallas | interpret | reference
):
    """Multi-token (S>1) chunked-prefill attention against a paged KV pool.

    q: (B,C,Hq,D) — one prefill chunk per row at positions ``q_positions``
    (B,C) (contiguous: row c sits at ``q_positions[:,0] + c``); cache_len:
    () or (B,) written tokens including this chunk. ``q_chunk``/``kv_chunk``
    are the reference path's flash chunk sizes — pass the model's so the
    reference stays bitwise identical to the dense-gather prefill (the
    sharing-on/off and paged-vs-dense token-identity guarantees); the
    kernel streams pages and ignores them. Returns (B,C,Hq,D) in q.dtype.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    if impl == "reference":
        return paged_prefill_attention_reference(
            q, k_pages, v_pages, block_tables,
            q_positions=q_positions, cache_len=cache_len,
            causal=causal, window=window, softcap=softcap,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
    from repro.kernels.paged_attention.kernel import (
        paged_prefill_attention_pallas,
    )

    return paged_prefill_attention_pallas(
        q, k_pages, v_pages, block_tables,
        q_positions=q_positions, cache_len=cache_len,
        causal=causal, window=window, softcap=softcap,
        interpret=(impl == "interpret"),
    )
