"""Pure-jnp oracle for the paged decode-attention kernel.

Gathers the pages a slot owns into the dense ``(B, Smax, Hkv, D)`` layout
through the block table, then runs the EXACT computation of
``layers.attention.decode_attention`` (same ops, same order, same shapes).
That transcription is load-bearing: the serving acceptance criterion is
*bitwise* token identity between the paged and dense cache layouts, and it
holds because post-mask the two paths are elementwise identical programs —
whatever garbage lives in unallocated/unwritten pages is squashed to an
exact 0 probability by the NEG_INF mask before it can touch the output.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_pages(pages, block_tables):
    """Materialize the per-slot dense view of a paged pool.

    pages: (P, page, Hkv, D) physical pool; block_tables: (B, n_logical)
    int32, ``-1`` = unallocated (clipped to page 0 — callers mask by
    ``cache_len`` so the junk is never visible). Returns
    (B, n_logical*page, Hkv, D).
    """
    P, page, Hkv, D = pages.shape
    B, nL = block_tables.shape
    tbl = jnp.clip(block_tables, 0, P - 1)
    return pages[tbl].reshape(B, nL * page, Hkv, D)


def paged_prefill_attention_reference(
    q, k_pages, v_pages, block_tables, *, q_positions, cache_len,
    causal: bool = True, window: int | None = None,
    softcap: float | None = None, q_chunk: int = 512, kv_chunk: int = 1024,
):
    """Multi-token (S>1) chunked-prefill attention against a paged cache.

    q: (B,C,Hq,D) — one prefill chunk per row at positions ``q_positions``
    (B,C) (row c sits at ``q_positions[b, 0] + c``); cache_len: () or (B,)
    total written tokens (chunk start + chunk length). Gathers the rows'
    pages into the dense ``(B, Smax, Hkv, D)`` layout and calls the model's
    ``flash_attention`` with EXACTLY the arguments the dense-gather prefill
    branch historically used — the reference IS the dense bridge, bitwise,
    by shared code rather than by transcription.
    """
    # lazy: layers.attention lazily imports this module (gather_pages /
    # the ops wrappers), so a module-level import here would be a cycle;
    # function-local keeps the layering acyclic at import time while the
    # bitwise dense bridge stays shared code instead of a copy that drifts
    from repro.layers.attention import flash_attention

    k_cache = gather_pages(k_pages, block_tables)
    v_cache = gather_pages(v_pages, block_tables)
    B, Smax = k_cache.shape[0], k_cache.shape[1]
    k_positions = jnp.broadcast_to(jnp.arange(Smax)[None, :], (B, Smax))
    return flash_attention(
        q, k_cache, v_cache, q_positions=q_positions,
        k_positions=k_positions, causal=causal, window=window,
        softcap=softcap, kv_len=cache_len,
        q_chunk=q_chunk, kv_chunk=kv_chunk, causal_skip=False,
    )


def paged_attention_reference(
    q, k_pages, v_pages, block_tables, *, q_position, cache_len,
    window: int | None = None, softcap: float | None = None,
):
    """Single-position attention against a paged cache.

    q: (B,1,Hq,D); k_pages/v_pages: (P, page, Hkv, D); block_tables:
    (B, n_logical) int32 (logical page j of slot b lives in physical page
    ``block_tables[b, j]``); cache_len: () or (B,) valid token count;
    q_position: () or (B,) query position (window masking).
    Returns (B,1,Hq,D) in q.dtype.
    """
    k_cache = gather_pages(k_pages, block_tables)
    v_cache = gather_pages(v_pages, block_tables)
    # -- from here on: decode_attention verbatim --
    B, _, Hq, D = q.shape
    _, Sk, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, 1, Hkv, G, D).transpose(0, 3, 2, 1, 4)  # (B,G,Hkv,1,D)
    kg = k_cache.transpose(0, 2, 1, 3)  # (B,Hkv,Sk,D)
    vg = v_cache.transpose(0, 2, 1, 3)
    s = jnp.einsum("bghqd,bhkd->bghqk", qg.astype(jnp.float32), kg.astype(jnp.float32))
    s *= scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kpos = jnp.arange(Sk)[None, None, None, None, :]
    qpos = jnp.asarray(q_position).reshape(-1, 1, 1, 1, 1)
    mask = kpos < jnp.asarray(cache_len).reshape(-1, 1, 1, 1, 1)
    if window is not None and window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bghqk,bhkd->bghqd", p, vg.astype(jnp.float32))
    return o.transpose(0, 3, 2, 1, 4).reshape(B, 1, Hq, D).astype(q.dtype)
