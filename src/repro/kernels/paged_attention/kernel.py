"""Paged decode-attention TPU kernel (scalar-prefetch block-table gather).

The block table IS the index map: the grid is ``(batch, kv_heads,
logical_pages)`` and the K/V BlockSpecs fetch ``pool[table[b, p]]`` per
step — the page gather happens inside the pallas_call machinery, so the
kernel streams exactly the pages a slot owns out of the shared HBM pool
(never a dense (B, Smax) view; that materialization is what paging exists
to avoid). Per (b, h) the logical pages arrive in order and fold into the
usual online-softmax recurrence held in VMEM scratch across grid steps;
pages at or past ``cache_len[b]`` are skipped with ``pl.when`` (their
table entries are clipped to page 0 by the wrapper and never read into
the accumulator).

Causal masking is implicit (the cache holds positions < cache_len only);
sliding window and logit softcap match the dense/ref semantics. GQA maps
each kv head's G query heads into one (G, d) q tile per program.

``paged_prefill_attention_pallas`` extends the same page-streaming design
to multi-token (S>1) chunked-prefill reads: the q tile is a whole prefill
chunk per kv head and causality is masked per (row, key) element, so the
prefill path attends the block table directly instead of gathering a
slot's pages into a dense view per chunk.

Validated in interpret mode on CPU against the ref oracles
(tests/test_kernels.py); on real TPUs the same code lowers through Mosaic.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro import compat

pl = compat.pallas()

NEG_INF = -1e30


def _paged_kernel(
    tbl_ref, lens_ref, qpos_ref,  # scalar-prefetch (also feeds the index maps)
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
    page_size: int, n_logical: int, window: int | None,
    softcap: float | None, sm_scale: float,
):
    """One (b, kv_head, logical_page) grid step.

    Refs (VMEM): q_ref (G, d); k_ref/v_ref (page_size, d) — the physical
    page the block table routed here; o_ref (G, d). Scratch: acc (G, d)
    f32, m/l (G, 1) f32 carried across the page loop of one (b, h).
    """
    b, p = pl.program_id(0), pl.program_id(2)
    length = lens_ref[b]

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(p * page_size < length)
    def _page():
        q = q_ref[...].astype(jnp.float32)          # (G, d)
        k = k_ref[...].astype(jnp.float32)          # (page, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                                 # (G, page)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < length
        if window is not None and window > 0:
            mask &= kpos > qpos_ref[b] - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_blk)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        pmat = jnp.exp(s - m_safe[:, None])
        pmat = jnp.where(mask, pmat, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_new = l_ref[:, 0] * alpha + jnp.sum(pmat, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            pmat, v_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    @pl.when(p == n_logical - 1)
    def _emit():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_prefill_kernel(
    tbl_ref, lens_ref, start_ref,  # scalar-prefetch (also feeds the index maps)
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
    page_size: int, n_logical: int, n_chunk: int, causal: bool,
    window: int | None, softcap: float | None, sm_scale: float,
):
    """One (b, kv_head, logical_page) grid step of the S>1 prefill read.

    Same page-streaming recurrence as ``_paged_kernel`` but the q tile is
    a whole prefill chunk per kv head: (G*n_chunk, d), row r = g*n_chunk+c
    at query position ``start[b] + c``. Causality is explicit here (a
    chunk's queries must not see later in-chunk keys, which ARE already
    written to the pool), masked per (row, key) element.
    """
    b, p = pl.program_id(0), pl.program_id(2)
    length = lens_ref[b]

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(p * page_size < length)
    def _page():
        q = q_ref[...].astype(jnp.float32)          # (G*C, d)
        k = k_ref[...].astype(jnp.float32)          # (page, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                                 # (G*C, page)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qpos = start_ref[b] + (
            jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % n_chunk
        )
        mask = kpos < length
        if causal:
            mask &= kpos <= qpos
        if window is not None and window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_blk)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        pmat = jnp.exp(s - m_safe[:, None])
        pmat = jnp.where(mask, pmat, 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_new = l_ref[:, 0] * alpha + jnp.sum(pmat, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            pmat, v_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    @pl.when(p == n_logical - 1)
    def _emit():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_prefill_attention_pallas(
    q, k_pages, v_pages, block_tables, *, q_positions, cache_len,
    causal: bool = True, window: int | None = None,
    softcap: float | None = None, interpret: bool = True,
):
    """q: (B,C,Hq,D) one prefill chunk per row; k_pages/v_pages:
    (P, page, Hkv, D); block_tables: (B, n_logical) int32 (``-1`` =
    unallocated); q_positions: (B,C) with row c at ``q_positions[:,0]+c``
    (the chunked-prefill contract: chunks are contiguous); cache_len: ()
    or (B,) written tokens incl. this chunk. Returns (B,C,Hq,D).

    Grid is ``(batch, kv_heads, logical_pages)`` exactly like the decode
    kernel: the chunk's queries stream every owned page once through the
    block-table index map instead of materializing a dense (B, Smax) view.
    """
    pltpu = compat.pallas_tpu()
    B, C, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    nL = block_tables.shape[-1]
    G = Hq // Hkv
    sm_scale = 1.0 / math.sqrt(D)
    d_pad = -(-D // 128) * 128

    qh = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, d_pad - D)))
    # head h -> (h // G, h % G) as in the dense layout; tile row = g*C + c
    qh = qh.reshape(B, C, Hkv, G, d_pad).transpose(0, 2, 3, 1, 4)
    qh = qh.reshape(B, Hkv, G * C, d_pad)
    kh = jnp.pad(k_pages, ((0, 0), (0, 0), (0, 0), (0, d_pad - D)))
    vh = jnp.pad(v_pages, ((0, 0), (0, 0), (0, 0), (0, d_pad - D)))
    kh = kh.transpose(2, 0, 1, 3)  # (Hkv, P, page, d)
    vh = vh.transpose(2, 0, 1, 3)

    tbl = jnp.clip(block_tables.astype(jnp.int32), 0, P - 1)
    lens = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,)
    )
    start = jnp.asarray(q_positions, jnp.int32).reshape(B, C)[:, 0]

    kernel = functools.partial(
        _paged_prefill_kernel,
        page_size=page, n_logical=nL, n_chunk=C, causal=causal,
        window=window, softcap=softcap, sm_scale=sm_scale,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, nL),
        in_specs=[
            pl.BlockSpec(
                (None, None, G * C, d_pad),
                lambda b, h, p, tbl, lens, start: (b, h, 0, 0),
            ),
            pl.BlockSpec(
                (None, None, page, d_pad),
                lambda b, h, p, tbl, lens, start: (h, tbl[b, p], 0, 0),
            ),
            pl.BlockSpec(
                (None, None, page, d_pad),
                lambda b, h, p, tbl, lens, start: (h, tbl[b, p], 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (None, None, G * C, d_pad),
            lambda b, h, p, tbl, lens, start: (b, h, 0, 0),
        ),
        scratch_shapes=[
            pltpu.VMEM((G * C, d_pad), jnp.float32),
            pltpu.VMEM((G * C, 1), jnp.float32),
            pltpu.VMEM((G * C, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G * C, d_pad), q.dtype),
        interpret=interpret,
    )(tbl, lens, start, qh, kh, vh)
    out = out.reshape(B, Hkv, G, C, d_pad).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, C, Hq, d_pad)[..., :D]


def paged_attention_pallas(
    q, k_pages, v_pages, block_tables, *, q_position, cache_len,
    window: int | None = None, softcap: float | None = None,
    interpret: bool = True,
):
    """q: (B,1,Hq,D); k_pages/v_pages: (P, page, Hkv, D); block_tables:
    (B, n_logical) int32 (``-1`` = unallocated). Returns (B,1,Hq,D).

    Head dim is padded to the 128-lane width; the pool is transposed to
    (Hkv, P, page, d) so one BlockSpec step fetches one head's page.
    """
    pltpu = compat.pallas_tpu()
    B, _, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    nL = block_tables.shape[-1]
    G = Hq // Hkv
    sm_scale = 1.0 / math.sqrt(D)
    d_pad = -(-D // 128) * 128

    qh = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, d_pad - D)))
    qh = qh.reshape(B, Hkv, G, d_pad)  # head h -> (h // G, h % G), as dense
    kh = jnp.pad(k_pages, ((0, 0), (0, 0), (0, 0), (0, d_pad - D)))
    vh = jnp.pad(v_pages, ((0, 0), (0, 0), (0, 0), (0, d_pad - D)))
    kh = kh.transpose(2, 0, 1, 3)  # (Hkv, P, page, d)
    vh = vh.transpose(2, 0, 1, 3)

    tbl = jnp.clip(block_tables.astype(jnp.int32), 0, P - 1)
    lens = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,)
    )
    qpos = jnp.broadcast_to(
        jnp.asarray(q_position, jnp.int32).reshape(-1), (B,)
    )

    kernel = functools.partial(
        _paged_kernel,
        page_size=page, n_logical=nL, window=window, softcap=softcap,
        sm_scale=sm_scale,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, nL),
        in_specs=[
            pl.BlockSpec(
                (None, None, G, d_pad), lambda b, h, p, tbl, lens, qpos: (b, h, 0, 0)
            ),
            pl.BlockSpec(
                (None, None, page, d_pad),
                lambda b, h, p, tbl, lens, qpos: (h, tbl[b, p], 0, 0),
            ),
            pl.BlockSpec(
                (None, None, page, d_pad),
                lambda b, h, p, tbl, lens, qpos: (h, tbl[b, p], 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (None, None, G, d_pad), lambda b, h, p, tbl, lens, qpos: (b, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G, d_pad), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, d_pad), q.dtype),
        interpret=interpret,
    )(tbl, lens, qpos, qh, kh, vh)
    return out.reshape(B, Hq, d_pad)[:, None, :, :D]
