"""Fused RMSNorm TPU kernel (pl.pallas_call + BlockSpec VMEM tiling).

One pass over rows: each program instance normalizes a (block_rows, d)
tile fully inside VMEM (reduction + scale in registers; a single HBM read
and write per element, vs read-reduce-read-write for the unfused lowering).
d is padded to the 128-lane width by the wrapper; the mean uses the true
d so padding does not bias the variance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat

pl = compat.pallas()


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float, true_d: int,
                    zero_centered: bool):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, d_pad)
    # padded lanes are zero and do not contribute; divide by true_d
    var = jnp.sum(x * x, axis=-1, keepdims=True) / true_d
    y = x * jax.lax.rsqrt(var + eps)
    s = s_ref[...].astype(jnp.float32)
    if zero_centered:
        s = 1.0 + s
    o_ref[...] = (y * s[None, :]).astype(o_ref.dtype)


def rmsnorm_pallas(
    x, scale, eps: float = 1e-6, zero_centered: bool = False,
    block_rows: int = 256, interpret: bool = True,
):
    """x: (..., d); scale: (d,). Returns same shape/dtype as x."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)

    d_pad = -(-d // 128) * 128
    block_rows = min(block_rows, rows)
    rows_pad = -(-rows // block_rows) * block_rows
    x2 = jnp.pad(x2, ((0, rows_pad - rows), (0, d_pad - d)))
    sp = jnp.pad(scale, (0, d_pad - d))

    kernel = functools.partial(
        _rmsnorm_kernel, eps=eps, true_d=d, zero_centered=zero_centered
    )
    out = pl.pallas_call(
        kernel,
        grid=(rows_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((d_pad,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, d_pad), x.dtype),
        interpret=interpret,
    )(x2, sp)
    return out[:rows, :d].reshape(orig_shape)
