"""Pure-jnp oracle for the fused RMSNorm kernel."""

from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_reference(x, scale, eps: float = 1e-6, zero_centered: bool = False):
    """x: (..., d); scale: (d,)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * (var + eps) ** -0.5
    s = scale.astype(jnp.float32)
    if zero_centered:
        s = 1.0 + s
    return (y * s).astype(x.dtype)
