"""jit'd public wrapper for the fused RMSNorm kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.ref import rmsnorm_reference


@functools.partial(
    jax.jit, static_argnames=("eps", "zero_centered", "block_rows", "impl")
)
def rmsnorm(
    x, scale, *, eps: float = 1e-6, zero_centered: bool = False,
    block_rows: int = 256, impl: str = "auto",
):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "reference"
    if impl == "reference":
        return rmsnorm_reference(x, scale, eps, zero_centered)
    # lazy: the kernel module needs Pallas at import time, and the
    # reference path must stay usable on builds without it
    from repro.kernels.rmsnorm.kernel import rmsnorm_pallas

    return rmsnorm_pallas(
        x, scale, eps, zero_centered, block_rows=block_rows,
        interpret=(impl == "interpret"),
    )
