from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.paged_attention.ops import (
    paged_attention,
    paged_prefill_attention,
)
from repro.kernels.rmsnorm.ops import rmsnorm

__all__ = [
    "flash_attention",
    "paged_attention",
    "paged_prefill_attention",
    "rmsnorm",
]
