"""jit'd public wrapper for the flash attention kernel.

``flash_attention(...)`` routes to the Pallas kernel on TPU (or in
interpret mode when asked) and to the pure-jnp oracle otherwise. The model
stack can swap its chunked-scan attention for this op via
``ModelConfig.use_pallas`` on real hardware.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.ref import attention_reference


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_kv", "impl"),
)
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    impl: str = "auto",  # auto | pallas | interpret | reference
):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    if impl == "reference":
        return attention_reference(
            q, k, v, causal=causal, window=window, softcap=softcap
        )
    # lazy: the kernel module needs Pallas at import time, and the
    # reference path must stay usable on builds without it
    from repro.kernels.flash_attention.kernel import flash_attention_pallas

    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv,
        interpret=(impl == "interpret"),
    )
