"""Flash attention TPU kernel (pl.pallas_call + explicit BlockSpec tiling).

TPU adaptation of the FlashAttention-2 schedule (DESIGN.md §9):
  * grid = (batch*kv_heads, q_blocks); each program instance owns one
    (B*Hkv, q_block) tile and streams kv blocks through VMEM with the
    online-softmax recurrence — scores never touch HBM (the dominant term
    of the §Roofline memory analysis for train/prefill cells);
  * block shapes are MXU-aligned (q_block x d and kv_block x d tiles,
    d padded to a 128 multiple by the wrapper);
  * the kv loop is a fori_loop with a causal upper bound: fully-future
    blocks are never fetched (compute AND bandwidth saving vs masking);
  * GQA handled by indexing the kv head = q head // group outside the
    kernel (the wrapper reshapes to one kv head per program).

Validated in interpret mode on CPU against ref.attention_reference across
shapes/dtypes (tests/test_kernels.py); on real TPUs the same code lowers
through Mosaic.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro import compat

pl = compat.pallas()

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, *,
    kv_seq_len: int, block_kv: int, causal: bool,
    window: int | None, softcap: float | None, block_q: int, sm_scale: float,
):
    """One (q_block x head_dim) tile vs the full kv stream.

    Refs (VMEM):
      q_ref: (block_q, d)    k_ref/v_ref: (kv_seq_len, d)    o_ref: (block_q, d)
    """
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * sm_scale
    d = q.shape[-1]

    q_start = qi * block_q
    num_kv_blocks = pl.cdiv(kv_seq_len, block_kv)
    if causal:
        # last kv block any row of this q tile can see
        hi = jax.lax.div(q_start + block_q - 1, block_kv) + 1
        hi = jnp.minimum(hi, num_kv_blocks)
    else:
        hi = num_kv_blocks

    def body(ki, carry):
        o, m, l = carry
        k = pl.load(k_ref, (pl.dslice(ki * block_kv, block_kv), pl.dslice(None)))
        v = pl.load(v_ref, (pl.dslice(ki * block_kv, block_kv), pl.dslice(None)))
        s = q @ k.astype(jnp.float32).T  # (block_q, block_kv)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_seq_len
        if causal:
            mask &= kpos <= qpos
        if window is not None and window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp(m - m_safe))
        l = l * alpha + jnp.sum(p, axis=-1)
        o = o * alpha[:, None] + p @ v.astype(jnp.float32)
        return o, m_new, l

    o0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    o, m, l = jax.lax.fori_loop(0, hi, body, (o0, m0, l0))
    o_ref[...] = (o / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q, k, v, *,
    causal: bool = True, window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128, block_kv: int = 128,
    interpret: bool = True,
):
    """q: (B,Sq,Hq,D); k,v: (B,Sk,Hkv,D). Returns (B,Sq,Hq,D).

    The wrapper maps GQA onto a (B*Hq, q_blocks) grid: each q head reads
    its kv head (Hq//G). Head dim is padded to a multiple of 128 (MXU lane
    width); seq dims to their block sizes.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    sm_scale = 1.0 / math.sqrt(D)

    d_pad = -(-D // 128) * 128
    sq_pad = -(-Sq // block_q) * block_q
    sk_pad = -(-Sk // block_kv) * block_kv

    qp = jnp.pad(q, ((0, 0), (0, sq_pad - Sq), (0, 0), (0, d_pad - D)))
    kp = jnp.pad(k, ((0, 0), (0, sk_pad - Sk), (0, 0), (0, d_pad - D)))
    vp = jnp.pad(v, ((0, 0), (0, sk_pad - Sk), (0, 0), (0, d_pad - D)))

    # (B*Hq, S, d) with q head -> kv head mapping
    qh = qp.transpose(0, 2, 1, 3).reshape(B * Hq, sq_pad, d_pad)
    kh = kp.transpose(0, 2, 1, 3)
    vh = vp.transpose(0, 2, 1, 3)
    head_map = jnp.repeat(jnp.arange(Hkv), G)  # q head -> kv head
    kh = kh[:, head_map].reshape(B * Hq, sk_pad, d_pad)
    vh = vh[:, head_map].reshape(B * Hq, sk_pad, d_pad)

    grid = (B * Hq, sq_pad // block_q)
    kernel = functools.partial(
        _flash_kernel,
        kv_seq_len=Sk, block_kv=block_kv, causal=causal,
        window=window, softcap=softcap, block_q=block_q, sm_scale=sm_scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d_pad), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, sk_pad, d_pad), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((None, sk_pad, d_pad), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d_pad), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, sq_pad, d_pad), q.dtype),
        interpret=interpret,
    )(qh, kh, vh)

    out = out.reshape(B, Hq, sq_pad, d_pad)[:, :, :Sq, :D].transpose(0, 2, 1, 3)
    return out
