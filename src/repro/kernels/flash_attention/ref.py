"""Pure-jnp oracle for the flash attention kernel.

Naive O(S^2)-memory attention with the exact same semantics the kernel
implements: GQA, causal/bidirectional, sliding window, logit softcap.
"""

from __future__ import annotations

import math

import jax.numpy as jnp


def attention_reference(
    q, k, v, *, causal: bool = True, window: int | None = None,
    softcap: float | None = None,
):
    """q: (B,Sq,Hq,D); k,v: (B,Sk,Hkv,D); Hq = G*Hkv. Returns (B,Sq,Hq,D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)  # head h -> (h//G, h%G)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bghqk", qf, kf) / math.sqrt(D)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None and window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bghqk,bkhd->bghqd", p, vf)
    return o.transpose(0, 3, 2, 1, 4).reshape(B, Sq, Hq, D).astype(q.dtype)
