"""Sharding rules: logical axes -> mesh axes, per architecture.

Two strategies (DESIGN.md §6), auto-validated against the arch's dimensions:

* ``megatron`` — tensor parallelism over the "model" axis (attention heads,
  FFN hidden, experts, vocab), FSDP (ZeRO-3) over the "data" axis on every
  parameter's embed dim, sequence-parallel residual stream over "model",
  batch over ("pod", "data"). Used when heads/dff divide the model axis.

* ``fsdp`` — parameters sharded over the flattened ("data","model") product
  on their largest divisible dim (pure ZeRO-3), activations batch-sharded
  over ("pod","data") with the residual stream sequence-sharded over
  "model" (context parallelism in attention: q stays seq-sharded, k/v
  gather). Used for archs whose head counts do not divide the model axis
  (gemma2-2b: 8 heads, xlstm-350m: 4 heads).

Rules are plain dicts consumed by layers.common.param_pspecs /
LogicalConstraints, so a strategy change never touches model code.
"""

from __future__ import annotations

from typing import Any

from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

BATCH_AXES = ("pod", "data")


def _axis(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def param_rules(cfg, mesh: Mesh) -> dict[str, Any]:
    """Logical param axis -> mesh axes."""
    model = _axis(mesh, "model")
    data_axes = tuple(a for a in BATCH_AXES if _axis(mesh, a) > 1) or ("data",)
    strategy = effective_strategy(cfg, mesh)

    if strategy == "megatron":
        rules = {
            "embed": "data",
            "embed_out": "data",
            "qkv": "model",
            "kv": "model",
            "mlp": "model",
            "experts": "model",
            "expert_mlp": None,
            "vocab": "model",
            "inner": "model",
            "inner_all": "model",
            "inner_q": "model",
            "ssm_heads": "model" if cfg.ssm and _div(cfg.ssm.n_heads(cfg.d_model), model) else None,
            "layers": None,
        }
    else:  # fsdp: one big ZeRO-3 domain over (data x model)
        fsdp_axes = tuple(a for a in ("data", "model") if _axis(mesh, a) > 1) or ("data",)
        rules = {
            "embed": fsdp_axes,
            "embed_out": None,
            "qkv": None,
            "kv": None,
            "mlp": fsdp_axes,       # on the (d_model, d_ff) input dim? no: mlp dim
            "experts": "model" if cfg.moe and _div(cfg.moe.n_experts, model) else None,
            "expert_mlp": None,
            "vocab": "model",
            "inner": fsdp_axes,
            "inner_all": fsdp_axes,
            "inner_q": None,
            "ssm_heads": None,
            "layers": None,
        }
        # mlp weights are ("embed","mlp")/("mlp","embed_out"): embed already
        # carries the fsdp axes; mlp must not reuse them
        rules["mlp"] = None
    return rules


def activation_rules(cfg, mesh: Mesh) -> dict[str, Any]:
    model = _axis(mesh, "model")
    batch = tuple(a for a in BATCH_AXES if _axis(mesh, a) > 1) or ("data",)
    strategy = effective_strategy(cfg, mesh)
    if strategy == "megatron":
        return {
            "batch": batch,
            "seq": "model",       # sequence-parallel residual stream
            "seq_q": None,
            "seq_kv": None,
            "seq_mlp": None,
            "heads": "model",
            "kv_heads": "model" if _div(cfg.n_kv_heads, model) else None,
            "mlp": "model",
            "experts": "model",
            "expert_cap": "data",
            "expert_mlp": None,
            "inner": "model",
            "vocab": "model",
        }
    return {
        # fsdp: batch over the whole fabric when divisible (shape-aware
        # constraint backs off to a divisible prefix otherwise)
        "batch": batch + ("model",),
        "seq": "model",           # residual stream still sequence-parallel
        "seq_q": "model",         # context parallel: q stays seq-sharded
        "seq_kv": None,           # k/v gathered once per layer
        "seq_mlp": "model",
        "heads": None,
        "kv_heads": None,
        "mlp": None,
        "experts": None,
        "expert_cap": None,
        "expert_mlp": None,
        "inner": None,
        "vocab": "model",
    }


def effective_strategy(cfg, mesh: Mesh) -> str:
    """Validate the requested strategy against arch dims; fall back to fsdp
    when tensor parallelism cannot shard the heads."""
    model = _axis(mesh, "model")
    if cfg.sharding == "megatron":
        heads_ok = _div(cfg.n_heads, model)
        dff_ok = cfg.d_ff == 0 or _div(cfg.d_ff, model)
        if heads_ok and (dff_ok or cfg.moe):
            return "megatron"
        return "fsdp"
    return cfg.sharding


def batch_pspec(cfg, mesh: Mesh) -> P:
    batch = tuple(a for a in BATCH_AXES if _axis(mesh, a) > 1) or ("data",)
    return P(batch)


def divisible_batch_axes(mesh: Mesh, batch: int):
    """Longest prefix of the batch axes whose product divides ``batch``
    (long_500k has batch=1 => no batch sharding)."""
    axes = []
    prod = 1
    for a in BATCH_AXES:
        size = _axis(mesh, a)
        if size <= 1:
            continue
        if batch % (prod * size) == 0:
            axes.append(a)
            prod *= size
        else:
            break
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def data_shards(mesh: Mesh) -> int:
    return _axis(mesh, "pod") * _axis(mesh, "data")


def input_shardings(cfg, mesh: Mesh, batch_spec_tree):
    """NamedShardings for a batch pytree: leading dim = global batch."""
    bp = batch_pspec(cfg, mesh)

    def f(x):
        ndim = len(x.shape)
        return compat.named_sharding(mesh, P(bp[0], *([None] * (ndim - 1))))

    import jax

    return jax.tree_util.tree_map(f, batch_spec_tree)
