"""Elastic scaling: move a training state between meshes of different size.

Combines checkpoint restore with target-mesh shardings: the state saved on
an N-chip mesh is re-placed (device_put against the new NamedShardings) on
an M-chip mesh. Used on node failure (shrink) or capacity gain (grow);
tested across 8->4 and 4->8 device CPU meshes.
"""

from __future__ import annotations

import jax

from repro import compat


def reshard_state(state_tree, target_mesh, target_pspecs):
    """Re-place every leaf of ``state_tree`` per ``target_pspecs`` on
    ``target_mesh``. Arrays come back to host once, then out to the new
    mesh (host staging keeps peak device memory at one shard)."""

    def f(leaf, pspec):
        host = jax.device_get(leaf)
        return compat.device_put(host, compat.named_sharding(target_mesh, pspec))

    return jax.tree_util.tree_map(f, state_tree, target_pspecs)
