from repro.distributed import sharding
from repro.distributed.elastic import reshard_state

__all__ = ["sharding", "reshard_state"]
