"""repro.session — the single public instrumentation surface.

The paper's headline claim is *ease of integration*: TALP attaches to an
unmodified binary via LD_PRELOAD + environment variables. ``PerfSession``
is this repository's analogue — one facade through which every entry point
(training loop, serving scheduler, launchers, benchmarks, examples) touches
instrumentation, with the concrete collector chosen by config **or** purely
by environment:

    TALP_ENABLE=1 TALP_BACKEND=monitor python examples/quickstart.py
    TALP_ENABLE=1 TALP_BACKEND=tracer  python -m repro.launch.train ...
    TALP_OUT=talp/mycase/history      # redirect finalize() artifacts

Backends (the ``Collector`` protocol):

  monitor   TalpMonitor — O(regions) on-the-fly POP collection (the paper's
            DLB/TALP module)
  tracer    TraceRecorder + post_process — the full-event Score-P/Extrae
            baseline; same RunRecord out, orders of magnitude more state
  null      no instrumentation; every hook is a no-op and ``wrap_step``
            returns the function unchanged (true zero overhead)

Surface:

  session.region(name)            context manager AND decorator
  session.wrap_step(fn, ...)      derive the StepProfile from the compiled
                                  function (compat cost accessors), attach
                                  it to ``region``, and per call: enter the
                                  region, execute, observe the step
  session.observe_step(...)       manual per-step observation
  session.finalize(out_dir)       stop, build the RunRecord, inject git
                                  metadata, save into the CI folder layout

Legacy ``TalpMonitor``/``TraceRecorder`` construction via ``repro.core``
still works for one release but emits a ``DeprecationWarning``.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import tempfile
import time
from typing import Any, Callable, Protocol, runtime_checkable

from repro.core.records import (
    DEFAULT_TOP_COMPUTATIONS,
    ResourceConfig,
    RunRecord,
)

# environment contract — the LD_PRELOAD analogue
ENV_ENABLE = "TALP_ENABLE"
ENV_BACKEND = "TALP_BACKEND"
ENV_OUT = "TALP_OUT"

BACKENDS = ("monitor", "tracer", "null")

_FALSY = {"0", "false", "no", "off", ""}


def env_backend(default: str | None = None) -> str | None:
    """Resolve the backend requested through the environment.

    Returns None when ``TALP_ENABLE`` is unset (no env override), ``"null"``
    when it is set falsy (explicit kill switch), else the backend named by
    ``TALP_BACKEND`` (falling back to ``default`` or ``"monitor"``).
    """
    raw = os.environ.get(ENV_ENABLE)
    if raw is None:
        return None
    if raw.strip().lower() in _FALSY:
        return "null"
    backend = os.environ.get(ENV_BACKEND, "").strip().lower() or default or "monitor"
    if backend not in BACKENDS:
        raise ValueError(
            f"{ENV_BACKEND}={backend!r} is not one of {BACKENDS}"
        )
    return backend


@dataclasses.dataclass
class SessionConfig:
    """Session-level knobs; backend-specific config is derived from these."""

    app_name: str = "app"
    backend: str = "null"  # "monitor" | "tracer" | "null"
    hardware: str = "tpu_v5e"
    sync_regions: bool = True
    lb_sample_every: int = 10
    overlap_fraction: float = 0.0
    top_computations: int = DEFAULT_TOP_COMPUTATIONS
    trace_dir: str = ""  # tracer backend event-stream directory
    out_dir: str = ""  # default finalize() destination (CI folder layout)
    clock: Callable[[], float] = time.perf_counter
    # honor TALP_ENABLE / TALP_BACKEND (off for overhead baselines so the
    # environment cannot skew a measurement)
    respect_env: bool = True


# ---------------------------------------------------------------------------
# the Collector protocol + its three backends
# ---------------------------------------------------------------------------


@runtime_checkable
class Collector(Protocol):
    """What a PerfSession backend must provide. ``finalize`` may return None
    (the null backend has nothing to report)."""

    name: str

    def start(self) -> None: ...

    def stop(self) -> None: ...

    def region_enter(self, name: str) -> None: ...

    def region_exit(self, name: str, sync: Any = None) -> None: ...

    def observe_step(self, outputs: Any = None, **aux: Any) -> None: ...

    def mark_device(self) -> None: ...

    def attach_static(self, region: str, profile: Any) -> None: ...

    def finalize(self) -> RunRecord | None: ...


class NullCollector:
    """Zero-overhead backend: every hook is a no-op."""

    name = "null"

    def start(self) -> None:
        pass

    def stop(self) -> None:
        pass

    def region_enter(self, name: str) -> None:
        pass

    def region_exit(self, name: str, sync: Any = None) -> None:
        pass

    def observe_step(self, outputs: Any = None, **aux: Any) -> None:
        pass

    def mark_device(self) -> None:
        pass

    def attach_static(self, region: str, profile: Any) -> None:
        pass

    def finalize(self) -> RunRecord | None:
        return None


def _monitor_collector(config: SessionConfig, resources: ResourceConfig):
    """The TALP path: ``TalpMonitor`` satisfies the Collector protocol
    directly (on-the-fly O(regions) accumulation, core.monitor)."""
    from repro.core.monitor import MonitorConfig, TalpMonitor

    return TalpMonitor(
        MonitorConfig(
            app_name=config.app_name,
            hardware=config.hardware,
            sync_regions=config.sync_regions,
            lb_sample_every=config.lb_sample_every,
            overlap_fraction=config.overlap_fraction,
            top_computations=config.top_computations,
            clock=config.clock,
        ),
        resources,
    )


class TracerCollector:
    """The Score-P/Extrae baseline: full event streams + post-processing
    (core.tracer). Same RunRecord out — the cross-tool agreement contract."""

    name = "tracer"

    # monitor-only observation kwargs the tracer's event schema has no
    # representation for (post_process only understands array-valued aux)
    _DROP_AUX = ("pod_size",)

    def __init__(self, config: SessionConfig, resources: ResourceConfig) -> None:
        self._config = config
        self._resources = resources
        self._recorder = None
        self._ever_started = False
        self._pre_start_static: dict[str, Any] = {}
        self.trace_dir = config.trace_dir

    def start(self) -> None:
        from repro.core.tracer import TraceRecorder

        if self._recorder is not None:
            raise RuntimeError("tracer session already started")
        self._ever_started = True
        if not self.trace_dir:
            self.trace_dir = tempfile.mkdtemp(prefix="talp_trace_")
        self._recorder = TraceRecorder(
            self.trace_dir,
            self._resources,
            app_name=self._config.app_name,
            clock=self._config.clock,
        )
        for region, profile in self._pre_start_static.items():
            self._recorder.attach_static(region, profile)
        self._pre_start_static.clear()

    def stop(self) -> None:
        if self._recorder is not None:
            self._recorder.close()
            self._recorder = None

    def region_enter(self, name: str) -> None:
        if self._recorder is None:
            self.start()  # parity with the monitor's region auto-start
        self._recorder.region_enter(name)

    def region_exit(self, name: str, sync: Any = None) -> None:
        if self._recorder is not None:
            self._recorder.region_exit(name)

    def observe_step(self, outputs: Any = None, **aux: Any) -> None:
        if self._recorder is None:
            return  # outside a started session: silent, like the monitor
        kept = {
            k: v for k, v in aux.items()
            if v is not None and k not in self._DROP_AUX
        }
        self._recorder.record_step(outputs, **kept)

    def mark_device(self) -> None:
        pass  # device-time marks are reconstructed from the event timeline

    def attach_static(self, region: str, profile: Any) -> None:
        if self._recorder is None:  # profiles attached before start()
            self._pre_start_static[region] = profile
        else:
            self._recorder.attach_static(region, profile)

    def finalize(self) -> RunRecord:
        from repro.core import factors as _factors
        from repro.core.tracer import post_process

        if not self._ever_started:
            self.start()  # finalize without start: emit an empty valid trace
        self.stop()
        run = post_process(self.trace_dir)
        # post_process knows nothing of session-level knobs; re-derive the
        # factors under the session's hardware/overlap model so both
        # backends answer through one contract
        run.hardware = self._config.hardware
        for reg in run.regions.values():
            reg.pop = _factors.compute_pop(
                reg, run.resources, self._config.hardware,
                overlap_fraction=self._config.overlap_fraction,
            )
        return run


def make_collector(
    backend: str, config: SessionConfig, resources: ResourceConfig
) -> Collector:
    if backend == "monitor":
        return _monitor_collector(config, resources)
    if backend == "tracer":
        return TracerCollector(config, resources)
    if backend == "null":
        return NullCollector()
    raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")


# ---------------------------------------------------------------------------
# region handles — context manager AND decorator
# ---------------------------------------------------------------------------


class _NullRegion:
    """Shared no-op handle: zero allocation per disabled region visit."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False

    def __call__(self, fn):
        return fn


_NULL_REGION = _NullRegion()


class _Region:
    __slots__ = ("_session", "name", "sync")

    def __init__(self, session: "PerfSession", name: str, sync: Any = None):
        self._session = session
        self.name = name
        self.sync = sync

    def __enter__(self) -> "PerfSession":
        ses = self._session
        if not ses._started:
            ses.start()
        ses._collector.region_enter(self.name)
        return ses

    def __exit__(self, *exc) -> bool:
        self._session._collector.region_exit(self.name, self.sync)
        return False

    def __call__(self, fn: Callable) -> Callable:
        ses, name, sync = self._session, self.name, self.sync

        @functools.wraps(fn)
        def wrapped(*args, **kw):
            with _Region(ses, name, sync):
                return fn(*args, **kw)

        return wrapped


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------


def _looks_compiled(obj: Any) -> bool:
    """A compiled XLA executable exposes the compat cost accessors."""
    return hasattr(obj, "as_text") or hasattr(obj, "cost_analysis")


def _default_observe(out: Any) -> dict[str, Any]:
    """Pull the monitor observables out of a step result: a metrics dict, or
    a ``(state, metrics)``-style tuple whose last element is the dict."""
    metrics = None
    if isinstance(out, dict):
        metrics = out
    elif isinstance(out, (tuple, list)) and out and isinstance(out[-1], dict):
        metrics = out[-1]
    if metrics is None:
        return {"outputs": out}
    return {
        "outputs": metrics,
        "tokens_per_shard": metrics.get("tokens_per_shard"),
        "expert_load": metrics.get("expert_load"),
    }


class PerfSession:
    """One run's instrumentation handle — the only object user code needs.

    >>> session = PerfSession(SessionConfig(app_name="train", backend="monitor"))
    >>> step = session.wrap_step(compiled_step, region="train_step")
    >>> with session:
    ...     for batch in batches:
    ...         state, metrics = step(state, batch)
    >>> session.finalize("talp/mycase/history")

    With the default ``backend="null"`` every hook is free, and the same
    program gains full monitoring from ``TALP_ENABLE=1`` alone.
    """

    def __init__(
        self,
        config: SessionConfig | None = None,
        resources: ResourceConfig | None = None,
        metadata: dict[str, Any] | None = None,
    ) -> None:
        self.config = config or SessionConfig()
        backend = self.config.backend
        if self.config.respect_env:
            override = env_backend(default=backend if backend != "null" else None)
            if override is not None:
                backend = override
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
        self.backend = backend
        self.resources = resources or ResourceConfig()
        self.metadata = dict(metadata or {})
        self._collector: Collector = make_collector(backend, self.config, self.resources)
        self._started = False
        self._stopped = False
        self.last_record_path: str | None = None

    # -- lifecycle ------------------------------------------------------

    @property
    def enabled(self) -> bool:
        return self.backend != "null"

    @property
    def collector(self) -> Collector:
        return self._collector

    def start(self) -> "PerfSession":
        if not self._started:
            self._started = True
            self._collector.start()
        return self

    def stop(self) -> None:
        if self._started and not self._stopped:
            self._stopped = True
            self._collector.stop()

    def __enter__(self) -> "PerfSession":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- regions --------------------------------------------------------

    def region(self, name: str, sync: Any = None):
        """A handle usable as context manager *and* decorator:

        >>> with session.region("train_step"): ...
        >>> @session.region("evaluate")
        ... def evaluate(...): ...
        """
        if not self.enabled:
            return _NULL_REGION
        return _Region(self, name, sync)

    def event(self, name: str, outputs: Any = None, **aux: Any) -> None:
        """One-shot region visit for sparse, host-side events (a retry, a
        quarantine, a watchdog trip): enter the region, record one observed
        step carrying ``aux``, and exit — so rare recovery actions show up
        in the report next to the hot-loop regions without the caller
        managing a context. No-op when the session is disabled."""
        if not self.enabled:
            return
        with self.region(name):
            self.observe_step(outputs, **aux)

    # -- per-step hooks (thin passthroughs; patchable per instance) -----

    def observe_step(self, outputs: Any = None, **aux: Any) -> None:
        if self.enabled:
            self._collector.observe_step(outputs, **aux)

    def mark_device(self) -> None:
        if self.enabled:
            self._collector.mark_device()

    def attach_static(self, region: str, profile: Any) -> None:
        if self.enabled:
            self._collector.attach_static(region, profile)

    # -- the integration one-liner --------------------------------------

    def wrap_step(
        self,
        fn: Callable,
        region: str = "step",
        *,
        compiled: Any = None,
        profile: Any = None,
        num_devices: int = 1,
        devices_per_pod: int | None = None,
        model_flops: float = 0.0,
        model_bytes: float = 0.0,
        derive: bool = False,
        observe: Callable[[Any], dict[str, Any]] | None = None,
    ) -> Callable:
        """Instrument a step function in one call.

        Derives the static ``StepProfile`` from the compiled executable
        (``compiled=`` when the caller kept it, ``fn`` itself when it *is*
        the executable, or — with ``derive=True`` — by AOT-lowering a
        jit-wrapped ``fn`` on its first call) and attaches it to ``region``.
        Each call then enters ``region``, executes, and observes the step;
        ``observe`` maps the step result to ``observe_step`` kwargs (an
        ``"outputs"`` key overrides what is blocked on; default: pull
        ``tokens_per_shard``/``expert_load`` from a metrics dict result).

        With the null backend the original function is returned unchanged —
        the instrumented and uninstrumented programs are the same object.
        """
        if not self.enabled:
            return fn

        from repro.core.profile import StepProfile

        def _derive(executable) -> None:
            self.attach_static(
                region,
                StepProfile.from_compiled(
                    executable,
                    num_devices=num_devices,
                    devices_per_pod=devices_per_pod,
                    model_flops=model_flops,
                    model_bytes=model_bytes,
                ),
            )

        pending_lower = False
        if profile is not None:
            self.attach_static(region, profile)
        elif compiled is not None:
            _derive(compiled)
        elif _looks_compiled(fn):
            _derive(fn)
        elif derive and hasattr(fn, "lower"):
            pending_lower = True  # AOT-lower with the first call's arguments

        state = {"pending": pending_lower}
        sync_outputs = self.config.sync_regions
        obs_fn = observe or _default_observe
        # one region handle reused across calls (it keeps no per-entry
        # state): a serving scheduler dispatches through wrapped steps tens
        # of thousands of times per second, and a per-call allocation on the
        # dispatch path is exactly the overhead the paper's Table 1 budgets
        # against. Several wrapped steps on one session (e.g. the
        # scheduler's decode + prefill regions) each hold their own handle.
        handle = _Region(self, region)

        @functools.wraps(fn)
        def wrapped(*args, **kw):
            if state["pending"]:
                state["pending"] = False
                _derive(fn.lower(*args, **kw).compile())
            with handle:
                out = fn(*args, **kw)
                obs = dict(obs_fn(out))
                outputs = obs.pop("outputs", out)
                self.observe_step(outputs if sync_outputs else None, **obs)
            return out

        return wrapped

    # -- finalize: record + git metadata + CI folder layout, in one call -

    def finalize(
        self,
        out_dir: str | None = None,
        *,
        save: bool = True,
        git: bool | str = "auto",
    ) -> RunRecord | None:
        """Stop collection and build the RunRecord. Injects git metadata
        (commit, branch, commit timestamp — the ``talp metadata`` step) and,
        when a destination is known, writes ``talp_<label>_<ts>.json`` into
        it (the CI folder layout). ``TALP_OUT`` overrides any destination so
        artifacts can be redirected with zero code changes. ``git="auto"``
        injects exactly when the record is persisted (a CI artifact wants
        commit provenance; an in-memory record stays clean for synthetic
        timestamps). Returns None for the null backend."""
        self.stop()
        run = self._collector.finalize()
        if run is None:
            return None
        for k, v in self.metadata.items():
            run.metadata.setdefault(k, v)
        # the env redirection is part of the env-activation contract, so a
        # respect_env=False session (benchmarks, synthetic fixtures) must not
        # leak artifacts into a globally exported TALP_OUT
        env_dest = os.environ.get(ENV_OUT) if self.config.respect_env else None
        dest = env_dest or out_dir or self.config.out_dir
        will_save = bool(save and dest)
        if git is True or (git == "auto" and will_save):
            from repro.core.folder import git_metadata

            for k, v in git_metadata().items():
                run.metadata.setdefault(k, v)
        if will_save:
            fname = f"talp_{run.resources.label}_{run.timestamp.replace(':', '')[:17]}.json"
            path = os.path.join(dest, fname)
            run.save(path)
            self.last_record_path = path
        return run


_NULL_SESSION: PerfSession | None = None


def null_session() -> PerfSession:
    """A shared always-disabled session (for default arguments)."""
    global _NULL_SESSION
    if _NULL_SESSION is None:
        _NULL_SESSION = PerfSession(SessionConfig(backend="null", respect_env=False))
    return _NULL_SESSION


def start(
    app_name: str = "app",
    backend: str | None = None,
    *,
    resources: ResourceConfig | None = None,
    metadata: dict[str, Any] | None = None,
    **config_kw: Any,
) -> PerfSession:
    """Create and start a session in one call — ``repro.start()``.

    ``backend=None`` means "off unless the environment says otherwise": an
    entry point calling ``repro.start()`` unconditionally costs nothing by
    default and gains full monitoring from ``TALP_ENABLE=1`` alone.
    """
    cfg = SessionConfig(app_name=app_name, backend=backend or "null", **config_kw)
    return PerfSession(cfg, resources=resources, metadata=metadata).start()
