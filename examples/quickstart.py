"""Quickstart: train a reduced-config model with first-class TALP
monitoring, print the POP factors, write a TALP-Pages run record.

    PYTHONPATH=src python examples/quickstart.py

All instrumentation flows through the one surface, ``repro.session``: the
training loop owns a ``PerfSession`` and ``loop.finalize_run(out_dir)``
writes the schema-v3 run record (git metadata included) into the CI folder
layout. The environment can re-point or re-plug it with zero code changes:

    TALP_ENABLE=1                     # force collection on
    TALP_ENABLE=1 TALP_BACKEND=tracer # swap the collector backend
    TALP_ENABLE=0                     # kill switch: no collection at all
    TALP_OUT=talp/quickstart/history  # redirect the artifact
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import smoke_config
from repro.core import factors as F
from repro.core import render_text, build_table
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.train import TrainConfig


def main():
    cfg = smoke_config("tinyllama-1.1b")
    data = SyntheticLM(
        DataConfig(global_batch=4, seq_len=64, vocab=cfg.vocab, pad_fraction=0.1)
    )
    loop = TrainLoop(
        cfg, make_host_mesh(), TrainConfig(), data,
        LoopConfig(steps=10, lb_sample_every=1, monitor_app_name="quickstart"),
    )
    loop.run()

    print("losses:", [round(m["loss"], 3) for m in loop.metrics_history])

    # one call: finalize + git metadata + save into the CI folder layout
    run = loop.finalize_run("results/quickstart")
    if run is None:  # TALP_ENABLE=0 disabled collection entirely
        print("monitoring disabled by environment; no run record")
        return
    print(f"\nTALP run record: {loop.session.last_record_path}")

    reg = run.regions["train_step"]
    print(f"\nPOP factors for region 'train_step' "
          f"({reg.measurements.num_steps} steps, "
          f"{reg.measurements.elapsed_s:.2f}s elapsed):")
    for key, depth in F.iter_tree():
        if key in reg.pop:
            print(f"  {'  ' * depth}{F.DISPLAY_NAMES[key]:<34} {reg.pop[key]:.3f}")

    table = build_table([run], region="train_step")
    print("\n" + render_text(table))


if __name__ == "__main__":
    main()
