"""Quickstart: train a reduced-config model with first-class TALP
monitoring, print the POP factors, write a TALP-Pages run record.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import smoke_config
from repro.core import factors as F
from repro.core import render_text, build_table
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.train import TrainConfig


def main():
    cfg = smoke_config("tinyllama-1.1b")
    data = SyntheticLM(
        DataConfig(global_batch=4, seq_len=64, vocab=cfg.vocab, pad_fraction=0.1)
    )
    loop = TrainLoop(
        cfg, make_host_mesh(), TrainConfig(), data,
        LoopConfig(steps=10, lb_sample_every=1, monitor_app_name="quickstart"),
    )
    loop.run()

    print("losses:", [round(m["loss"], 3) for m in loop.metrics_history])

    run = loop.finalize_run()
    out = "results/quickstart/talp_quickstart.json"
    run.save(out)
    print(f"\nTALP run record: {out}")

    reg = run.regions["train_step"]
    print(f"\nPOP factors for region 'train_step' "
          f"({reg.measurements.num_steps} steps, "
          f"{reg.measurements.elapsed_s:.2f}s elapsed):")
    for key, depth in F.iter_tree():
        if key in reg.pop:
            print(f"  {'  ' * depth}{F.DISPLAY_NAMES[key]:<34} {reg.pop[key]:.3f}")

    table = build_table([run], region="train_step")
    print("\n" + render_text(table))


if __name__ == "__main__":
    main()
