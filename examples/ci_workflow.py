"""Full CI workflow simulation — the paper's GENE-X integration (§CI
Workflow, listings 5/6) end to end on the mini-app:

  for each "commit":                          (performance job)
      run the performance experiment at two resource configurations
      write talp/<case>/<experiment>/talp_*.json
      talp metadata  (inject commit info)
  then:                                       (talp-pages job)
      talp merge-history  (previous pipeline's artifacts)
      talp ci-report -i talp -o public/talp --regions train_step
      -> static site with badges, scaling tables, time series, findings

    PYTHONPATH=src python examples/ci_workflow.py
"""

import json
import os
import shutil
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.pages import main as talp_cli

ROOT = "results/ci_workflow"
SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_JOB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import sys; sys.path.insert(0, {src!r})
import time
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.train import TrainConfig

cfg = smoke_config("tinyllama-1.1b")
data = SyntheticLM(DataConfig(global_batch=4, seq_len=64, vocab=cfg.vocab))
loop = TrainLoop(cfg, make_host_mesh(), TrainConfig(), data,
                 LoopConfig(steps=6, lb_sample_every=1, monitor_app_name="miniapp"))
if {slow}:  # this commit has a host-stall bug
    _obs = loop.session.observe_step
    def slow_obs(*a, **k):
        time.sleep(0.03)
        return _obs(*a, **k)
    loop.session.observe_step = slow_obs
loop.run()
run = loop.finalize_run()
if run is None:
    raise SystemExit("ci_workflow needs collection enabled — unset TALP_ENABLE=0")
run.metadata.update({{"git_commit_short": {commit!r},
                      "git_commit_timestamp": {ts!r}}})
run.timestamp = {ts!r}
run.save({out!r})
print("performance job done:", run.resources.label)
"""


def performance_job(commit: str, ts: str, slow: bool, pipeline_dir: str):
    """The paper's matrix job: one run per resource configuration."""
    for ndev in (1, 2):
        out = os.path.join(pipeline_dir, "talp", "salpha", "strong_scaling",
                           f"talp_1x{ndev}_{commit}.json")
        code = _JOB.format(ndev=ndev, src=SRC, commit=commit, ts=ts, out=out,
                           slow="True" if slow else "False")
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=600)
        if r.returncode != 0:
            raise RuntimeError(r.stderr[-2000:])


def main():
    shutil.rmtree(ROOT, ignore_errors=True)
    commits = [("aaa111", False), ("bbb222", False), ("ccc333", True)]
    prev_pipeline = None
    for i, (commit, slow) in enumerate(commits):
        pipeline = os.path.join(ROOT, f"pipeline_{i}")
        ts = f"2026-07-{10 + i:02d}T12:00:00"
        print(f"=== pipeline {i} (commit {commit}{' — buggy' if slow else ''}) ===")
        performance_job(commit, ts, slow, pipeline)

        talp_dir = os.path.join(pipeline, "talp")
        # talp metadata (already injected by the loop here; idempotent)
        talp_cli(["metadata", "-i", talp_dir, "--extra", f"pipeline={i}"])
        # talp merge-history (download previous pipeline artifacts)
        if prev_pipeline:
            talp_cli(["merge-history",
                      "--history", os.path.join(prev_pipeline, "talp"),
                      "--current", talp_dir])
        # talp ci-report
        site = os.path.join(pipeline, "public", "talp")
        talp_cli(["ci-report", "-i", talp_dir, "-o", site,
                  "--regions", "train_step", "--region-for-badge", "train_step"])
        prev_pipeline = pipeline

    findings = json.load(open(os.path.join(site, "findings.json")))
    print(f"\nfinal report: {os.path.join(site, 'index.html')}")
    print(f"findings ({len(findings)}):")
    for f in findings:
        print("  -", f["description"])
    regressions = [f for f in findings if f["kind"] == "regression"
                   and f["commit"] == "ccc333"]
    assert regressions, "the buggy commit must be detected"
    print("\nCI workflow reproduced: buggy commit ccc333 detected "
          f"and explained via {regressions[0]['explanation']}")


if __name__ == "__main__":
    main()
