"""End-to-end training driver: ~100M-parameter llama-family model with
monitoring, checkpointing, restart, and a TALP-Pages artifact.

Full run (a few hundred steps, real hardware or a beefy CPU box):
    PYTHONPATH=src python examples/train_100m.py --steps 300

CI/CPU-container demo (reduced width, same code path):
    PYTHONPATH=src python examples/train_100m.py --steps 4 --tiny
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.train import TrainConfig
from repro.optim import AdamWConfig


def model_100m() -> ModelConfig:
    """~105M params: llama-style, d=640, 12 layers, vocab 32000."""
    return ModelConfig(
        name="llama-100m", d_model=640, n_heads=10, n_kv_heads=5,
        d_ff=1792, vocab=32000, pattern=("attn",), repeats=12,
        rope_theta=10000.0, remat="none", q_chunk=256, kv_chunk=256,
    )


def model_tiny() -> ModelConfig:
    return model_100m().replace(d_model=64, n_heads=4, n_kv_heads=2,
                                d_ff=128, vocab=512, repeats=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced width for CPU-container demo")
    ap.add_argument("--ckpt-dir", default="results/train_100m/ckpt")
    ap.add_argument("--out", default="results/train_100m/talp/main/history")
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    if args.tiny:
        args.seq = min(args.seq, 128)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
          f"{len(jax.devices())} device(s)")

    data = SyntheticLM(DataConfig(
        global_batch=args.batch, seq_len=args.seq, vocab=cfg.vocab,
        pad_fraction=0.05,
    ))
    loop = TrainLoop(
        cfg, make_host_mesh(),
        TrainConfig(optimizer=AdamWConfig(lr=3e-4), warmup_steps=20,
                    total_steps=args.steps),
        data,
        LoopConfig(steps=args.steps, ckpt_every=max(args.steps // 4, 1),
                   ckpt_dir=args.ckpt_dir, lb_sample_every=1,
                   monitor_app_name="train_100m"),
    )
    loop.run()

    hist = loop.metrics_history
    print(f"steps {hist[0]['step']}..{hist[-1]['step']}  "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    # finalize + git metadata + CI folder layout in one repro.session call
    run = loop.finalize_run(args.out)
    if run is None:
        print("monitoring disabled by environment; no run record")
        return
    reg = run.regions["train_step"]
    print(f"run record: {loop.session.last_record_path}")
    print(f"parallel efficiency: {reg.pop.get('parallel_efficiency', 0):.3f}  "
          f"MXU util: {reg.pop.get('mxu_utilization', 0):.5f}  "
          f"achieved TFLOP/s/dev: {reg.pop.get('achieved_tflops_per_device', 0):.4f}")
    print(f"restartable: rerun this command — it resumes from {args.ckpt_dir}")


if __name__ == "__main__":
    main()
