"""Batched serving demo: continuous batching scheduler + TALP monitoring of
the serving loop (prefill/decode regions), emitting a run record suitable
for the same CI report as training runs.

    PYTHONPATH=src python examples/serve_batch.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import compat
from repro.configs import smoke_config
from repro.core import MonitorConfig, ResourceConfig, TalpMonitor
from repro.launch.mesh import make_host_mesh
from repro.layers.common import init_params
from repro.models import transformer as T
from repro.serve.serve import BatchScheduler, ServeConfig


def main():
    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    mon = TalpMonitor(
        MonitorConfig(app_name="serve", lb_sample_every=1),
        ResourceConfig(num_hosts=1, devices_per_host=len(jax.devices())),
    )

    rng = np.random.default_rng(0)
    with compat.use_mesh(mesh), mon:
        sched = BatchScheduler(cfg, mesh, ServeConfig(max_len=128, batch=4), params)
        for rid in range(10):
            prompt = rng.integers(4, cfg.vocab, size=rng.integers(3, 10)).tolist()
            sched.submit(prompt, request_id=rid, max_new=8)
        with mon.region("decode"):
            steps = 0
            while len(sched.completed) < 10 and steps < 200:
                sched.step()
                mon.observe_step(sched.tokens)
                steps += 1
            sched.drain()  # flush any deferred token readbacks

    run = mon.finalize()
    out = "results/serve_batch/talp_serve.json"
    run.save(out)
    print(f"completed {len(sched.completed)} requests in {steps} decode steps")
    for req in sched.completed[:3]:
        print(f"  request {req['id']}: generated {req['generated']}")
    reg = run.regions["decode"]
    print(f"decode region: {reg.measurements.num_steps} steps, "
          f"dispatch efficiency {reg.pop.get('dispatch_efficiency', 0):.3f}")
    print(f"run record: {out}")


if __name__ == "__main__":
    main()
