"""Batched serving demo: continuous batching over a paged KV cache with
chunked prefill-on-attach overlapped with in-flight decode + TALP
monitoring of the serving loop through ``repro.session``, emitting a run
record suitable for the same CI report as training runs.

    PYTHONPATH=src python examples/serve_batch.py            # paged (default)
    PYTHONPATH=src python examples/serve_batch.py --dense    # dense baseline
    PYTHONPATH=src python examples/serve_batch.py --shared-prefix
        # cross-request prefix cache: requests share a system prompt whose
        # KV pages are prefilled once and mapped into every later request's
        # block table (copy-on-write at the divergence point); the demo
        # prints pages saved and prefill tokens skipped
    PYTHONPATH=src python examples/serve_batch.py --traffic
        # open-loop bursty traffic against a deliberately tight page pool:
        # arrivals queue, the pool exhausts, victims preempt and
        # recompute-resume (bitwise identically), some clients hang up
        # mid-stream — the demo prints goodput, TTFT percentiles and the
        # scheduler's pressure counters
    PYTHONPATH=src python examples/serve_batch.py --chaos
        # the traffic run under a seeded fault schedule: NaN logits, a
        # corrupted KV page, an allocator spike and a hung dispatch land
        # mid-run; victims retry through recompute-resume (their streams
        # stay bitwise identical), the watchdog trips on the hang, and
        # the demo prints the recovery counters next to goodput
    PYTHONPATH=src python examples/serve_batch.py --spec
        # speculative decoding A/B on a repetitive workload: an n-gram
        # drafter proposes up to spec_k tokens from each request's own
        # history and one batched verify dispatch scores them all — the
        # demo runs the same trace spec on and off and prints the
        # acceptance rate, dispatches saved, and bitwise token identity

The paged layout (``ServeConfig.paged``, the ``--paged`` default here and
in ``repro.launch.serve``) keeps attention KV in a shared pool of
``page_size``-token pages addressed through per-slot block tables —
``num_pages`` below sizes the pool to this workload's concurrent-token
peak, well under the dense ``batch x max_len`` equivalent, and the demo
prints the pool accounting to show it. Generated tokens are bitwise
identical either way.

The scheduler takes the session directly — every decode dispatch is a visit
of its ``decode`` region and every prefill chunk a visit of its ``prefill``
region, each with its own StepProfile derived from the compiled step by
``session.wrap_step``, so the report tracks prefill and decode factors
separately. No code edits needed to re-plug it: ``TALP_ENABLE=1
TALP_BACKEND=tracer`` swaps the collector, ``TALP_ENABLE=0`` turns the
whole thing off.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

import repro
from repro import compat
from repro.configs import smoke_config
from repro.core import ResourceConfig
from repro.launch.mesh import make_host_mesh
from repro.layers.common import init_params
from repro.models import transformer as T
from repro.serve.serve import BatchScheduler, ServeConfig


def main():
    paged = "--dense" not in sys.argv[1:]
    shared_prefix = "--shared-prefix" in sys.argv[1:]
    traffic = "--traffic" in sys.argv[1:]
    chaos = "--chaos" in sys.argv[1:]
    spec = "--spec" in sys.argv[1:]
    if (shared_prefix or traffic or chaos or spec) and not paged:
        raise SystemExit("--shared-prefix/--traffic/--chaos/--spec need the "
                         "paged layout")
    if traffic or chaos:
        return main_traffic(chaos=chaos)
    if spec:
        return main_spec()
    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    session = repro.start(
        "serve", backend="monitor", lb_sample_every=1,
        resources=ResourceConfig(num_hosts=1,
                                 devices_per_host=len(jax.devices())),
    )

    rng = np.random.default_rng(0)
    with compat.use_mesh(mesh), session:
        sched = BatchScheduler(
            cfg, mesh,
            # pool sized to the workload: 4 slots x ceil((10+8)/16) pages,
            # vs the dense equivalent of 4 x 128/16 = 32 pages (the shared-
            # prefix run carries 48 extra prompt tokens per request, shared
            # after the first — plus the trie's pinned copy)
            ServeConfig(max_len=128, batch=4, prefill_chunk=16,
                        paged=paged, page_size=16,
                        num_pages=(16 if shared_prefix else 8) if paged else None,
                        prefix_cache=shared_prefix),
            params, session=session,
        )
        # --shared-prefix: one 48-token system prompt, divergent user tails
        system = (rng.integers(4, cfg.vocab, size=48).tolist()
                  if shared_prefix else [])
        for rid in range(10):
            prompt = system + rng.integers(4, cfg.vocab,
                                           size=rng.integers(3, 10)).tolist()
            sched.submit(prompt, request_id=rid, max_new=8)
        steps = 0
        while len(sched.completed) < 10 and steps < 200:
            sched.step()
            steps += 1
        sched.drain()  # finish partial prefills + flush deferred readbacks

    run = session.finalize("results/serve_batch")
    print(f"completed {len(sched.completed)} requests in {steps} ticks "
          f"({sched.stats['decode_steps']} decode steps, "
          f"{sched.stats['prefill_chunks']} prefill chunks)")
    kv = sched.kv_cache_stats()
    if kv["layout"] == "paged":
        print(f"paged KV pool: {kv['kv_bytes']} bytes "
              f"({kv['num_pages']} pages x {kv['page_size']} tokens), "
              f"peak {kv['peak_used_pages']} pages live, "
              f"utilization {kv['pool_utilization']}")
        if "prefix_cache" in kv:
            pc = kv["prefix_cache"]
            print(f"prefix cache: {pc['pages_saved_by_sharing']} pages saved "
                  f"by sharing, {pc['prefill_tokens_skipped']} prefill tokens "
                  f"skipped, hit rate {pc['hit_rate']} "
                  f"({pc['cow_copies']} copy-on-write pages)")
    else:
        print(f"dense KV cache: {kv['kv_bytes']} bytes")
    for req in sched.completed[:3]:
        print(f"  request {req['id']}: generated {req['generated']}")
    if run is None:
        print("monitoring disabled by environment; no run record")
        return
    for name in ("prefill", "decode"):
        reg = run.regions[name]
        print(f"{name} region: {reg.measurements.num_steps} steps, "
              f"dispatch efficiency {reg.pop.get('dispatch_efficiency', 0):.3f}")
    print(f"run record: {session.last_record_path}")


def main_traffic(chaos: bool = False):
    """Open-loop bursty load against a pool sized well under the demand
    peak: admission queueing, preemption + recompute-resume, and
    mid-stream cancellations, measured the way BENCH_serve.json reports
    them. With ``chaos`` a seeded fault schedule rides the same run and
    the scheduler must recover through retry/quarantine."""
    from repro.serve.traffic import TrafficConfig, generate_workload, replay

    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    session = repro.start(
        "serve-traffic", backend="monitor", lb_sample_every=1,
        resources=ResourceConfig(num_hosts=1,
                                 devices_per_host=len(jax.devices())),
    )
    workload = generate_workload(TrafficConfig(
        n_requests=12, seed=0, arrival="burst", rate=0.8, burst_mult=5.0,
        prompt_short=(4, 10), prompt_long=(12, 20), max_new_short=(4, 8),
        max_new_long=(8, 12), cancel_frac=0.0 if chaos else 0.2,
        vocab_hi=cfg.vocab,
    ))
    injector = None
    if chaos:
        from repro.serve.faults import FaultConfig, FaultInjector

        injector = FaultInjector(FaultConfig(seed=3, horizon_ticks=24,
                                             hang_s=0.2))
    with compat.use_mesh(mesh), session:
        sched = BatchScheduler(
            cfg, mesh,
            # 2 slots x 3 pages: bursts must queue, long requests must
            # preempt — graceful degradation instead of a RuntimeError
            ServeConfig(max_len=64, batch=2, prefill_chunk=8, paged=True,
                        page_size=8, num_pages=6,
                        watchdog_deadline_s=0.05 if chaos else None),
            params, session=session,
        )
        m = replay(sched, workload, faults=injector)
    session.finalize("results/serve_traffic")
    print(f"bursty traffic: {m['completed']} completed, "
          f"{m['cancelled']} cancelled, {m['failed']} failed "
          f"of {m['requests']} in {m['ticks']} ticks")
    print(f"goodput {m['goodput_tokens_per_sec']} tok/s "
          f"({m['good_tokens']} tokens); TTFT p50/p95/p99 "
          f"{m['ttft_p50_s']}/{m['ttft_p95_s']}/{m['ttft_p99_s']} s; "
          f"queue depth peak {m['queue_depth_peak']}")
    print(f"pressure: {m['preemptions']} preemptions, {m['resumes']} "
          f"resumes, {m['cancellations']} cancellations "
          f"({m['kv']['pressure']['pages_freed_by_preempt']} pages freed "
          f"by preempt)")
    if chaos:
        rec = m["recovery"]
        print(f"chaos: injected {rec['injected']}; recovered with "
              f"{rec['retries']} retries ({rec['backoff_total_ticks']} "
              f"backoff ticks), {rec['watchdog_trips']} watchdog trips, "
              f"{rec['quarantined']} quarantined, {rec['shed']} shed")


def main_spec():
    """Speculative-decode A/B on a workload the drafter can actually
    predict: residual-zeroed "copy regime" weights make greedy decode a
    pure function of the last token, so generation cycles and the n-gram
    drafter locks on — the same trick ``benchmarks/serve_throughput.py``
    uses for its deterministic speedup gate. Random-weight generations
    are aperiodic; on those the drafter proposes nothing and speculation
    degrades gracefully to sequential decode (still bitwise identical)."""
    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    params = dict(params, slots=jax.tree_util.tree_map(
        lambda x: x * 0.0, params["slots"]))
    pat = [5, 9, 13, 7]
    prompts = [pat * 4, pat * 6, [2, 3] + pat * 5]

    def run(spec_on):
        with compat.use_mesh(mesh):
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=256, batch=4, prefill_chunk=16,
                            paged=True, page_size=16, num_pages=44,
                            spec_decode=spec_on, spec_k=4),
                params,
            )
            for rid, p in enumerate(prompts):
                sched.submit(p, request_id=rid, max_new=64)
            sched.drain()
        return sched

    plain, spec = run(False), run(True)
    toks = lambda s: {r["id"]: r["generated"] for r in s.completed}
    sp = spec.kv_cache_stats()["speculation"]
    print(f"plain decode: {plain.stats['decode_steps']} dispatches for "
          f"{sum(len(g) for g in toks(plain).values())} tokens")
    print(f"speculative:  {spec.stats['decode_steps']} dispatches "
          f"({sp['tokens_per_dispatch']} tokens/dispatch, acceptance rate "
          f"{sp['acceptance_rate']}, mean accepted len "
          f"{sp['mean_accepted_len']})")
    print(f"tokens bitwise identical: {toks(spec) == toks(plain)}")


if __name__ == "__main__":
    main()
