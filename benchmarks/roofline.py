"""§Roofline — aggregate the dry-run artifacts into the roofline table.

Reads results/dryrun/*.json (produced by repro.launch.dryrun) and emits,
per (arch x shape) on the single-pod mesh: the three terms, the dominant
bottleneck, MODEL/HLO FLOPs ratio, and a one-line recommendation. Markdown
written to results/roofline.md for EXPERIMENTS.md inclusion.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import csv_line, save_result

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN", "results/dryrun")


def _recommendation(rec: dict) -> str:
    r = rec["roofline"]
    p = rec["profile"]
    b = r["bottleneck"]
    if b == "memory_s":
        if p["remat_dot_flops"] > 0.3 * max(p["dot_flops"], 1):
            return "attention-scores HBM traffic + remat dominate: Pallas flash kernel / dots-saveable remat"
        return "HBM traffic dominates: fuse attention (Pallas flash), cut f32 intermediates"
    if b == "collective_s":
        if rec.get("strategy") == "megatron":
            return "SP all-gathers dominate: smaller TP degree / fsdp strategy / comm-compute overlap"
        return "collectives dominate: overlap or reshard"
    return "compute-bound: near roofline; raise MXU utilization (bigger tiles)"


def load_cells(multi_pod: bool = False) -> list[dict]:
    suffix = "multipod" if multi_pod else "singlepod"
    cells = []
    if not os.path.isdir(DRYRUN_DIR):
        return cells
    for name in sorted(os.listdir(DRYRUN_DIR)):
        if name.endswith(f"{suffix}.json"):
            with open(os.path.join(DRYRUN_DIR, name)) as f:
                cells.append(json.load(f))
    return cells


def table_markdown(cells: list[dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "MODEL/HLO flops | roofline frac | mem/dev GiB | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in cells:
        if rec["status"] == "skipped":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | skipped | — | — | — | "
                f"{rec['reason'][:70]} |"
            )
            continue
        if rec["status"] != "ok":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | FAILED | — | — | — | "
                f"{rec['error'][:70]} |"
            )
            continue
        r = rec["roofline"]
        m = rec["memory_analysis"]
        mem_dev = (m.get("argument_size_in_bytes", 0)
                   + m.get("temp_size_in_bytes", 0)) / 2**30
        frac = r.get("memory_roofline_fraction", r.get("roofline_fraction", 0.0))
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['bottleneck'][:-2]} | {r.get('model_to_hlo_flops', 0):.2f} | "
            f"{frac:.3f} | {mem_dev:.2f} | {_recommendation(rec)[:80]} |"
        )
    return "\n".join(lines)


def main() -> list[str]:
    single = load_cells(False)
    multi = load_cells(True)
    md = ["# Roofline table — single-pod 16x16 (256 x TPU v5e)", "",
          table_markdown(single), ""]
    if multi:
        md += ["# Multi-pod 2x16x16 (512 chips) — DCN split", "",
               table_markdown(multi), ""]
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write("\n".join(md))

    ok = [c for c in single if c["status"] == "ok"]
    failed = [c for c in single if c["status"] == "failed"]
    bottlenecks: dict[str, int] = {}
    for c in ok:
        b = c["roofline"]["bottleneck"]
        bottlenecks[b] = bottlenecks.get(b, 0) + 1
    save_result("roofline_summary", {
        "cells_ok": len(ok), "cells_failed": len(failed),
        "bottlenecks": bottlenecks,
        "multi_pod_ok": sum(1 for c in multi if c["status"] == "ok"),
    })
    return [
        csv_line("roofline_cells", 0.0,
                 f"ok={len(ok)} failed={len(failed)} "
                 f"multipod_ok={sum(1 for c in multi if c['status'] == 'ok')} "
                 f"bottlenecks={bottlenecks}"),
    ]


if __name__ == "__main__":
    print("\n".join(main()))
