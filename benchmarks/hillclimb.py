"""§Perf hillclimbing tool: lower one (arch x shape) cell with config
overrides, print the three roofline terms + memory + attribution, and log
the iteration to results/hillclimb/.

    PYTHONPATH=src python -m benchmarks.hillclimb \
        --arch tinyllama-1.1b --shape train_4k --tag fsdp \
        --set sharding=fsdp causal_skip=True

Every invocation appends to the per-cell iteration log so the
hypothesis -> change -> before -> after chain is auditable.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time


def parse_value(v: str):
    if v in ("True", "False"):
        return v == "True"
    if v == "None":
        return None
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", nargs="*", default=[], metavar="K=V")
    ap.add_argument("--moe-set", nargs="*", default=[], metavar="K=V")
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()

    from repro.configs import get_config, MoEConfig
    from repro.core.profile import StepProfile
    from repro import compat
    from repro.core import hlo as H
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import devices_per_pod
    from repro.train.train import TrainConfig
    from repro.data.pipeline import batch_specs
    from repro.configs import SHAPE_BY_NAME

    cfg = get_config(args.arch)
    overrides = {k: parse_value(v) for k, _, v in
                 (kv.partition("=") for kv in args.set)}
    if args.moe_set and cfg.moe:
        moe_over = {k: parse_value(v) for k, _, v in
                    (kv.partition("=") for kv in args.moe_set)}
        overrides["moe"] = dataclasses.replace(cfg.moe, **moe_over)
    if overrides:
        cfg = cfg.replace(**overrides)

    t0 = time.time()
    compiled, model_flops, mesh, meta = lower_cell(
        args.arch, args.shape, args.multi_pod, cfg=cfg, accum=args.accum
    )
    hlo_text = compat.compiled_text(compiled)
    cost = H.analyze_hlo(hlo_text, devices_per_pod=devices_per_pod(mesh))
    profile = StepProfile.from_hlo_cost(
        cost, num_devices=mesh.devices.size, model_flops=model_flops,
        xla_cost=H.xla_cost_analysis(compiled), memory=H.memory_stats(compiled),
    )
    terms = profile.roofline_terms()

    # --- kernel-adjusted memory: traffic inside the flash chunk loops ---
    # Computations whose call multiplicity exceeds ~2x the layer count live
    # inside the per-block attention scans (scores, exp/mask fusions, o/m/l
    # carries). A Pallas flash kernel holds all of those in VMEM; its HBM
    # traffic is only q/o once + k/v once per q-block. VMEM footprint:
    # qc*kc*4 + 2*kc*d*2 + qc*d*8 bytes << 128 MB.
    mod = H.parse_module(hlo_text)
    comps = mod.computations
    fusion_bodies = mod.fusion_bodies
    mult = mod.multiplicity

    layer_mult = 2.0 * max(cfg.repeats * len(cfg.pattern), 1)
    inner_bytes = 0.0
    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None or m <= layer_mult or cname in fusion_bodies:
            continue
        for i in comp.instructions.values():
            op = i.op
            if op in H._FREE_OPS or op in ("while", "conditional", "call"):
                continue
            if op in H.COLLECTIVE_KINDS:
                continue
            rb = H.shape_bytes(i.type_str)
            if op in ("dynamic-slice", "slice", "gather", "dynamic-update-slice", "scatter"):
                t = 2.0 * rb
            else:
                t = rb + sum(
                    H.shape_bytes(comp.instructions[o].type_str)
                    for o in i.operands if o in comp.instructions
                )
            inner_bytes += t * m
    inner_total = inner_bytes * mesh.devices.size

    # the kernel's own HBM traffic for the same work (analytic, whole machine)
    shape = SHAPE_BY_NAME[args.shape]
    Btok = shape.global_batch
    S = shape.seq_len if args.shape.startswith(("train", "prefill")) else 1
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    n_attn = sum(1 for k in cfg.pattern if k in ("attn", "local_attn", "moe")) * cfg.repeats
    nq = max(S // cfg.q_chunk, 1)
    per_layer = (
        2.0 * Btok * S * hq * hd * 2      # q read + o write (bf16)
        + 2.0 * nq * Btok * S * hkv * hd * 2  # k+v streamed once per q block
    )
    passes = 4.0 if args.shape.startswith("train") else 1.0  # fwd+bwd+remat
    kernel_bytes = per_layer * n_attn * passes

    adj_bytes = max(profile.hbm_bytes - inner_total + kernel_bytes, 0.0)
    from repro.core.hardware import TPU_V5E

    adj_mem = adj_bytes / (mesh.devices.size * TPU_V5E.hbm_bandwidth)
    sb_total = inner_total

    rec = {
        "tag": args.tag,
        "hypothesis": args.hypothesis,
        "arch": args.arch, "shape": args.shape,
        "overrides": {k: str(v) for k, v in overrides.items()},
        "compile_s": meta["compile_s"],
        "strategy": meta["strategy"],
        "roofline": terms,
        "kernel_adjusted_memory_s": adj_mem,
        "flash_inner_bytes_total": sb_total,
        "kernel_replacement_bytes": kernel_bytes,
        "memory_analysis": profile.memory,
        "flops": profile.flops, "hbm_bytes": profile.hbm_bytes,
        "collective_bytes_ici": profile.collective_bytes_ici,
        "collective_bytes_dcn": profile.collective_bytes_dcn,
        "collective_counts": profile.collective_counts,
        "remat_dot_flops": profile.remat_dot_flops,
        "model_flops": profile.model_flops,
    }
    out_dir = "results/hillclimb"
    os.makedirs(out_dir, exist_ok=True)
    log = os.path.join(out_dir, f"{args.arch}__{args.shape}.jsonl")
    with open(log, "a") as f:
        f.write(json.dumps(rec) + "\n")

    mem_dev = (profile.memory.get("argument_size_in_bytes", 0)
               + profile.memory.get("temp_size_in_bytes", 0)) / 2**30
    print(f"[{args.tag}] {args.arch} {args.shape} strategy={meta['strategy']}")
    print(f"  compute   {terms['compute_s']:.3f}s   (model/hlo flops "
          f"{terms.get('model_to_hlo_flops', 0):.2f}, remat share "
          f"{profile.remat_dot_flops / max(profile.dot_flops, 1):.2f})")
    print(f"  memory    {terms['memory_s']:.3f}s   (kernel-adjusted "
          f"{adj_mem:.3f}s; flash-inner {sb_total/1e12:.2f}TB -> kernel "
          f"{kernel_bytes/1e12:.2f}TB)")
    print(f"  collective {terms['collective_s']:.3f}s  (ici {terms['collective_ici_s']:.3f} "
          f"dcn {terms['collective_dcn_s']:.3f}) counts={profile.collective_counts}")
    print(f"  bottleneck {terms['bottleneck']}   roofline_frac "
          f"{terms.get('roofline_fraction', 0):.4f}  mem/dev {mem_dev:.2f}GiB")
    print(f"  serial step {terms['step_time_serial_s']:.3f}s  "
          f"overlapped bound {terms['step_time_lower_bound_s']:.3f}s")


if __name__ == "__main__":
    main()
