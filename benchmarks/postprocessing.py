"""Paper Table 2 — post-processing resources to obtain the scaling table.

Produces the same scaling-efficiency table through both pipelines:
  TALP-Pages   read run JSONs -> build_table          (paper row 1)
  Tracer       read full event traces -> post_process (JSC/BSC rows)

and measures wall time, peak python memory, and on-disk storage for each.
The orders-of-magnitude asymmetry is the paper's core quantitative claim.
"""

from __future__ import annotations

import os
import shutil
import time

from benchmarks.common import csv_line, peak_memory, save_result
from repro.core import (
    ResourceConfig,
    StepProfile,
    build_table,
    post_process,
    trace_storage_bytes,
)
from repro.session import PerfSession, SessionConfig


def _generate_runs(root: str, configs=((1, 8), (2, 8), (4, 8)), steps=200,
                   devices_scale_events=True):
    """Produce both artifacts (JSON + trace) for a synthetic scaling study —
    the same workload driven through both PerfSession backends."""
    os.makedirs(root, exist_ok=True)
    json_dir = os.path.join(root, "talp", "study", "strong")
    runs = []
    for hosts, devs in configs:
        res = ResourceConfig(num_hosts=hosts, devices_per_host=devs)
        n = hosts * devs
        profile = StepProfile(
            num_devices=n, flops=4e12, hbm_bytes=2e10,
            collective_bytes_ici=1e9 * (n > 1), model_flops=3.5e12,
            collective_counts={"all-gather": 6, "all-reduce": 3},
        )
        clock = [0.0]
        tick = lambda: clock[0]

        def _session(backend: str, trace_dir: str = "") -> PerfSession:
            ses = PerfSession(
                SessionConfig(app_name="study", backend=backend, clock=tick,
                              sync_regions=False, lb_sample_every=1,
                              trace_dir=trace_dir, respect_env=False),
                res,
            )
            ses.attach_static("timestep", profile)
            return ses.start()

        mon = _session("monitor")
        tr = _session("tracer", os.path.join(root, f"trace_{hosts}x{devs}"))
        with mon.region("timestep"), tr.region("timestep"):
            for s in range(steps):
                clock[0] += 1.0 / n  # perfect strong scaling of step time
                mon.observe_step(tokens_per_shard=[100] * hosts)
                tr.observe_step(tokens_per_shard=[100] * hosts)
        tr.stop()  # write the event streams; post-processed separately below
        run = mon.finalize(git=False)
        run.save(os.path.join(json_dir, f"talp_{hosts}x{devs}.json"))
        runs.append(run)
    return json_dir, [os.path.join(root, f"trace_{h}x{d}") for h, d in configs]


def run(root: str = "/tmp/repro_postproc", steps: int = 200) -> dict:
    shutil.rmtree(root, ignore_errors=True)
    json_dir, trace_dirs = _generate_runs(root, steps=steps)

    # --- TALP-Pages path ---
    from repro.core.records import load_folder

    def talp_path():
        runs = load_folder(json_dir)
        return build_table(runs)

    table_a, t_talp, mem_talp = peak_memory(talp_path)
    storage_talp = sum(
        os.path.getsize(os.path.join(json_dir, f)) for f in os.listdir(json_dir)
    )

    # --- tracer path ---
    def tracer_path():
        runs = [post_process(d) for d in trace_dirs]
        return build_table(runs)

    table_b, t_trace, mem_trace = peak_memory(tracer_path)
    storage_trace = sum(trace_storage_bytes(d) for d in trace_dirs)

    # cross-tool agreement (paper Tables 6/7 check)
    max_dev = 0.0
    for ca, cb in zip(table_a.columns, table_b.columns):
        for k, va in ca.pop.items():
            vb = cb.pop.get(k)
            if vb is not None and abs(va) > 1e-9:
                max_dev = max(max_dev, abs(va - vb) / max(abs(va), 1e-9))

    result = {
        "steps": steps,
        "talp": {"time_s": t_talp, "peak_mem_mb": mem_talp / 2**20,
                 "storage_mb": storage_talp / 2**20},
        "tracer": {"time_s": t_trace, "peak_mem_mb": mem_trace / 2**20,
                   "storage_mb": storage_trace / 2**20},
        "speedup": t_trace / max(t_talp, 1e-9),
        "storage_ratio": storage_trace / max(storage_talp, 1),
        "memory_ratio": mem_trace / max(mem_talp, 1),
        "max_factor_deviation": max_dev,
    }
    save_result("table2_postprocessing", result)
    return result


def main() -> list[str]:
    r = run()
    return [
        csv_line("table2_talp_postproc", r["talp"]["time_s"] * 1e6,
                 f"mem={r['talp']['peak_mem_mb']:.1f}MB storage={r['talp']['storage_mb']:.2f}MB"),
        csv_line("table2_tracer_postproc", r["tracer"]["time_s"] * 1e6,
                 f"mem={r['tracer']['peak_mem_mb']:.1f}MB storage={r['tracer']['storage_mb']:.2f}MB"),
        csv_line("table2_ratios", 0.0,
                 f"time_x={r['speedup']:.0f} storage_x={r['storage_ratio']:.0f} "
                 f"mem_x={r['memory_ratio']:.0f} max_dev={r['max_factor_deviation']:.4f}"),
    ]


if __name__ == "__main__":
    print("\n".join(main()))
