"""Serving throughput A/B — paged vs dense KV cache, overlap vs stop-world.

Runs the same request trace through ``serve.BatchScheduler`` three ways —
paged+overlapped (the production configuration), paged+stop-the-world, and
dense+overlapped — and measures what the ISSUE's acceptance criteria name:

  tokens/sec            end-to-end generated-token throughput
  ttft                  time from submit to the first-token dispatch
                        (prefill completion), per request
  decode max gap        longest wall-clock gap between consecutive decode
                        dispatches while a prefill was in flight — the
                        "decode stall" a stop-the-world prefill causes
  peak KV bytes         attention-cache HBM footprint: the full dense
                        buffers vs the paged pool (sized to the workload's
                        concurrent-token peak), plus the pool's live-page
                        peak and utilization
  overlap guarantee     scheduler-level invariant: every tick with an
                        in-flight prefill and >=1 decoding slot also
                        dispatched a decode (no gap > one tick)
  identical tokens      paged == dense, and overlap on/off, token for token

A fourth section runs the shared-prefix workload (one long system prompt,
divergent tails) with the cross-request prefix cache on vs off on the SAME
warm-first schedule, reporting TTFT, tokens/sec, peak live pages and the
prefill chunks the trie hits skipped — plus bitwise token identity between
the two sides.

Emits ``BENCH_serve.json`` (default ``results/BENCH_serve.json``) so the
repo carries a serve-path perf trajectory next to the TALP records; the
``--check`` shape in ``benchmarks/run.py`` runs the tiny variant and
asserts paged/dense token identity (greedy AND sampled), the overlap
guarantee, that the paged pool footprint lands strictly below dense for
the mixed-length trace, and that prefix sharing keeps tokens bitwise
identical (greedy AND sampled) while strictly lowering peak live pages
and skipping prefill chunks.

A fifth section (``"traffic"``) replays the open-loop harness from
``repro.serve.traffic`` — the same seeded workload under Poisson and
Markov-modulated bursty arrivals against a deliberately tight page pool —
and reports goodput, p50/p95/p99 TTFT, queue depth and the scheduler's
preemption/resume/cancellation counters; ``--check`` additionally forces
a preemption (tiny pool vs ample pool) and asserts the recompute-resume
token streams are bitwise identical, greedy AND sampled, with zero pages
leaked after drain.

A sixth section (``--chaos`` / ``run_chaos``) is the fault-injection
soak: the same seeded burst workload replayed fault-free and under a
seeded ``repro.serve.faults`` schedule (NaN logits, page corruption,
allocator spikes, dispatch hangs), reporting the recovery counters,
goodput retention and completed-token identity between the two runs;
``--check`` gates fault-recovery token identity (greedy AND sampled,
every fault kind injected at least once), quarantine-works (a request
whose faults exhaust ``max_retries`` ends terminal ``failed`` while its
neighbors stay bitwise intact) and zero pages leaked after drain.

A seventh section (``"speculation"`` / ``run_spec`` / ``--spec``) is the
speculative-decode A/B: the same trace with ``spec_decode`` on vs off, on
a repetitive trace (residual-zeroed "copy regime" weights whose greedy
decode provably cycles — the prompt-lookup drafter's home turf) and a
non-repetitive trace (random weights and prompts, where the drafter
proposes little and speculation must degrade gracefully to sequential
decode). Reports bitwise token identity, tokens/sec speedup, TTFT,
acceptance rate, dispatches saved, and pages leaked after drain;
``--check`` gates identity on both traces in greedy AND sampled modes, a
STRICT tokens/sec speedup plus acceptance_rate > 0 on the repetitive
trace, and zero leaked pages.

    PYTHONPATH=src:. python benchmarks/serve_throughput.py [arch ...]
    PYTHONPATH=src:. python benchmarks/serve_throughput.py --traffic [arch ...]
    PYTHONPATH=src:. python benchmarks/serve_throughput.py --chaos [arch ...]
    PYTHONPATH=src:. python benchmarks/serve_throughput.py --spec [arch ...]

With archs given (the nightly sweep), the first writes BENCH_serve.json
and each additional arch writes BENCH_serve_<arch>.json; ``--traffic``
writes ``BENCH_serve_traffic_<arch>.json`` per arch, ``--chaos`` writes
``BENCH_serve_chaos_<arch>.json`` per arch and ``--spec`` writes
``BENCH_serve_spec_<arch>.json`` per arch.
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

from benchmarks.common import RESULTS_DIR, csv_line


import functools


@functools.lru_cache(maxsize=4)
def _build(cfg_name: str = "tinyllama-1.1b"):
    import jax

    from repro.configs import smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.layers.common import init_params
    from repro.models import transformer as T

    cfg = smoke_config(cfg_name)
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, mesh, params


def _request_trace(cfg, n_requests: int, seed: int = 0):
    import numpy as np

    rng = np.random.default_rng(seed)
    return [rng.integers(4, cfg.vocab, size=int(n)).tolist()
            for n in rng.integers(8, 24, size=n_requests)]


def run_mode(cfg, mesh, params, prompts, *, overlap: bool, max_new: int,
             batch: int, prefill_chunk: int, max_len: int = 128,
             paged: bool = True, page_size: int = 16,
             num_pages: int | None = None, prefix_cache: bool = False,
             greedy: bool = True, temperature: float = 1.0,
             top_k: int | None = None, sample_seed: int = 0,
             spec_decode: bool = False, spec_k: int = 4,
             spec_min_match: int = 2, warm_first: bool = False) -> dict:
    """One scheduler pass; returns the measured dict for BENCH_serve.json.

    ``warm_first`` runs ``prompts[0]`` to completion before the rest are
    submitted — the shared-prefix A/B schedule: the first request warms
    the prefix trie, then the wave attaches against it (the no-sharing
    pass runs the SAME schedule so the comparison is honest)."""
    from repro import compat
    from repro.serve.serve import BatchScheduler, ServeConfig

    with compat.use_mesh(mesh):
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=max_len, batch=batch,
                        prefill_chunk=prefill_chunk, overlap=overlap,
                        paged=paged, page_size=page_size,
                        num_pages=num_pages, prefix_cache=prefix_cache,
                        greedy=greedy, temperature=temperature, top_k=top_k,
                        sample_seed=sample_seed, spec_decode=spec_decode,
                        spec_k=spec_k, spec_min_match=spec_min_match),
            params,
        )
        if warm_first:
            first, late = prompts[:1], prompts[1:]
        else:
            # stagger: half the requests arrive while the first half decodes,
            # so prefill-on-attach genuinely competes with in-flight decode
            half = max(1, len(prompts) // 2)
            first, late = prompts[:half], prompts[half:]
        t0 = time.perf_counter()
        submit_t: dict = {}
        for rid, p in enumerate(first):
            sched.submit(p, request_id=rid, max_new=max_new)
            submit_t[rid] = time.perf_counter()
        decode_times: list[float] = []
        gaps_during_prefill: list[float] = []
        ttft: dict = {}
        ticks = 0
        injected = False
        while len(sched.completed) < len(prompts) and ticks < 50 * max_new:
            inject_due = (len(sched.completed) >= len(first)) if warm_first \
                else (ticks >= 2)
            if not injected and inject_due:
                for rid, p in enumerate(late, start=len(first)):
                    sched.submit(p, request_id=rid, max_new=max_new)
                    submit_t[rid] = time.perf_counter()
                injected = True
            prefill_inflight = bool(sched._prefills)
            decodes_before = sched.stats["decode_steps"]
            sched.step()
            now = time.perf_counter()
            if sched.stats["decode_steps"] > decodes_before:
                if decode_times and prefill_inflight:
                    gaps_during_prefill.append(now - decode_times[-1])
                decode_times.append(now)
            for slot, req in enumerate(sched.active):
                if req is not None and req["id"] not in ttft:
                    # first-token dispatch: the request just finished prefill
                    ttft[req["id"]] = now - submit_t[req["id"]]
            ticks += 1
        sched.drain()
        wall = time.perf_counter() - t0
    tokens = sum(len(r["generated"]) for r in sched.completed)
    return {
        "overlap": overlap,
        "paged": paged,
        "requests": len(prompts),
        "completed": len(sched.completed),
        "ticks": ticks,
        "wall_s": round(wall, 4),
        "tokens": tokens,
        "tokens_per_sec": round(tokens / max(wall, 1e-9), 2),
        "ttft_mean_s": round(sum(ttft.values()) / max(len(ttft), 1), 4),
        "ttft_max_s": round(max(ttft.values(), default=0.0), 4),
        "decode_max_gap_during_prefill_s": round(
            max(gaps_during_prefill, default=0.0), 4
        ),
        # overall stall: the stop-the-world mode pays its prefills *between*
        # decode dispatches (host-blocked inside attach), which this catches
        "decode_max_gap_s": round(
            max((b - a for a, b in zip(decode_times, decode_times[1:])),
                default=0.0), 4
        ),
        "kv": sched.kv_cache_stats(),
        "stats": dict(sched.stats),
        "generated": {str(r["id"]): r["generated"] for r in sched.completed},
    }


def _shared_prefix_trace(cfg, n_requests: int, prefix_len: int,
                         seed: int = 0):
    """N requests sharing a long system prompt, divergent short tails.

    ``prefix_len`` should be a page multiple: the shared pages then skip
    whole prefill chunks on the same chunk grid the cold path uses, which
    keeps the sharing-on/off token identity bitwise even in bf16 (mid-page
    divergence — the copy-on-write path — is exercised at f32 in
    tests/test_serve.py)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    system = rng.integers(4, cfg.vocab, size=prefix_len).tolist()
    return [system + rng.integers(4, cfg.vocab, size=int(n)).tolist()
            for n in rng.integers(3, 11, size=n_requests)]


def run_prefix(cfg, mesh, params, *, n_requests: int, prefix_len: int,
               max_new: int, batch: int, prefill_chunk: int, max_len: int,
               page_size: int, greedy: bool = True, temperature: float = 1.0,
               top_k: int | None = None) -> dict:
    """Shared-prefix workload, sharing on vs off (same warm-first schedule:
    request 0 completes — and, with sharing on, warms the trie — before the
    wave attaches). Returns the A/B with TTFT, tokens/sec and peak live
    pages per side."""
    prompts = _shared_prefix_trace(cfg, n_requests, prefix_len)
    num_pages = _workload_pages(prompts, max_new, batch, page_size)
    kw = dict(overlap=True, max_new=max_new, batch=batch,
              prefill_chunk=prefill_chunk, max_len=max_len,
              page_size=page_size, num_pages=num_pages, warm_first=True,
              greedy=greedy, temperature=temperature, top_k=top_k)
    # warmup: compile both sides' step functions (the prefix_cache=True pair
    # is a distinct jit key — without this the sharing-on pass would pay
    # compilation inside its timed region and the TTFT columns would lie)
    for pc in (True, False):
        run_mode(cfg, mesh, params, prompts[:2], prefix_cache=pc,
                 **{**kw, "max_new": 2})
    on = run_mode(cfg, mesh, params, prompts, prefix_cache=True, **kw)
    off = run_mode(cfg, mesh, params, prompts, prefix_cache=False, **kw)
    gen_on, gen_off = on.pop("generated"), off.pop("generated")
    return {
        "config": {"requests": n_requests, "prefix_len": prefix_len,
                   "max_new": max_new, "batch": batch,
                   "prefill_chunk": prefill_chunk, "max_len": max_len,
                   "page_size": page_size, "num_pages": num_pages,
                   "greedy": greedy},
        # sharing on/off bitwise token identity (a shared page holds exactly
        # the K/V the request would have prefilled itself)
        "identical_tokens": gen_on == gen_off,
        # the memory win: strictly fewer live pages at peak, trie pins and
        # all, because the wave's prefix pages exist once instead of B times
        "peak_pages_below_no_sharing": (
            on["kv"]["peak_used_pages"] < off["kv"]["peak_used_pages"]
        ),
        # the compute win, deterministically (no wall-clock jitter): shared
        # prefix pages skip their prefill chunks outright
        "prefill_chunks_saved": (
            off["stats"]["prefill_chunks"] - on["stats"]["prefill_chunks"]
        ),
        "ttft_mean_speedup": round(
            off["ttft_mean_s"] / max(on["ttft_mean_s"], 1e-9), 3
        ),
        "sharing_on": on,
        "sharing_off": off,
    }


def run_traffic(cfg, mesh, params, *, arrival: str, n_requests: int = 10,
                rate: float = 0.8, batch: int = 2, max_len: int = 64,
                page_size: int = 8, num_pages: int = 6,
                prefill_chunk: int = 4, cancel_frac: float = 0.2,
                preempt_policy: str = "priority", seed: int = 0,
                keep_generated: bool = False) -> dict:
    """One open-loop traffic pass: a seeded workload (Poisson or bursty
    arrivals, mixed lengths, priority classes, scheduled cancellations)
    against a deliberately tight page pool, measured by ``traffic.replay``
    — goodput, TTFT percentiles, queue depth and preemption counts."""
    from repro import compat
    from repro.serve.serve import BatchScheduler, ServeConfig
    from repro.serve.traffic import TrafficConfig, generate_workload, replay

    tcfg = TrafficConfig(
        n_requests=n_requests, seed=seed, arrival=arrival, rate=rate,
        prompt_short=(4, 10), prompt_long=(12, 20), max_new_short=(3, 6),
        max_new_long=(8, 12), cancel_frac=cancel_frac, vocab_hi=cfg.vocab,
    )
    workload = generate_workload(tcfg)
    with compat.use_mesh(mesh):
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=max_len, batch=batch,
                        prefill_chunk=prefill_chunk, paged=True,
                        page_size=page_size, num_pages=num_pages,
                        preempt_policy=preempt_policy),
            params,
        )
        metrics = replay(sched, workload)
    if not keep_generated:
        metrics.pop("generated", None)
    metrics["arrival"] = arrival
    metrics["config"] = {
        "n_requests": n_requests, "rate": rate, "batch": batch,
        "page_size": page_size, "num_pages": num_pages,
        "cancel_frac": cancel_frac, "preempt_policy": preempt_policy,
        "seed": seed,
    }
    return metrics


def _forced_preempt(cfg, mesh, params, *, num_pages: int,
                    greedy: bool) -> "object":
    """Two 2-page requests through a pool of ``num_pages``: at 3 the
    younger parks itself mid-decode and resumes after the older retires;
    at 16 nothing ever waits. Returns the drained scheduler."""
    from repro import compat
    from repro.serve.serve import BatchScheduler, ServeConfig

    kw = {} if greedy else dict(greedy=False, temperature=0.8, top_k=20,
                                sample_seed=3)
    with compat.use_mesh(mesh):
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4, paged=True,
                        page_size=8, num_pages=num_pages, **kw),
            params,
        )
        for rid, p in enumerate([list(range(4, 12)), list(range(20, 28))]):
            sched.submit(p, request_id=rid, max_new=8)
        sched.drain()
    return sched


def _check_preemption(cfg, mesh, params) -> None:
    """The forced-preemption identity gate: preemption + recompute-resume
    must be a pure scheduling decision — tokens bitwise identical to the
    ample-pool run, greedy AND sampled, with real pressure (preemptions
    > 0) and nothing leaked after drain."""
    for greedy in (True, False):
        mode = "greedy" if greedy else "sampled"
        ample = _forced_preempt(cfg, mesh, params, num_pages=16,
                                greedy=greedy)
        tight = _forced_preempt(cfg, mesh, params, num_pages=3,
                                greedy=greedy)
        if tight.stats["preemptions"] <= 0:
            raise AssertionError(
                f"forced-preemption run ({mode}) saw no preemption: "
                f"{tight.kv_cache_stats()['pressure']}"
            )
        toks = lambda s: {r["id"]: r["generated"] for r in s.completed}
        if toks(tight) != toks(ample):
            raise AssertionError(
                f"preempt-resume changed tokens vs ample pool ({mode}): "
                f"{toks(tight)} vs {toks(ample)}"
            )
        if tight._alloc.used != 0:
            raise AssertionError(
                f"allocator leaked {tight._alloc.used} pages across "
                f"preempt/resume ({mode})"
            )


def _leaked_pages(sched) -> int:
    """Pages still allocated after drain beyond the prefix trie's own pins
    (with ``prefix_cache`` on, trie-pinned pages legitimately survive their
    inserting request — anything else is a leak)."""
    kv = sched.kv_cache_stats()
    pinned = kv.get("prefix_cache", {}).get("trie_pages", 0)
    return sched._alloc.used - pinned


def run_chaos(cfg, mesh, params, *, arrival: str = "burst",
              n_requests: int = 12, seed: int = 0,
              fault_seed: int = 0) -> dict:
    """Goodput-under-faults: the seeded burst workload replayed twice on
    the same scheduler config — fault-free baseline, then under the
    seeded chaos schedule — reporting the recovery counters, the goodput
    retention ratio, and completed-request token identity between the
    two runs (the recovery-correctness signal the nightly soak records).
    No scheduled cancellations: every request must complete in both runs
    so the identity comparison covers the full workload."""
    from repro import compat
    from repro.serve.faults import FaultConfig, FaultInjector
    from repro.serve.serve import BatchScheduler, ServeConfig
    from repro.serve.traffic import TrafficConfig, generate_workload, replay

    tcfg = TrafficConfig(
        n_requests=n_requests, seed=seed, arrival=arrival, rate=0.8,
        prompt_short=(4, 10), prompt_long=(12, 20), max_new_short=(3, 6),
        max_new_long=(8, 12), cancel_frac=0.0, vocab_hi=cfg.vocab,
    )
    workload = generate_workload(tcfg)
    fcfg = FaultConfig(seed=fault_seed, horizon_ticks=24, n_nan=2,
                       n_page_corrupt=1, n_alloc_spike=1, n_hang=1,
                       hang_s=0.2)

    def one(injector):
        with compat.use_mesh(mesh):
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                            paged=True, page_size=8, num_pages=10,
                            prefix_cache=True, watchdog_deadline_s=0.05),
                params,
            )
            metrics = replay(sched, workload, faults=injector)
        return metrics, sched

    base_m, base_s = one(None)
    injector = FaultInjector(fcfg)
    chaos_m, chaos_s = one(injector)
    gen_b, gen_c = base_m.pop("generated"), chaos_m.pop("generated")
    common = set(gen_b) & set(gen_c)
    return {
        "arrival": arrival,
        "fault_config": dataclasses.asdict(fcfg),
        "identical_completed_tokens": (
            set(gen_b) == set(gen_c)
            and all(gen_b[k] == gen_c[k] for k in common)
        ),
        "completed_both": len(common),
        "injected": dict(injector.counters),
        "goodput_retention": round(
            chaos_m["goodput_tokens_per_sec"]
            / max(base_m["goodput_tokens_per_sec"], 1e-9), 3
        ),
        "zero_leak": _leaked_pages(base_s) == 0 and _leaked_pages(chaos_s) == 0,
        "baseline": base_m,
        "chaos": chaos_m,
    }


def _chaos_batch(cfg, mesh, params, *, greedy: bool, fault_cfg=None,
                 fault_events=None, max_new: int = 8):
    """One drained scheduler pass over a fixed 6-request trace, with an
    optional fault schedule; the ``_check_chaos`` building block (small
    direct submits — faster and more controllable than the traffic
    composition, which ``run_chaos`` covers)."""
    from repro import compat
    from repro.serve.faults import FaultInjector
    from repro.serve.serve import BatchScheduler, ServeConfig

    kw = {} if greedy else dict(greedy=False, temperature=0.8, top_k=20,
                                sample_seed=3)
    injector = None
    if fault_cfg is not None or fault_events is not None:
        injector = FaultInjector(fault_cfg, events=fault_events)
    prompts = _request_trace(cfg, 6, seed=5)
    with compat.use_mesh(mesh):
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=4, prefill_chunk=4, paged=True,
                        page_size=8, num_pages=24, prefix_cache=True,
                        watchdog_deadline_s=0.05, **kw),
            params, fault_injector=injector,
        )
        for rid, p in enumerate(prompts):
            sched.submit(p, request_id=rid, max_new=max_new)
        sched.drain()
    return sched, injector


def _check_chaos(cfg, mesh, params) -> None:
    """The fault-recovery identity gate (tiny shape): under a seeded
    schedule injecting every fault kind at least once, each request's
    tokens must be bitwise identical to the fault-free run — greedy AND
    sampled — with zero pages leaked; then a targeted schedule that
    exhausts one request's retries must quarantine exactly that request
    (terminal ``failed``, pages freed) while its co-residents stay
    bitwise intact."""
    from repro.serve.faults import FaultConfig, FaultEvent

    fcfg = FaultConfig(seed=3, horizon_ticks=20, n_nan=2, n_page_corrupt=1,
                       n_alloc_spike=1, n_hang=1, hang_s=0.2)
    toks = lambda s: {r["id"]: r["generated"] for r in s.completed}
    for greedy in (True, False):
        mode = "greedy" if greedy else "sampled"
        base, _ = _chaos_batch(cfg, mesh, params, greedy=greedy)
        chaos, inj = _chaos_batch(cfg, mesh, params, greedy=greedy,
                                  fault_cfg=fcfg)
        for kind in ("nan_injected", "pages_corrupted", "alloc_spikes",
                     "hangs"):
            if inj.counters[kind] < 1:
                raise AssertionError(
                    f"chaos schedule injected no {kind} ({mode}): "
                    f"{inj.counters}"
                )
        rec = chaos.kv_cache_stats()["recovery"]
        if rec["retries"] < 1 or rec["watchdog_trips"] < 1:
            raise AssertionError(
                f"chaos run recovered nothing ({mode}): {rec}"
            )
        if toks(chaos) != toks(base):
            raise AssertionError(
                f"fault recovery changed tokens vs fault-free run "
                f"({mode}): {toks(chaos)} vs {toks(base)}"
            )
        if _leaked_pages(chaos) != 0:
            raise AssertionError(
                f"chaos run leaked {_leaked_pages(chaos)} pages ({mode})"
            )
    # quarantine: more NaN faults pinned to request 0 than max_retries
    # allows -> terminal failed, pages freed, neighbors bitwise intact
    base, _ = _chaos_batch(cfg, mesh, params, greedy=True)
    n_faults = base.scfg.max_retries + 1
    events = [FaultEvent(kind="nan", tick=4 + 3 * i, request_id=0)
              for i in range(n_faults)]
    quar, _ = _chaos_batch(cfg, mesh, params, greedy=True,
                           fault_events=events)
    victims = [r for r in quar.failed if r["id"] == 0]
    if not victims or victims[0]["_status"] != "failed":
        raise AssertionError(
            f"request 0 was not quarantined: failed={quar.failed} "
            f"stats={quar.kv_cache_stats()['recovery']}"
        )
    if quar.stats["quarantined"] != 1:
        raise AssertionError(
            f"expected exactly 1 quarantine: {quar.stats['quarantined']}"
        )
    expect = {k: v for k, v in toks(base).items() if k != 0}
    if toks(quar) != expect:
        raise AssertionError(
            f"quarantine disturbed co-resident streams: {toks(quar)} "
            f"vs {expect}"
        )
    if _leaked_pages(quar) != 0:
        raise AssertionError(
            f"quarantine leaked {_leaked_pages(quar)} pages"
        )


def _workload_pages(prompts, max_new: int, batch: int, page_size: int) -> int:
    """Pool size for the trace: every concurrently-resident request (at most
    ``batch``) fully extended — the honest paged footprint, well below the
    dense ``batch x max_len`` equivalent for mixed-length request sets."""
    need = max(len(p) for p in prompts) + max_new
    return batch * (-(-need // page_size))


def _copy_regime(params):
    """Zero the residual blocks so the logits become a pure function of the
    LAST token (embed -> final norm -> unembed: a near-Markov map over the
    vocab). Greedy decode on such a model must fall into a cycle
    (pigeonhole), which is exactly the workload a prompt-lookup drafter can
    predict — random init weights generate aperiodic continuations no
    n-gram lookup ever matches, and the spec A/B would measure pure
    overhead. The zeroed model runs the exact same jitted step functions
    at the exact same shapes, so the dispatch-count and wall-clock win it
    measures is the real one."""
    import jax

    return dict(params, slots=jax.tree_util.tree_map(
        lambda x: x * 0.0, params["slots"]))


def _spec_repetitive_trace():
    """Prompts built from a repeated 4-gram: the drafter locks on from the
    prompt itself, and the copy-regime model keeps the repetition going."""
    pat = [5, 9, 13, 7]
    return [pat * 4, pat * 6, [2, 3] + pat * 5]


def run_spec(cfg_name: str = "tinyllama-1.1b", *, spec_k: int = 4,
             greedy: bool = True, max_new: int = 160,
             max_new_nonrep: int = 12) -> dict:
    """Speculative-decode A/B: the same trace with ``spec_decode`` on vs
    off, on a repetitive trace (copy-regime weights — the drafter's home
    turf) and a non-repetitive one (random weights + random prompts — the
    drafter proposes little and speculation must degrade gracefully to
    the sequential path). Reports bitwise token identity, tokens/sec
    speedup, TTFT, acceptance rate and pages leaked after drain."""
    cfg, mesh, params = _build(cfg_name)
    kw = dict(overlap=True, batch=4, prefill_chunk=16, max_len=256,
              page_size=16, spec_k=spec_k)
    if not greedy:
        kw.update(greedy=False, temperature=0.8, top_k=20, sample_seed=3)
    traces = {
        "repetitive": (_copy_regime(params), _spec_repetitive_trace(),
                       max_new),
        "non_repetitive": (params, _request_trace(cfg, 3, seed=7),
                           max_new_nonrep),
    }
    out: dict = {"arch": cfg_name, "spec_k": spec_k, "greedy": greedy}
    for name, (ps, prompts, new) in traces.items():
        mkw = dict(kw, max_new=new,
                   num_pages=_workload_pages(prompts, new, kw["batch"],
                                             kw["page_size"]))
        # warmup: spec on/off are distinct jit keys (the verify step only
        # exists on the spec side) — compile both outside the timed passes
        for spec in (False, True):
            run_mode(cfg, mesh, ps, prompts[:2], spec_decode=spec,
                     **{**mkw, "max_new": 2})
        off = run_mode(cfg, mesh, ps, prompts, spec_decode=False, **mkw)
        on = run_mode(cfg, mesh, ps, prompts, spec_decode=True, **mkw)
        gen_on, gen_off = on.pop("generated"), off.pop("generated")
        spec = on["kv"]["speculation"]
        out[name] = {
            # the tentpole guarantee: accepted draft tokens are exactly the
            # tokens sequential decode would have produced
            "identical_tokens": gen_on == gen_off,
            "tokens_per_sec_speedup": round(
                on["tokens_per_sec"] / max(off["tokens_per_sec"], 1e-9), 3),
            # deterministic win (no wall-clock jitter): decode dispatches
            # the accepted drafts made unnecessary
            "dispatches_saved": (off["stats"]["decode_steps"]
                                 - on["stats"]["decode_steps"]),
            "acceptance_rate": spec["acceptance_rate"],
            "mean_accepted_len": spec["mean_accepted_len"],
            "tokens_per_dispatch": spec["tokens_per_dispatch"],
            "leaked_pages_on": on["kv"]["pages_in_use"],
            "leaked_pages_off": off["kv"]["pages_in_use"],
            "spec_on": on,
            "spec_off": off,
        }
    return out


def run(n_requests: int = 6, max_new: int = 16, batch: int = 4,
        prefill_chunk: int = 8, cfg_name: str = "tinyllama-1.1b",
        page_size: int = 16, max_len: int = 128) -> dict:
    cfg, mesh, params = _build(cfg_name)
    prompts = _request_trace(cfg, n_requests)
    num_pages = _workload_pages(prompts, max_new, batch, page_size)
    kw = dict(max_new=max_new, batch=batch, prefill_chunk=prefill_chunk,
              max_len=max_len, page_size=page_size)
    # warmup: compile BOTH layouts' decode + prefill traces outside the
    # measured passes (the jitted pairs are keyed on paged vs dense)
    run_mode(cfg, mesh, params, prompts[:2], overlap=True, paged=True,
             num_pages=num_pages, **{**kw, "max_new": 2})
    run_mode(cfg, mesh, params, prompts[:2], overlap=True, paged=False,
             **{**kw, "max_new": 2})
    paged_ov = run_mode(cfg, mesh, params, prompts, overlap=True, paged=True,
                        num_pages=num_pages, **kw)
    paged_sw = run_mode(cfg, mesh, params, prompts, overlap=False, paged=True,
                        num_pages=num_pages, **kw)
    dense_ov = run_mode(cfg, mesh, params, prompts, overlap=True, paged=False,
                        **kw)
    # shared-prefix A/B: longest page-aligned system prompt that still leaves
    # room for the divergent tail + generation inside max_len
    prefix_len = max(page_size,
                     ((max_len // 2 - max_new) // page_size) * page_size)
    prefix = run_prefix(cfg, mesh, params, n_requests=n_requests,
                        prefix_len=prefix_len, max_new=max_new, batch=batch,
                        prefill_chunk=prefill_chunk, max_len=max_len,
                        page_size=page_size)
    gen_po, gen_ps = paged_ov.pop("generated"), paged_sw.pop("generated")
    gen_do = dense_ov.pop("generated")
    # open-loop traffic: the same seeded workload under memoryless and
    # bursty arrivals, against a pool tight enough that bursts queue and
    # preempt — goodput and TTFT tails are the load-dependent numbers a
    # fixed FIFO trace can never produce
    traffic = {
        arrival: run_traffic(cfg, mesh, params, arrival=arrival)
        for arrival in ("poisson", "burst")
    }
    # speculative decode A/B at its own tuned shape (the strict-speedup
    # comparison needs enough decode steps that dispatch savings dominate)
    speculation = run_spec(cfg_name)
    ostats = paged_ov["stats"]
    kv_paged, kv_dense = paged_ov["kv"], dense_ov["kv"]
    return {
        "arch": cfg_name,
        "config": {"requests": n_requests, "max_new": max_new, "batch": batch,
                   "prefill_chunk": prefill_chunk, "max_len": max_len,
                   "page_size": page_size, "num_pages": num_pages},
        # overlap on/off bitwise token identity (on the paged layout)
        "identical_tokens": gen_po == gen_ps,
        # paged vs dense bitwise token identity (the tentpole guarantee)
        "paged_matches_dense": gen_po == gen_do,
        # prefill and decode genuinely co-existed (overlap_ticks > 0) and no
        # tick's decode dispatch ever waited behind prefill work — "no
        # decode gap > one tick while a prefill is in progress"
        "overlap_no_decode_gap": (
            ostats["overlap_ticks"] > 0
            and ostats["decode_after_prefill_ticks"] == 0
        ),
        "kv": {
            "paged": kv_paged,
            "dense": kv_dense,
            # the memory win: pool footprint strictly below the dense buffers
            "paged_below_dense": kv_paged["kv_bytes"] < kv_dense["kv_bytes"],
            "savings_ratio": round(
                kv_dense["kv_bytes"] / max(kv_paged["kv_bytes"], 1), 3
            ),
        },
        "paged_overlap": paged_ov,
        "paged_stop_world": paged_sw,
        "dense_overlap": dense_ov,
        "prefix": prefix,
        "traffic": traffic,
        "speculation": speculation,
    }


def check(out_path: str | None = None) -> str:
    """The cheap CI shape: tiny trace, asserts the acceptance criteria."""
    result = run(n_requests=3, max_new=6, batch=2, prefill_chunk=4,
                 max_len=64)
    if not result["identical_tokens"]:
        raise AssertionError(
            "overlapped chunked prefill changed generated tokens vs "
            "stop-the-world prefill"
        )
    if not result["paged_matches_dense"]:
        raise AssertionError(
            "paged KV cache changed generated tokens vs the dense layout"
        )
    if not result["overlap_no_decode_gap"]:
        raise AssertionError(
            "decode gap while a prefill was in flight: "
            f"{result['paged_overlap']['stats']}"
        )
    if not result["kv"]["paged_below_dense"]:
        raise AssertionError(
            "paged pool footprint not below dense KV bytes: "
            f"{result['kv']}"
        )
    ov, sw = result["paged_overlap"], result["paged_stop_world"]
    # only enforce the wall-clock comparison when stop-the-world stalled
    # measurably (tiny CI shapes on loaded runners are jitter-prone)
    if sw["decode_max_gap_s"] > 0.05 and (
            ov["decode_max_gap_s"] >= sw["decode_max_gap_s"]):
        raise AssertionError(
            f"overlap did not beat stop-the-world on decode stall: "
            f"{ov['decode_max_gap_s']}s >= {sw['decode_max_gap_s']}s"
        )
    prefix = result["prefix"]
    if not prefix["identical_tokens"]:
        raise AssertionError(
            "prefix sharing changed generated tokens vs the cold path (greedy)"
        )
    if not prefix["peak_pages_below_no_sharing"]:
        raise AssertionError(
            "prefix sharing did not reduce peak live pages: "
            f"on={prefix['sharing_on']['kv']['peak_used_pages']} vs "
            f"off={prefix['sharing_off']['kv']['peak_used_pages']}"
        )
    if prefix["prefill_chunks_saved"] <= 0:
        raise AssertionError(
            "prefix sharing skipped no prefill chunks: "
            f"{prefix['prefill_chunks_saved']}"
        )
    # sampling must be sharing-invariant too (per-slot streams are keyed on
    # absolute position, not on how the KV for earlier positions got there)
    cfg, mesh, params = _build()
    sampled = run_prefix(cfg, mesh, params, n_requests=3, prefix_len=16,
                         max_new=6, batch=2, prefill_chunk=4, max_len=64,
                         page_size=16, greedy=False, temperature=0.8, top_k=5)
    if not sampled["identical_tokens"]:
        raise AssertionError(
            "prefix sharing changed sampled tokens (temperature=0.8, top_k=5)"
        )
    # ...and the S>1 paged prefill read must match the dense layout under
    # sampling as well as greedy (the greedy case is gated above)
    sprompts = _request_trace(cfg, 3)
    skw = dict(overlap=True, max_new=6, batch=2, prefill_chunk=4, max_len=64,
               page_size=16, greedy=False, temperature=0.8, top_k=5)
    spaged = run_mode(cfg, mesh, params, sprompts, paged=True,
                      num_pages=_workload_pages(sprompts, 6, 2, 16), **skw)
    sdense = run_mode(cfg, mesh, params, sprompts, paged=False, **skw)
    if spaged["generated"] != sdense["generated"]:
        raise AssertionError(
            "paged KV cache changed sampled tokens vs the dense layout"
        )
    # forced-preemption identity (greedy AND sampled) + no-leak gate
    _check_preemption(cfg, mesh, params)
    # fault-recovery identity (greedy AND sampled), every fault kind
    # injected, quarantine-works + no-leak gate
    _check_chaos(cfg, mesh, params)
    # goodput sanity under both arrival processes: the tight pool must
    # degrade gracefully (preempt/queue), never drop or fail a request
    for arrival, m in result["traffic"].items():
        if m["completed"] + m["cancelled"] != m["requests"] or m["failed"]:
            raise AssertionError(
                f"traffic[{arrival}] lost requests: {m['completed']} done + "
                f"{m['cancelled']} cancelled + {m['failed']} failed "
                f"of {m['requests']}"
            )
        if m["good_tokens"] <= 0 or m["goodput_tokens_per_sec"] <= 0:
            raise AssertionError(
                f"traffic[{arrival}] produced no goodput: {m}"
            )
        if not (m["ttft_p50_s"] <= m["ttft_p95_s"] <= m["ttft_p99_s"]):
            raise AssertionError(
                f"traffic[{arrival}] TTFT percentiles inverted: {m}"
            )
    # speculative decode: bitwise identity on BOTH traces, a strict
    # tokens/sec win + real acceptance on the repetitive one, and zero
    # pages leaked after drain with rejections in play
    spec = result["speculation"]
    for name in ("repetitive", "non_repetitive"):
        s = spec[name]
        if not s["identical_tokens"]:
            raise AssertionError(
                f"speculative decode changed tokens on the {name} trace "
                "(greedy)"
            )
        if s["leaked_pages_on"] or s["leaked_pages_off"]:
            raise AssertionError(
                f"speculative {name} run leaked pages: "
                f"on={s['leaked_pages_on']} off={s['leaked_pages_off']}"
            )
    rep = spec["repetitive"]
    if rep["acceptance_rate"] <= 0:
        raise AssertionError(
            "drafter accepted nothing on the repetitive trace: "
            f"{rep['acceptance_rate']}"
        )
    if rep["tokens_per_sec_speedup"] <= 1.0:
        raise AssertionError(
            "speculation did not beat plain decode on the repetitive "
            f"trace: {rep['tokens_per_sec_speedup']}x "
            f"(dispatches_saved={rep['dispatches_saved']})"
        )
    # sampled mode must stay bitwise-invariant too (per-request keys folded
    # at the accepted position == the keys sequential decode would fold);
    # smaller max_new — identity is the gate here, not throughput
    sspec = run_spec(greedy=False, max_new=24, max_new_nonrep=8)
    for name in ("repetitive", "non_repetitive"):
        if not sspec[name]["identical_tokens"]:
            raise AssertionError(
                f"speculative decode changed sampled tokens on the {name} "
                "trace (temperature=0.8, top_k=20)"
            )
    _save(result, out_path)
    return csv_line(
        "check_serve_paged",
        ov["wall_s"] * 1e6 / max(ov["ticks"], 1),
        f"tok/s={ov['tokens_per_sec']};kv_savings={result['kv']['savings_ratio']}x;"
        f"pool_util={result['kv']['paged']['pool_utilization']};"
        f"prefix_chunks_saved={prefix['prefill_chunks_saved']};"
        f"traffic_goodput={result['traffic']['burst']['goodput_tokens_per_sec']};"
        f"spec_speedup={rep['tokens_per_sec_speedup']}x;"
        f"spec_accept={rep['acceptance_rate']}",
    )


def _save(result: dict, out_path: str | None = None) -> str:
    path = out_path or os.environ.get(
        "BENCH_SERVE_OUT",
        os.path.join(os.path.dirname(RESULTS_DIR) or "results",
                     "BENCH_serve.json"),
    )
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return path


def _lines(result: dict, path: str) -> list[str]:
    po, do = result["paged_overlap"], result["dense_overlap"]
    sw = result["paged_stop_world"]
    pf = result["prefix"]
    pon, poff = pf["sharing_on"], pf["sharing_off"]
    tag = result["arch"]
    return [
        csv_line(f"serve_paged_overlap[{tag}]",
                 po["wall_s"] * 1e6 / max(po["ticks"], 1),
                 f"tok/s={po['tokens_per_sec']};ttft={po['ttft_mean_s']}s;"
                 f"kv_bytes={po['kv']['kv_bytes']};"
                 f"pool_util={po['kv']['pool_utilization']}"),
        csv_line(f"serve_paged_stop_world[{tag}]",
                 sw["wall_s"] * 1e6 / max(sw["ticks"], 1),
                 f"tok/s={sw['tokens_per_sec']};ttft={sw['ttft_mean_s']}s"),
        csv_line(f"serve_dense_overlap[{tag}]",
                 do["wall_s"] * 1e6 / max(do["ticks"], 1),
                 f"tok/s={do['tokens_per_sec']};kv_bytes={do['kv']['kv_bytes']}"),
        csv_line(f"serve_identity[{tag}]", 0.0,
                 f"overlap_identical={result['identical_tokens']};"
                 f"paged_matches_dense={result['paged_matches_dense']};"
                 f"no_decode_gap={result['overlap_no_decode_gap']};"
                 f"kv_savings={result['kv']['savings_ratio']}x;json={path}"),
        csv_line(f"serve_prefix_sharing_on[{tag}]",
                 pon["wall_s"] * 1e6 / max(pon["ticks"], 1),
                 f"tok/s={pon['tokens_per_sec']};ttft={pon['ttft_mean_s']}s;"
                 f"peak_pages={pon['kv']['peak_used_pages']}"),
        csv_line(f"serve_prefix_sharing_off[{tag}]",
                 poff["wall_s"] * 1e6 / max(poff["ticks"], 1),
                 f"tok/s={poff['tokens_per_sec']};ttft={poff['ttft_mean_s']}s;"
                 f"peak_pages={poff['kv']['peak_used_pages']}"),
        csv_line(f"serve_prefix_identity[{tag}]", 0.0,
                 f"identical={pf['identical_tokens']};"
                 f"peak_pages_below={pf['peak_pages_below_no_sharing']};"
                 f"prefill_chunks_saved={pf['prefill_chunks_saved']};"
                 f"ttft_speedup={pf['ttft_mean_speedup']}x"),
    ] + _spec_lines(result["speculation"], tag) + [
        csv_line(f"serve_traffic_{arrival}[{tag}]",
                 tr["wall_s"] * 1e6 / max(tr["ticks"], 1),
                 f"goodput={tr['goodput_tokens_per_sec']}tok/s;"
                 f"ttft_p50={tr['ttft_p50_s']}s;ttft_p99={tr['ttft_p99_s']}s;"
                 f"queue_peak={tr['queue_depth_peak']};"
                 f"preempt={tr['preemptions']};resume={tr['resumes']};"
                 f"cancel={tr['cancellations']}")
        for arrival, tr in result["traffic"].items()
    ]


def _spec_lines(spec: dict, tag: str) -> list[str]:
    lines = []
    for name in ("repetitive", "non_repetitive"):
        s = spec[name]
        on = s["spec_on"]
        lines.append(csv_line(
            f"serve_spec_{name}[{tag}]",
            on["wall_s"] * 1e6 / max(on["ticks"], 1),
            f"speedup={s['tokens_per_sec_speedup']}x;"
            f"accept_rate={s['acceptance_rate']};"
            f"tok_per_dispatch={s['tokens_per_dispatch']};"
            f"dispatches_saved={s['dispatches_saved']};"
            f"identical={s['identical_tokens']};"
            f"ttft={on['ttft_mean_s']}s",
        ))
    return lines


def main_spec(archs: list[str] | None = None) -> list[str]:
    """The nightly speculation sweep: per arch, the spec on/off A/B on the
    repetitive and non-repetitive traces, written to
    ``BENCH_serve_spec_<arch>.json`` next to the serve artifacts (the
    Pages assembly globs ``BENCH_serve*.json``, so the speculation
    trajectory rides the existing pipeline)."""
    archs = archs or ["tinyllama-1.1b"]
    lines: list[str] = []
    for arch in archs:
        result = {"arch": arch, "speculation": run_spec(arch)}
        path = _save(result, os.path.join(
            os.path.dirname(RESULTS_DIR) or "results",
            f"BENCH_serve_spec_{arch}.json",
        ))
        lines += _spec_lines(result["speculation"], arch)
        lines.append(csv_line(
            f"serve_spec_json[{arch}]", 0.0, f"json={path}"))
    return lines


def main_traffic(archs: list[str] | None = None) -> list[str]:
    """The nightly traffic sweep: per arch, the open-loop harness under
    Poisson and bursty arrivals (moderate scale, tight pool), written to
    ``BENCH_serve_traffic_<arch>.json`` next to the serve artifacts."""
    archs = archs or ["tinyllama-1.1b"]
    lines: list[str] = []
    for arch in archs:
        cfg, mesh, params = _build(arch)
        result = {
            "arch": arch,
            "traffic": {
                arrival: run_traffic(cfg, mesh, params, arrival=arrival,
                                     n_requests=16, num_pages=8)
                for arrival in ("poisson", "burst")
            },
        }
        path = _save(result, os.path.join(
            os.path.dirname(RESULTS_DIR) or "results",
            f"BENCH_serve_traffic_{arch}.json",
        ))
        lines += [
            csv_line(f"serve_traffic_{arrival}[{arch}]",
                     tr["wall_s"] * 1e6 / max(tr["ticks"], 1),
                     f"goodput={tr['goodput_tokens_per_sec']}tok/s;"
                     f"ttft_p99={tr['ttft_p99_s']}s;"
                     f"preempt={tr['preemptions']};json={path}")
            for arrival, tr in result["traffic"].items()
        ]
    return lines


def main_chaos(archs: list[str] | None = None) -> list[str]:
    """The nightly chaos soak: per arch, the seeded burst workload under
    the seeded fault schedule vs fault-free, written to
    ``BENCH_serve_chaos_<arch>.json`` next to the serve artifacts (the
    Pages assembly globs ``BENCH_serve*.json``, so the robustness
    trajectory rides the existing pipeline)."""
    archs = archs or ["tinyllama-1.1b"]
    lines: list[str] = []
    for arch in archs:
        cfg, mesh, params = _build(arch)
        result = {"arch": arch,
                  "chaos": run_chaos(cfg, mesh, params, arrival="burst")}
        path = _save(result, os.path.join(
            os.path.dirname(RESULTS_DIR) or "results",
            f"BENCH_serve_chaos_{arch}.json",
        ))
        ch = result["chaos"]
        rec = ch["chaos"].get("recovery", {})
        lines.append(csv_line(
            f"serve_chaos_{ch['arrival']}[{arch}]",
            ch["chaos"]["wall_s"] * 1e6 / max(ch["chaos"]["ticks"], 1),
            f"goodput_retention={ch['goodput_retention']};"
            f"identical={ch['identical_completed_tokens']};"
            f"retries={rec.get('retries', 0)};"
            f"quarantined={rec.get('quarantined', 0)};"
            f"watchdog={rec.get('watchdog_trips', 0)};"
            f"zero_leak={ch['zero_leak']};json={path}",
        ))
    return lines


def main(archs: list[str] | None = None) -> list[str]:
    archs = archs or ["tinyllama-1.1b"]
    lines: list[str] = []
    for i, arch in enumerate(archs):
        result = run(cfg_name=arch)
        path = _save(result) if i == 0 else _save(
            result,
            os.path.join(os.path.dirname(RESULTS_DIR) or "results",
                         f"BENCH_serve_{arch}.json"),
        )
        lines += _lines(result, path)
    return lines


if __name__ == "__main__":
    argv = sys.argv[1:]
    print("name,us_per_call,derived")
    if argv and argv[0] == "--traffic":
        for line in main_traffic(argv[1:] or None):
            print(line)
    elif argv and argv[0] == "--chaos":
        for line in main_chaos(argv[1:] or None):
            print(line)
    elif argv and argv[0] == "--spec":
        for line in main_spec(argv[1:] or None):
            print(line)
    else:
        for line in main(argv or None):
            print(line)
