"""Serving throughput A/B — paged vs dense KV cache, overlap vs stop-world.

Runs the same request trace through ``serve.BatchScheduler`` three ways —
paged+overlapped (the production configuration), paged+stop-the-world, and
dense+overlapped — and measures what the ISSUE's acceptance criteria name:

  tokens/sec            end-to-end generated-token throughput
  ttft                  time from submit to the first-token dispatch
                        (prefill completion), per request
  decode max gap        longest wall-clock gap between consecutive decode
                        dispatches while a prefill was in flight — the
                        "decode stall" a stop-the-world prefill causes
  peak KV bytes         attention-cache HBM footprint: the full dense
                        buffers vs the paged pool (sized to the workload's
                        concurrent-token peak), plus the pool's live-page
                        peak and utilization
  overlap guarantee     scheduler-level invariant: every tick with an
                        in-flight prefill and >=1 decoding slot also
                        dispatched a decode (no gap > one tick)
  identical tokens      paged == dense, and overlap on/off, token for token

Emits ``BENCH_serve.json`` (default ``results/BENCH_serve.json``) so the
repo carries a serve-path perf trajectory next to the TALP records; the
``--check`` shape in ``benchmarks/run.py`` runs the tiny variant and
asserts paged/dense token identity, the overlap guarantee, and that the
paged pool footprint lands strictly below dense for the mixed-length trace.

    PYTHONPATH=src:. python benchmarks/serve_throughput.py [arch ...]

With archs given (the nightly sweep), the first writes BENCH_serve.json
and each additional arch writes BENCH_serve_<arch>.json.
"""

from __future__ import annotations

import json
import os
import sys
import time

from benchmarks.common import RESULTS_DIR, csv_line


def _build(cfg_name: str = "tinyllama-1.1b"):
    import jax

    from repro.configs import smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.layers.common import init_params
    from repro.models import transformer as T

    cfg = smoke_config(cfg_name)
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, mesh, params


def _request_trace(cfg, n_requests: int, seed: int = 0):
    import numpy as np

    rng = np.random.default_rng(seed)
    return [rng.integers(4, cfg.vocab, size=int(n)).tolist()
            for n in rng.integers(8, 24, size=n_requests)]


def run_mode(cfg, mesh, params, prompts, *, overlap: bool, max_new: int,
             batch: int, prefill_chunk: int, max_len: int = 128,
             paged: bool = True, page_size: int = 16,
             num_pages: int | None = None) -> dict:
    """One scheduler pass; returns the measured dict for BENCH_serve.json."""
    from repro import compat
    from repro.serve.serve import BatchScheduler, ServeConfig

    with compat.use_mesh(mesh):
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=max_len, batch=batch,
                        prefill_chunk=prefill_chunk, overlap=overlap,
                        paged=paged, page_size=page_size,
                        num_pages=num_pages),
            params,
        )
        # stagger: half the requests arrive while the first half decodes,
        # so prefill-on-attach genuinely competes with in-flight decode
        half = max(1, len(prompts) // 2)
        first, late = prompts[:half], prompts[half:]
        t0 = time.perf_counter()
        submit_t: dict = {}
        for rid, p in enumerate(first):
            sched.submit(p, request_id=rid, max_new=max_new)
            submit_t[rid] = time.perf_counter()
        decode_times: list[float] = []
        gaps_during_prefill: list[float] = []
        ttft: dict = {}
        ticks = 0
        injected = False
        while len(sched.completed) < len(prompts) and ticks < 50 * max_new:
            if not injected and ticks >= 2:
                for rid, p in enumerate(late, start=len(first)):
                    sched.submit(p, request_id=rid, max_new=max_new)
                    submit_t[rid] = time.perf_counter()
                injected = True
            prefill_inflight = bool(sched._prefills)
            decodes_before = sched.stats["decode_steps"]
            sched.step()
            now = time.perf_counter()
            if sched.stats["decode_steps"] > decodes_before:
                if decode_times and prefill_inflight:
                    gaps_during_prefill.append(now - decode_times[-1])
                decode_times.append(now)
            for slot, req in enumerate(sched.active):
                if req is not None and req["id"] not in ttft:
                    # first-token dispatch: the request just finished prefill
                    ttft[req["id"]] = now - submit_t[req["id"]]
            ticks += 1
        sched.drain()
        wall = time.perf_counter() - t0
    tokens = sum(len(r["generated"]) for r in sched.completed)
    return {
        "overlap": overlap,
        "paged": paged,
        "requests": len(prompts),
        "completed": len(sched.completed),
        "ticks": ticks,
        "wall_s": round(wall, 4),
        "tokens": tokens,
        "tokens_per_sec": round(tokens / max(wall, 1e-9), 2),
        "ttft_mean_s": round(sum(ttft.values()) / max(len(ttft), 1), 4),
        "ttft_max_s": round(max(ttft.values(), default=0.0), 4),
        "decode_max_gap_during_prefill_s": round(
            max(gaps_during_prefill, default=0.0), 4
        ),
        # overall stall: the stop-the-world mode pays its prefills *between*
        # decode dispatches (host-blocked inside attach), which this catches
        "decode_max_gap_s": round(
            max((b - a for a, b in zip(decode_times, decode_times[1:])),
                default=0.0), 4
        ),
        "kv": sched.kv_cache_stats(),
        "stats": dict(sched.stats),
        "generated": {str(r["id"]): r["generated"] for r in sched.completed},
    }


def _workload_pages(prompts, max_new: int, batch: int, page_size: int) -> int:
    """Pool size for the trace: every concurrently-resident request (at most
    ``batch``) fully extended — the honest paged footprint, well below the
    dense ``batch x max_len`` equivalent for mixed-length request sets."""
    need = max(len(p) for p in prompts) + max_new
    return batch * (-(-need // page_size))


def run(n_requests: int = 6, max_new: int = 16, batch: int = 4,
        prefill_chunk: int = 8, cfg_name: str = "tinyllama-1.1b",
        page_size: int = 16, max_len: int = 128) -> dict:
    cfg, mesh, params = _build(cfg_name)
    prompts = _request_trace(cfg, n_requests)
    num_pages = _workload_pages(prompts, max_new, batch, page_size)
    kw = dict(max_new=max_new, batch=batch, prefill_chunk=prefill_chunk,
              max_len=max_len, page_size=page_size)
    # warmup: compile BOTH layouts' decode + prefill traces outside the
    # measured passes (the jitted pairs are keyed on paged vs dense)
    run_mode(cfg, mesh, params, prompts[:2], overlap=True, paged=True,
             num_pages=num_pages, **{**kw, "max_new": 2})
    run_mode(cfg, mesh, params, prompts[:2], overlap=True, paged=False,
             **{**kw, "max_new": 2})
    paged_ov = run_mode(cfg, mesh, params, prompts, overlap=True, paged=True,
                        num_pages=num_pages, **kw)
    paged_sw = run_mode(cfg, mesh, params, prompts, overlap=False, paged=True,
                        num_pages=num_pages, **kw)
    dense_ov = run_mode(cfg, mesh, params, prompts, overlap=True, paged=False,
                        **kw)
    gen_po, gen_ps = paged_ov.pop("generated"), paged_sw.pop("generated")
    gen_do = dense_ov.pop("generated")
    ostats = paged_ov["stats"]
    kv_paged, kv_dense = paged_ov["kv"], dense_ov["kv"]
    return {
        "arch": cfg_name,
        "config": {"requests": n_requests, "max_new": max_new, "batch": batch,
                   "prefill_chunk": prefill_chunk, "max_len": max_len,
                   "page_size": page_size, "num_pages": num_pages},
        # overlap on/off bitwise token identity (on the paged layout)
        "identical_tokens": gen_po == gen_ps,
        # paged vs dense bitwise token identity (the tentpole guarantee)
        "paged_matches_dense": gen_po == gen_do,
        # prefill and decode genuinely co-existed (overlap_ticks > 0) and no
        # tick's decode dispatch ever waited behind prefill work — "no
        # decode gap > one tick while a prefill is in progress"
        "overlap_no_decode_gap": (
            ostats["overlap_ticks"] > 0
            and ostats["decode_after_prefill_ticks"] == 0
        ),
        "kv": {
            "paged": kv_paged,
            "dense": kv_dense,
            # the memory win: pool footprint strictly below the dense buffers
            "paged_below_dense": kv_paged["kv_bytes"] < kv_dense["kv_bytes"],
            "savings_ratio": round(
                kv_dense["kv_bytes"] / max(kv_paged["kv_bytes"], 1), 3
            ),
        },
        "paged_overlap": paged_ov,
        "paged_stop_world": paged_sw,
        "dense_overlap": dense_ov,
    }


def check(out_path: str | None = None) -> str:
    """The cheap CI shape: tiny trace, asserts the acceptance criteria."""
    result = run(n_requests=3, max_new=6, batch=2, prefill_chunk=4,
                 max_len=64)
    if not result["identical_tokens"]:
        raise AssertionError(
            "overlapped chunked prefill changed generated tokens vs "
            "stop-the-world prefill"
        )
    if not result["paged_matches_dense"]:
        raise AssertionError(
            "paged KV cache changed generated tokens vs the dense layout"
        )
    if not result["overlap_no_decode_gap"]:
        raise AssertionError(
            "decode gap while a prefill was in flight: "
            f"{result['paged_overlap']['stats']}"
        )
    if not result["kv"]["paged_below_dense"]:
        raise AssertionError(
            "paged pool footprint not below dense KV bytes: "
            f"{result['kv']}"
        )
    ov, sw = result["paged_overlap"], result["paged_stop_world"]
    # only enforce the wall-clock comparison when stop-the-world stalled
    # measurably (tiny CI shapes on loaded runners are jitter-prone)
    if sw["decode_max_gap_s"] > 0.05 and (
            ov["decode_max_gap_s"] >= sw["decode_max_gap_s"]):
        raise AssertionError(
            f"overlap did not beat stop-the-world on decode stall: "
            f"{ov['decode_max_gap_s']}s >= {sw['decode_max_gap_s']}s"
        )
    _save(result, out_path)
    return csv_line(
        "check_serve_paged",
        ov["wall_s"] * 1e6 / max(ov["ticks"], 1),
        f"tok/s={ov['tokens_per_sec']};kv_savings={result['kv']['savings_ratio']}x;"
        f"pool_util={result['kv']['paged']['pool_utilization']}",
    )


def _save(result: dict, out_path: str | None = None) -> str:
    path = out_path or os.environ.get(
        "BENCH_SERVE_OUT",
        os.path.join(os.path.dirname(RESULTS_DIR) or "results",
                     "BENCH_serve.json"),
    )
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return path


def _lines(result: dict, path: str) -> list[str]:
    po, do = result["paged_overlap"], result["dense_overlap"]
    sw = result["paged_stop_world"]
    tag = result["arch"]
    return [
        csv_line(f"serve_paged_overlap[{tag}]",
                 po["wall_s"] * 1e6 / max(po["ticks"], 1),
                 f"tok/s={po['tokens_per_sec']};ttft={po['ttft_mean_s']}s;"
                 f"kv_bytes={po['kv']['kv_bytes']};"
                 f"pool_util={po['kv']['pool_utilization']}"),
        csv_line(f"serve_paged_stop_world[{tag}]",
                 sw["wall_s"] * 1e6 / max(sw["ticks"], 1),
                 f"tok/s={sw['tokens_per_sec']};ttft={sw['ttft_mean_s']}s"),
        csv_line(f"serve_dense_overlap[{tag}]",
                 do["wall_s"] * 1e6 / max(do["ticks"], 1),
                 f"tok/s={do['tokens_per_sec']};kv_bytes={do['kv']['kv_bytes']}"),
        csv_line(f"serve_identity[{tag}]", 0.0,
                 f"overlap_identical={result['identical_tokens']};"
                 f"paged_matches_dense={result['paged_matches_dense']};"
                 f"no_decode_gap={result['overlap_no_decode_gap']};"
                 f"kv_savings={result['kv']['savings_ratio']}x;json={path}"),
    ]


def main(archs: list[str] | None = None) -> list[str]:
    archs = archs or ["tinyllama-1.1b"]
    lines: list[str] = []
    for i, arch in enumerate(archs):
        result = run(cfg_name=arch)
        path = _save(result) if i == 0 else _save(
            result,
            os.path.join(os.path.dirname(RESULTS_DIR) or "results",
                         f"BENCH_serve_{arch}.json"),
        )
        lines += _lines(result, path)
    return lines


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in main(sys.argv[1:] or None):
        print(line)
