"""Paper Table 1 — runtime overhead of the collection tools.

Trains the mini-app (reduced tinyllama) for N steps under four regimes:
  baseline      no instrumentation
  talp          TalpMonitor, sync_regions=True (paper's DLB row)
  talp-nosync   TalpMonitor without region syncs (the cheap mode)
  tracer        full event tracing (the Extrae/Score-P row)

Reports wall-time overhead % per regime — the paper's claim is low-single-
digit overhead for TALP vs tracing; granularity sensitivity is exercised by
``--steps-per-region``.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import csv_line, save_result
from repro import compat
from repro.configs import smoke_config
from repro.core import MonitorConfig, ResourceConfig, TalpMonitor, TraceRecorder
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train.train import TrainConfig, init_state, make_train_step


def _setup(steps: int):
    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    tcfg = TrainConfig()
    st = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    state = {"params": st.params, "opt_state": st.opt_state, "step": st.step}
    data = SyntheticLM(DataConfig(global_batch=4, seq_len=64, vocab=cfg.vocab))
    with compat.use_mesh(mesh):
        step = jax.jit(make_train_step(cfg, mesh, tcfg))
        state, m = step(state, data.batch_at(0))  # warmup compile
        jax.block_until_ready(m["loss"])
    batches = [data.batch_at(i) for i in range(steps)]
    return mesh, step, state, batches


def run(steps: int = 30, tmpdir: str = "/tmp/repro_overhead") -> dict:
    res = ResourceConfig(num_hosts=1, devices_per_host=1)
    mesh, step, state0, batches = _setup(steps)
    mesh_ctx = compat.use_mesh(mesh)

    def run_baseline():
        state = state0
        for b in batches:
            state, metrics = step(state, b)
        jax.block_until_ready(metrics["loss"])

    def run_talp(sync: bool):
        mon = TalpMonitor(MonitorConfig(app_name="bench", sync_regions=sync,
                                        lb_sample_every=1), res)
        state = state0
        with mon:
            with mon.region("train"):
                for b in batches:
                    state, metrics = step(state, b)
                    mon.observe_step(
                        metrics if sync else None,
                        tokens_per_shard=metrics.get("tokens_per_shard"),
                    )
        jax.block_until_ready(metrics["loss"])
        return mon.finalize()

    def run_tracer():
        # the tracer writes one event stream per device it owns (Extrae's
        # per-rank .mpit files); simulate the 16-device host share
        res16 = ResourceConfig(num_hosts=1, devices_per_host=16)
        tr = TraceRecorder(tmpdir, res16, clock=time.perf_counter)
        tr.region_enter("train")
        state = state0
        for b in batches:
            state, metrics = step(state, b)
            tr.record_step(metrics,
                           tokens_per_shard=metrics.get("tokens_per_shard"))
        tr.region_exit("train")
        tr.close()

    def best_of(fn, reps=3):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            with mesh_ctx:
                fn()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t_base = best_of(run_baseline)
    t_talp = best_of(lambda: run_talp(True))
    t_talp_ns = best_of(lambda: run_talp(False))
    t_trace = best_of(run_tracer)

    def ovh(t):
        return 100.0 * (t - t_base) / t_base

    result = {
        "steps": steps,
        "baseline_s": t_base,
        "talp_s": t_talp, "talp_overhead_pct": ovh(t_talp),
        "talp_nosync_s": t_talp_ns, "talp_nosync_overhead_pct": ovh(t_talp_ns),
        "tracer_s": t_trace, "tracer_overhead_pct": ovh(t_trace),
    }
    save_result("table1_overhead", result)
    return result


def main() -> list[str]:
    r = run()
    return [
        csv_line("table1_talp_overhead", r["talp_s"] / r["steps"] * 1e6,
                 f"overhead={r['talp_overhead_pct']:.1f}%"),
        csv_line("table1_talp_nosync_overhead", r["talp_nosync_s"] / r["steps"] * 1e6,
                 f"overhead={r['talp_nosync_overhead_pct']:.1f}%"),
        csv_line("table1_tracer_overhead", r["tracer_s"] / r["steps"] * 1e6,
                 f"overhead={r['tracer_overhead_pct']:.1f}%"),
    ]


if __name__ == "__main__":
    print("\n".join(main()))
