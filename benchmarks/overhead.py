"""Paper Table 1 — runtime overhead of the collection tools.

Trains the mini-app (reduced tinyllama) for N steps under five regimes, all
through the ONE ``PerfSession`` code path (the backends are pluggable, the
harness is not):

  baseline      plain loop, no session at all (reference)
  null          PerfSession null backend — must be indistinguishable from
                baseline (wrap_step returns the function unchanged)
  talp          monitor backend, sync_regions=True (paper's DLB row)
  talp-nosync   monitor backend without per-step output syncs (cheap mode)
  tracer        full event tracing (the Extrae/Score-P row)

Reports wall-time overhead % per regime — the paper's claim is low-single-
digit overhead for TALP vs tracing, and the null backend proves the session
facade itself costs nothing.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import csv_line, save_result
from repro import compat
from repro.configs import smoke_config
from repro.core import ResourceConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.session import PerfSession, SessionConfig
from repro.train.train import TrainConfig, init_state, make_train_step


def _setup(steps: int):
    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    tcfg = TrainConfig()
    st = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    state = {"params": st.params, "opt_state": st.opt_state, "step": st.step}
    data = SyntheticLM(DataConfig(global_batch=4, seq_len=64, vocab=cfg.vocab))
    with compat.use_mesh(mesh):
        step = jax.jit(make_train_step(cfg, mesh, tcfg))
        state, m = step(state, data.batch_at(0))  # warmup compile
        jax.block_until_ready(m["loss"])
    batches = [data.batch_at(i) for i in range(steps)]
    return mesh, step, state, batches


# the single harness, parameterized by backend — replaces the three
# hand-rolled loops the old benchmark maintained
def _run_instrumented(step, state0, batches, *, backend: str, sync: bool,
                      resources: ResourceConfig, trace_dir: str = "") -> None:
    session = PerfSession(
        SessionConfig(app_name="bench", backend=backend, sync_regions=sync,
                      lb_sample_every=1, trace_dir=trace_dir,
                      respect_env=False),
        resources,
    )
    wrapped = session.wrap_step(step, region="train")
    state = state0
    with session:
        for b in batches:
            state, metrics = wrapped(state, b)
    jax.block_until_ready(metrics["loss"])
    if backend == "monitor":
        # the O(regions) finalize is part of the monitor's runtime cost;
        # trace post-processing is Table 2's benchmark, not Table 1's
        session.finalize(save=False, git=False)


def run(steps: int = 30, tmpdir: str = "/tmp/repro_overhead") -> dict:
    res = ResourceConfig(num_hosts=1, devices_per_host=1)
    # the tracer writes one event stream per device it owns (Extrae's
    # per-rank .mpit files); simulate the 16-device host share
    res16 = ResourceConfig(num_hosts=1, devices_per_host=16)
    mesh, step, state0, batches = _setup(steps)

    def run_baseline():
        state = state0
        for b in batches:
            state, metrics = step(state, b)
        jax.block_until_ready(metrics["loss"])

    modes = {
        "null": lambda: _run_instrumented(
            step, state0, batches, backend="null", sync=True, resources=res),
        "talp": lambda: _run_instrumented(
            step, state0, batches, backend="monitor", sync=True, resources=res),
        "talp_nosync": lambda: _run_instrumented(
            step, state0, batches, backend="monitor", sync=False, resources=res),
        "tracer": lambda: _run_instrumented(
            step, state0, batches, backend="tracer", sync=True,
            resources=res16, trace_dir=tmpdir),
    }

    def best_of(fn, reps=3):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            with compat.use_mesh(mesh):  # fresh ctx: use_mesh is single-use
                fn()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t_base = best_of(run_baseline)

    def ovh(t):
        return 100.0 * (t - t_base) / t_base

    result = {"steps": steps, "baseline_s": t_base}
    for name, fn in modes.items():
        t = best_of(fn)
        result[f"{name}_s"] = t
        result[f"{name}_overhead_pct"] = ovh(t)
    save_result("table1_overhead", result)
    return result


def main() -> list[str]:
    r = run()
    return [
        csv_line(f"table1_{name}_overhead", r[f"{name}_s"] / r["steps"] * 1e6,
                 f"overhead={r[f'{name}_overhead_pct']:.1f}%")
        for name in ("null", "talp", "talp_nosync", "tracer")
    ]


if __name__ == "__main__":
    print("\n".join(main()))
