"""Paper Figure 7 / §Reports — detect AND explain a performance change.

Simulates a CI history of commits on the mini-app where commit c2 introduces
a host-side stall (dispatch bug) and commit c4 doubles the executed FLOPs
(remat/recompute bug). The report must flag both elapsed-time regressions
and attribute each to the right factor — the paper's core qualitative claim
(wall-clock-only monitoring cannot do the second part).
"""

from __future__ import annotations

import json
import os
import shutil
import time

import jax

from benchmarks.common import csv_line, save_result
from repro import compat
from repro.configs import smoke_config
from repro.core import ResourceConfig, StepProfile, generate_report, scan
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.session import PerfSession, SessionConfig
from repro.train.train import TrainConfig, init_state, make_train_step


def _train_once(commit: str, ts: str, out: str, *, stall_s: float = 0.0,
                flop_scale: float = 1.0, steps: int = 8):
    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    tcfg = TrainConfig()
    st = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    state = {"params": st.params, "opt_state": st.opt_state, "step": st.step}
    data = SyntheticLM(DataConfig(global_batch=2, seq_len=32, vocab=cfg.vocab))
    session = PerfSession(
        SessionConfig(app_name="miniapp", backend="monitor", lb_sample_every=1,
                      respect_env=False),
        ResourceConfig(num_hosts=1, devices_per_host=1),
        metadata={"git_commit_short": commit, "git_commit_timestamp": ts},
    )
    # static profile from the compiled step; the flop bug shows up here
    # exactly as it would through the HLO counters of the buggy binary
    with compat.use_mesh(mesh):
        step = jax.jit(make_train_step(cfg, mesh, tcfg))
        example = data.batch_at(0)
        compiled = step.lower(state, example).compile()
    profile = StepProfile.from_compiled(compiled, num_devices=1)
    profile.flops *= flop_scale
    profile.model_flops = profile.dot_flops
    session.attach_static("train_step", profile)

    # warm up outside the monitored window: compile time must not pollute
    # the elapsed-time series (it would on real CI too — the paper's runs
    # measure the solver, not the build)
    with compat.use_mesh(mesh):
        _s, _m = step(state, data.batch_at(0))
        jax.block_until_ready(_m["loss"])

    with compat.use_mesh(mesh), session:
        for s in range(steps):
            with session.region("train_step"):
                state, metrics = step(state, data.batch_at(s))
                if flop_scale > 1.0:
                    # the recompute bug also costs real time
                    t0 = time.perf_counter()
                    while time.perf_counter() - t0 < 0.15:
                        pass
                session.observe_step(metrics)
                if stall_s:
                    time.sleep(stall_s)  # host-side stall (input pipeline bug)
    run = session.finalize(git=False)
    run.save(out)
    return run


def run(root: str = "/tmp/repro_regression") -> dict:
    shutil.rmtree(root, ignore_errors=True)
    hist = os.path.join(root, "talp", "miniapp", "history")
    commits = [
        ("c0", {}, "2026-07-01"),
        ("c1", {}, "2026-07-02"),
        ("c2", {"stall_s": 0.25}, "2026-07-03"),       # dispatch bug
        ("c3", {}, "2026-07-04"),                      # fixed
        ("c4", {"flop_scale": 2.0}, "2026-07-05"),     # recompute bug
    ]
    for commit, kw, day in commits:
        _train_once(commit, f"{day}T12:00:00", os.path.join(hist, f"{commit}.json"), **kw)

    out = os.path.join(root, "site")
    generate_report(scan(os.path.join(root, "talp")), out, regions=["train_step"])
    findings = json.load(open(os.path.join(out, "findings.json")))

    def find(commit, kind):
        return [
            f for f in findings
            if f["commit"] == commit and f["kind"] == kind
            and f["region"] == "train_step"
        ]

    c2 = find("c2", "regression")
    c4 = find("c4", "regression")
    c2_explained = any("dispatch_efficiency" in f["explanation"] for f in c2)
    c4_explained = any(
        "flop_scaling" in f["explanation"] or "computation_scalability" in f["explanation"]
        for f in c4
    )
    result = {
        "n_findings": len(findings),
        "c2_detected": bool(c2), "c2_explained_as_dispatch": c2_explained,
        "c4_detected": bool(c4), "c4_explained_as_flops": c4_explained,
        "findings": findings,
    }
    save_result("figure7_regression", result)
    return result


def main() -> list[str]:
    r = run()
    return [
        csv_line(
            "figure7_detect_explain", 0.0,
            f"dispatch_bug detected={r['c2_detected']} explained={r['c2_explained_as_dispatch']}; "
            f"recompute_bug detected={r['c4_detected']} explained={r['c4_explained_as_flops']}",
        )
    ]


if __name__ == "__main__":
    print("\n".join(main()))
