"""Paper Tables 6/7 — weak and strong scaling-efficiency tables from REAL
multi-device executions of the mini-app.

Runs the reduced-config training job in subprocesses with 1/2/4 forced host
devices (the only way to change the device count after jax init), collects
the TALP JSONs, and builds both tables. The weak run scales the global batch
with devices; the strong run keeps it fixed.
"""

from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import textwrap

from benchmarks.common import csv_line, save_result
from repro.core import build_table, render_text, scan

_WORKER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import sys
sys.path.insert(0, {src!r})
import jax
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.train import TrainConfig

cfg = smoke_config("tinyllama-1.1b")
data = SyntheticLM(DataConfig(global_batch={batch}, seq_len=64,
                              vocab=cfg.vocab, pad_fraction=0.1))
loop = TrainLoop(cfg, make_host_mesh(), TrainConfig(), data,
                 LoopConfig(steps={steps}, lb_sample_every=1,
                            monitor_app_name="miniapp"))
loop.run()
run = loop.finalize_run()
run.save({out!r})
print("done", run.resources.label)
"""


def _run_config(ndev: int, batch: int, steps: int, out: str) -> None:
    code = _WORKER.format(
        ndev=ndev, batch=batch, steps=steps, out=out,
        src=os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src")),
    )
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"worker {ndev}dev failed:\n{r.stderr[-3000:]}")


def run(root: str = "/tmp/repro_scaling", steps: int = 10) -> dict:
    shutil.rmtree(root, ignore_errors=True)
    for ndev in (1, 2, 4):
        _run_config(ndev, batch=8, steps=steps,
                    out=os.path.join(root, "strong_scaling", f"talp_1x{ndev}.json"))
        _run_config(ndev, batch=4 * ndev, steps=steps,
                    out=os.path.join(root, "weak_scaling", f"talp_1x{ndev}.json"))

    tables = {}
    text = {}
    for exp in scan(root):
        kind = "strong" if "strong" in exp.rel_path else "weak"
        table = build_table(exp.runs)
        tables[kind] = table
        text[kind] = render_text(table)

    result = {
        "strong_mode_detected": tables["strong"].mode,
        "weak_mode_detected": tables["weak"].mode,
        "strong_table": tables["strong"].to_json(),
        "weak_table": tables["weak"].to_json(),
        "strong_text": text["strong"],
        "weak_text": text["weak"],
    }
    save_result("tables67_scaling", result)
    return result


def main() -> list[str]:
    r = run()
    print(r["strong_text"])
    print()
    print(r["weak_text"])
    ok_modes = (r["strong_mode_detected"] == "strong"
                and r["weak_mode_detected"] == "weak")
    return [
        csv_line("tables67_scaling_modes", 0.0,
                 f"strong={r['strong_mode_detected']} weak={r['weak_mode_detected']} "
                 f"detection_correct={ok_modes}"),
    ]


if __name__ == "__main__":
    print("\n".join(main()))
