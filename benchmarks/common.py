"""Shared benchmark utilities."""

from __future__ import annotations

import json
import os
import sys
import time
import tracemalloc

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/benchmarks")


def synthetic_call_chain_hlo(n_comps: int = 150) -> str:
    """A synthetic HLO module text: ENTRY calling a chain of ``n_comps``
    computations via ``call``/``to_apply``. Large enough that a cold
    ``analyze_hlo`` parse measurably dominates a cached hit; shared by the
    CI cache gate (benchmarks/run.py --check) and tests/test_hlo.py so the
    two cannot drift apart grammatically."""
    comps, calls = [], []
    for i in range(n_comps):
        comps.append(
            f"%w{i} (p{i}: f32[32,32]) -> f32[32,32] {{\n"
            f"  %p{i} = f32[32,32]{{1,0}} parameter(0)\n"
            f"  %m{i} = f32[32,32]{{1,0}} multiply(f32[32,32]{{1,0}} %p{i}, f32[32,32]{{1,0}} %p{i})\n"
            f"  %d{i} = f32[32,32]{{1,0}} dot(f32[32,32]{{1,0}} %m{i}, f32[32,32]{{1,0}} %p{i}), "
            f"lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n"
            f"  ROOT %a{i} = f32[32,32]{{1,0}} add(f32[32,32]{{1,0}} %d{i}, f32[32,32]{{1,0}} %p{i})\n"
            f"}}\n"
        )
        prev = "%p" if i == 0 else f"%c{i - 1}"
        root = "ROOT " if i == n_comps - 1 else ""
        calls.append(
            f"  {root}%c{i} = f32[32,32]{{1,0}} call(f32[32,32]{{1,0}} {prev}), to_apply=%w{i}"
        )
    return (
        "HloModule call_chain\n\n" + "\n".join(comps)
        + "\nENTRY %main (p: f32[32,32]) -> f32[32,32] {\n"
        + "  %p = f32[32,32]{1,0} parameter(0)\n"
        + "\n".join(calls) + "\n}\n"
    )


def save_result(name: str, payload: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def peak_memory(fn, *args, **kw):
    """Returns (result, seconds, peak_python_bytes)."""
    tracemalloc.start()
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    dt = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return out, dt, peak


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
