"""Shared benchmark utilities."""

from __future__ import annotations

import json
import os
import sys
import time
import tracemalloc

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/benchmarks")


def save_result(name: str, payload: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def peak_memory(fn, *args, **kw):
    """Returns (result, seconds, peak_python_bytes)."""
    tracemalloc.start()
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    dt = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return out, dt, peak


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
