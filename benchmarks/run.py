"""Benchmark driver — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  table1_*    runtime overhead of monitor vs tracer   (paper Table 1)
  table2_*    post-processing resources               (paper Table 2)
  tables67_*  weak/strong scaling-efficiency tables   (paper Tables 6/7)
  figure7_*   regression detect + explain             (paper Figure 7)
  roofline_*  §Roofline aggregation from the dry-run artifacts
  serve_*     overlapped vs stop-the-world serving    (BENCH_serve.json)

``--check`` is the CI gate: it runs the tier-1 suite
(``PYTHONPATH=src python -m pytest -x -q``) plus a cold-vs-cached
``analyze_hlo`` timing assertion (so the HLO parse cache cannot silently
regress even if the equivalent unit test is edited away) plus the cheap
shape of ``benchmarks/serve_throughput.py`` (paged and dense KV layouts
must keep producing identical tokens — greedy AND sampled — overlapped
chunked prefill must keep producing identical tokens with no decode gap
while prefilling, the paged pool footprint must stay strictly below the
dense buffers, and cross-request prefix sharing must keep tokens bitwise
identical on/off in both decode modes while strictly lowering peak live
pages and skipping prefill chunks). It also forces a preemption (tiny
page pool vs ample pool) and asserts the recompute-resumed token streams
are bitwise identical — greedy AND sampled — with ``preemptions > 0`` and
zero allocator pages leaked after drain, plus a goodput sanity pass of
the open-loop traffic harness under Poisson and bursty arrivals (every
request completed or cancelled, none failed, TTFT percentiles ordered).
Finally the chaos gate: under a seeded fault schedule injecting every
fault kind at least once (NaN logits, KV-page corruption, allocator
spike, hung dispatch), every recovered request's tokens must be bitwise
identical to the fault-free run — greedy AND sampled — a retry-exhausted
request must be quarantined (terminal ``failed``, pages freed,
co-residents untouched), and zero pages may leak after drain. Last the
speculation gate: spec-decode on/off must produce bitwise-identical
tokens on a repetitive AND a non-repetitive trace, greedy AND sampled,
with a STRICT tokens/sec speedup and acceptance_rate > 0 on the
repetitive workload and zero pages leaked after drain.
"""

from __future__ import annotations

import os
import sys
import traceback


def _repo_paths() -> tuple[str, str]:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return root, os.path.join(root, "src")


def _check_cache_speedup(min_ratio: float = 5.0) -> str:
    """Assert a cached analyze_hlo call is >= min_ratio faster than the cold
    parse of the same module text. Returns a CSV summary line."""
    import time

    from benchmarks.common import synthetic_call_chain_hlo
    from repro.core import hlo as H

    text = synthetic_call_chain_hlo()
    H.clear_caches()
    t0 = time.perf_counter()
    cold_cost = H.analyze_hlo(text)
    cold = time.perf_counter() - t0
    warm = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        H.analyze_hlo(text)
        warm = min(warm, time.perf_counter() - t0)
    if cold_cost.hbm_bytes <= 0:
        raise AssertionError("analyze_hlo returned zero hbm_bytes for call-chain module")
    ratio = cold / max(warm, 1e-12)
    if ratio < min_ratio:
        raise AssertionError(
            f"analyze_hlo cache regressed: cold={cold * 1e3:.2f}ms "
            f"warm={warm * 1e3:.3f}ms ratio={ratio:.1f}x < {min_ratio}x"
        )
    return f"check_hlo_cache,{warm * 1e6:.1f},speedup={ratio:.0f}x"


def check() -> int:
    """CI gate: tier-1 suite green + the analyze_hlo cache guarantee."""
    import subprocess

    root, src = _repo_paths()
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    rc = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q"], cwd=root, env=env
    ).returncode
    if rc != 0:
        print(f"[check] tier-1 suite FAILED (rc={rc})", file=sys.stderr)
        return rc
    # invoked as `python benchmarks/run.py`: sys.path[0] is benchmarks/, so
    # both the repo root (for benchmarks.common) and src/ need inserting
    for p in (src, root):
        if p not in sys.path:
            sys.path.insert(0, p)
    try:
        line = _check_cache_speedup()
    except AssertionError as e:
        print(f"[check] {e}", file=sys.stderr)
        return 1
    print(line)
    from benchmarks import serve_throughput

    try:
        print(serve_throughput.check())
    except AssertionError as e:
        print(f"[check] serve paged/overlap: {e}", file=sys.stderr)
        return 1
    print("[check] tier-1 suite green, hlo cache OK, serve paged+overlap OK")
    return 0


def main() -> None:
    if "--check" in sys.argv[1:]:
        sys.exit(check())

    from benchmarks import (
        overhead,
        postprocessing,
        regression,
        roofline,
        scaling_tables,
        serve_throughput,
    )

    lines: list[str] = []
    failures = 0
    for mod in (overhead, postprocessing, scaling_tables, regression, roofline,
                serve_throughput):
        name = mod.__name__.split(".")[-1]
        try:
            lines += mod.main()
        except Exception as e:
            failures += 1
            traceback.print_exc()
            lines.append(f"{name},0.0,FAILED:{type(e).__name__}:{e}")
    print("name,us_per_call,derived")
    for line in lines:
        print(line)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
