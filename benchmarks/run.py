"""Benchmark driver — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  table1_*    runtime overhead of monitor vs tracer   (paper Table 1)
  table2_*    post-processing resources               (paper Table 2)
  tables67_*  weak/strong scaling-efficiency tables   (paper Tables 6/7)
  figure7_*   regression detect + explain             (paper Figure 7)
  roofline_*  §Roofline aggregation from the dry-run artifacts
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import overhead, postprocessing, regression, roofline, scaling_tables

    lines: list[str] = []
    failures = 0
    for mod in (overhead, postprocessing, scaling_tables, regression, roofline):
        name = mod.__name__.split(".")[-1]
        try:
            lines += mod.main()
        except Exception as e:
            failures += 1
            traceback.print_exc()
            lines.append(f"{name},0.0,FAILED:{type(e).__name__}:{e}")
    print("name,us_per_call,derived")
    for line in lines:
        print(line)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
