"""Tracer baseline vs monitor agreement (paper Tables 6/7 cross-tool check)
and report generation — all collection driven through ``repro.session``."""

import json
import os

import numpy as np
import pytest

from repro.core import (
    GLOBAL_REGION,
    ResourceConfig,
    StepProfile,
    generate_report,
    scan,
    trace_storage_bytes,
)
from repro.core import factors as F
from repro.session import PerfSession, SessionConfig


RES = ResourceConfig(num_hosts=2, devices_per_host=4)
PROFILE = StepProfile(
    num_devices=8, flops=1e12, hbm_bytes=1e10, collective_bytes_ici=1e8,
    model_flops=8e11, collective_counts={"all-reduce": 3, "all-gather": 2},
)


def clocked_session(backend, *, resources=RES, tmp_path=None, metadata=None, **kw):
    clock = [0.0]
    if backend == "tracer" and tmp_path is not None:
        kw.setdefault("trace_dir", str(tmp_path))
    ses = PerfSession(
        SessionConfig(app_name="x", backend=backend, clock=lambda: clock[0],
                      sync_regions=False, lb_sample_every=1,
                      respect_env=False, **kw),
        resources, metadata=metadata,
    )
    return ses, clock


def drive(ses, clock, steps=20):
    """Run the same synthetic workload through either backend."""
    for _ in range(steps):
        clock[0] += 0.01  # device work
        ses.observe_step(tokens_per_shard=[100, 90], expert_load=[5, 3, 2, 0])


def test_monitor_and_tracer_agree_on_factors(tmp_path):
    runs = {}
    for backend in ("monitor", "tracer"):
        ses, clock = clocked_session(backend, tmp_path=tmp_path / "trace")
        ses.attach_static("timestep", PROFILE)
        ses.start()
        with ses.region("timestep"):
            drive(ses, clock)
        runs[backend] = ses.finalize()

    a = runs["monitor"].regions["timestep"]
    b = runs["tracer"].regions["timestep"]
    assert a.measurements.num_steps == b.measurements.num_steps == 20
    np.testing.assert_allclose(a.measurements.data_lb, b.measurements.data_lb,
                               rtol=1e-6)
    np.testing.assert_allclose(a.measurements.expert_lb,
                               b.measurements.expert_lb, rtol=1e-6)
    assert a.counters.useful_flops == b.counters.useful_flops
    # the factor values the table would show agree
    for key in (F.DATA_LB, F.EXPERT_LB, F.COMM_EFF, F.ICI_COMM_EFF):
        np.testing.assert_allclose(a.pop[key], b.pop[key], rtol=1e-5)


def test_tracer_storage_scales_with_devices_and_steps(tmp_path):
    """The paper's Table 2 asymmetry by construction: trace storage grows
    with devices x steps, monitor JSON stays O(regions)."""

    def trace_size(ndev, steps):
        res = ResourceConfig(num_hosts=1, devices_per_host=ndev)
        d = str(tmp_path / f"t{ndev}_{steps}")
        ses, clock = clocked_session("tracer", resources=res, trace_dir=d)
        ses.attach_static("s", PROFILE)
        ses.start()
        with ses.region("s"):
            for _ in range(steps):
                clock[0] += 0.01
                ses.observe_step()
        ses.stop()  # close the event streams without post-processing
        return trace_storage_bytes(d)

    s1 = trace_size(2, 10)
    s2 = trace_size(4, 10)
    s3 = trace_size(2, 40)
    assert s2 > 1.8 * s1     # scales with devices
    assert s3 > 3.0 * s1     # scales with steps

    ses, _ = clocked_session("monitor")
    ses.start()
    with ses.region("s"):
        for _ in range(100):
            ses.observe_step()
    run = ses.finalize()
    run.save(tmp_path / "mon.json")
    assert os.path.getsize(tmp_path / "mon.json") < 16_000  # O(regions)


def _make_history(root, runs=4, slow_at=None):
    clock = [0.0]
    for i in range(runs):
        ses = PerfSession(
            SessionConfig(app_name="app", backend="monitor",
                          clock=lambda: clock[0], sync_regions=False,
                          lb_sample_every=1, respect_env=False),
            ResourceConfig(num_hosts=1, devices_per_host=8),
            metadata={
                "git_commit_short": f"c{i:02d}",
                "git_commit_timestamp": f"2026-07-{10+i:02d}T00:00:00",
            },
        )
        prof = PROFILE
        if slow_at is not None and i == slow_at:
            # remat bug: 2x executed flops
            prof = StepProfile(**{**PROFILE.to_json(), "flops": 2e12})
        ses.attach_static("timestep", prof)
        ses.start()
        with ses.region("timestep"):
            for _ in range(10):
                clock[0] += 0.02 if (slow_at is not None and i == slow_at) else 0.01
                ses.observe_step()
        run = ses.finalize()
        run.timestamp = f"2026-07-{10+i:02d}T01:00:00"
        run.save(os.path.join(root, "case1", "history", f"run_{i}.json"))


def test_report_generation_end_to_end(tmp_path):
    _make_history(str(tmp_path / "talp"), runs=4, slow_at=2)
    exps = scan(str(tmp_path / "talp"))
    assert len(exps) == 1
    out = str(tmp_path / "site")
    index = generate_report(exps, out, regions=["timestep"])
    html = open(index).read()
    assert "Scaling efficiency" in html
    assert "timestep" in html
    assert os.path.exists(os.path.join(out, "findings.json"))
    findings = json.load(open(os.path.join(out, "findings.json")))
    # the injected slowdown at commit c02 is detected and explained
    regressions = [f for f in findings if f["kind"] == "regression"]
    assert regressions, findings
    assert any("c02" == f["commit"] for f in regressions)
    explained = [f for f in regressions if f["commit"] == "c02"][0]
    assert "flop_scaling" in explained["explanation"] or \
           "throughput_scaling" in explained["explanation"]
    badges = [n for n in os.listdir(out) if n.startswith("badge_")]
    assert badges


def test_cli_ci_report_and_badge(tmp_path, capsys):
    from repro.core.pages import main

    _make_history(str(tmp_path / "talp"), runs=2)
    rc = main(["ci-report", "-i", str(tmp_path / "talp"), "-o",
               str(tmp_path / "site"), "--regions", "timestep",
               "--print-tables"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "Global efficiency" in out
    rc = main(["badge", "-i", str(tmp_path / "talp"), "-o",
               str(tmp_path / "b.svg")])
    assert rc == 0
    assert "<svg" in open(tmp_path / "b.svg").read()
    rc = main(["validate", "-i", str(tmp_path / "talp")])
    assert rc == 0


def test_cli_merge_history(tmp_path):
    from repro.core.pages import main

    _make_history(str(tmp_path / "old"), runs=2)
    _make_history(str(tmp_path / "new"), runs=1)
    rc = main(["merge-history", "--history", str(tmp_path / "old"),
               "--current", str(tmp_path / "new")])
    assert rc == 0
    exps = scan(str(tmp_path / "new"))
    assert len(exps[0].runs) == 2  # one merged + one current


def test_per_computation_breakdown_flows_to_report(tmp_path):
    """StepProfile.per_computation -> typed RegionRecord.computations ->
    rendered drill-down (schema v3: no metadata side-channel)."""
    import jax
    import jax.numpy as jnp

    from repro.core import ComputationCounters

    compiled = jax.jit(lambda a, b: jnp.tanh(a @ b).sum()).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
    ).compile()
    prof = StepProfile.from_compiled(compiled, num_devices=1)
    assert prof.per_computation  # the engine emitted a breakdown
    top = prof.top_computations(1)[0]
    assert isinstance(top, ComputationCounters) and top.hbm_bytes > 0

    ses = PerfSession(
        SessionConfig(app_name="bd", backend="monitor", sync_regions=False,
                      respect_env=False),
        ResourceConfig(num_hosts=1, devices_per_host=1),
    )
    with ses:
        with ses.region("train_step"):
            ses.observe_step()
        ses.attach_static("train_step", prof)
    run = ses.finalize()
    assert "per_computation" not in run.metadata  # side-channel is gone
    reg = run.regions["train_step"]
    assert reg.computations and top.name in reg.computations
    # Global inherits the child breakdown like it inherits counters
    assert run.global_region.computations
    # counters and their per-computation slice stay consistent
    assert reg.computations[top.name].hbm_bytes <= reg.counters.hlo_bytes
    run.save(os.path.join(tmp_path, "exp", "run_0.json"))

    exps = scan(str(tmp_path))
    # reloaded record carries the typed breakdown
    assert exps[0].runs[0].regions["train_step"].computations
    index = generate_report(exps, str(tmp_path / "site"))
    html = open(index).read()
    assert "HLO computation breakdown" in html
    assert "comps_exp" in html  # drill-down anchor exists


def test_tracer_postprocess_carries_computations(tmp_path):
    """The tracing baseline recovers the same typed breakdown (cross-tool
    agreement extends to schema v3)."""
    from repro.core import ComputationCounters

    prof = StepProfile(
        num_devices=8, flops=1e12, hbm_bytes=1e10,
        per_computation={
            "entry": ComputationCounters(name="entry", kind="entry",
                                         flops=1e12, hbm_bytes=1e10),
        },
    )
    ses, clock = clocked_session("tracer", tmp_path=tmp_path / "tr")
    ses.attach_static("s", prof)
    ses.start()
    with ses.region("s"):
        for _ in range(3):
            clock[0] += 0.01
            ses.observe_step()
    run = ses.finalize()
    comps = run.regions["s"].computations
    assert comps["entry"].flops == pytest.approx(3e12)  # scaled by steps
    # Global inherits the child breakdown, like the monitor
    assert run.regions[GLOBAL_REGION].computations["entry"].flops == pytest.approx(3e12)
