"""Per-architecture smoke tests (required deliverable f): reduced config of
the same family, one forward + one train step on CPU, asserting output
shapes and absence of NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, list_archs, smoke_config
from repro.layers.common import init_params
from repro.models import transformer as T
from repro.train.train import TrainConfig, init_state, make_train_step
from repro.launch.mesh import make_host_mesh

B, S = 2, 64


def _batch(cfg, accum=1):
    shape = (accum, B, S) if accum else (B, S)
    labels = jnp.where(
        jnp.arange(S)[None, :] % 5 == 0, -1,
        jnp.ones((B, S), jnp.int32),
    )
    if accum:
        labels = jnp.broadcast_to(labels[None], (accum, B, S))
    batch = {"labels": labels}
    if cfg.frontend == "audio":
        fe_shape = shape + (cfg.d_model,)
        batch["frontend"] = jnp.full(fe_shape, 0.01, jnp.bfloat16)
    elif cfg.frontend == "vlm":
        nf = cfg.n_frontend_tokens
        fe_shape = ((accum, B, nf, cfg.d_model) if accum else (B, nf, cfg.d_model))
        batch["frontend"] = jnp.full(fe_shape, 0.01, jnp.bfloat16)
        tshape = (accum, B, S - nf) if accum else (B, S - nf)
        batch["tokens"] = jnp.ones(tshape, jnp.int32)
    else:
        batch["tokens"] = jnp.ones(shape, jnp.int32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }[arch]
    L, d, hq, hkv, dff, vocab = spec
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == hq and cfg.n_kv_heads == hkv
    assert (cfg.moe.d_ff if cfg.moe else cfg.d_ff) == dff
    assert cfg.vocab == vocab
    if arch == "dbrx-132b":
        assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 4
    if arch == "qwen3-moe-30b-a3b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 8
    if arch == "zamba2-2.7b":
        assert cfg.ssm.d_state == 64


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    mesh = make_host_mesh()
    tcfg = TrainConfig()
    st = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    state = {"params": st.params, "opt_state": st.opt_state, "step": st.step}
    batch = _batch(cfg, accum=1)

    with mesh:
        logits, _ = jax.jit(lambda p, b: T.apply_logits(p, b, cfg))(
            state["params"], jax.tree_util.tree_map(lambda x: x[0], batch)
        )
        assert logits.shape == (B, S, cfg.vocab_padded)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

        step = jax.jit(make_train_step(cfg, mesh, tcfg))
        new_state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0.0 < loss < 50.0
    # the optimizer saw non-zero gradients (bf16 params may not change at
    # warmup-suppressed lr in one step)
    m0 = jax.tree_util.tree_leaves(new_state["opt_state"]["m"])[0]
    assert float(np.abs(np.asarray(m0, np.float32)).sum()) > 0.0
    assert int(new_state["step"]) == 1


def test_param_counts_are_plausible():
    """Full configs should land near their nameplate sizes."""
    expectations = {
        "tinyllama-1.1b": (0.9e9, 1.4e9),
        "gemma2-2b": (2.0e9, 3.3e9),
        "glm4-9b": (8e9, 11e9),
        "command-r-35b": (30e9, 40e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
        "dbrx-132b": (110e9, 140e9),
        "qwen3-moe-30b-a3b": (26e9, 34e9),
        "xlstm-350m": (0.25e9, 0.6e9),  # full qkv vs block-diag: +0.1B
        "zamba2-2.7b": (2.2e9, 3.4e9),
        "hubert-xlarge": (0.8e9, 1.2e9),
    }
    for arch, (lo, hi) in expectations.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_much_smaller_than_total():
    cfg = get_config("qwen3-moe-30b-a3b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()
