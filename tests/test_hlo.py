"""HLO-text cost analyzer: exactness on loop-free graphs, loop
multiplicities, collective classification."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.core import hlo as H


def test_shape_parsing():
    assert H.shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert H.shape_bytes("bf16[3]{0}") == 6
    assert H.shape_bytes("(f32[2,2], s8[4]{0})") == 16 + 4
    assert H.shape_bytes("pred[]") == 1
    assert H.shape_elems("f32[0]{0}") == 0
    # tuple with /*index=N*/ comments (the real-HLO format)
    t = "(s32[], bf16[16,256]{1,0}, /*index=5*/f32[4]{0})"
    assert H.shape_bytes(t) == 4 + 16 * 256 * 2 + 16


def test_instr_line_parser_handles_index_comments():
    line = ("  %while.485 = (s32[], bf16[16,256]{1,0}, /*index=5*/f32[4]{0}) "
            "while(%tuple.392), condition=%c, body=%b, "
            'backend_config={"known_trip_count":{"n":"22"}}')
    instr = H._parse_instr_line(line)
    assert instr is not None and instr.op == "while"
    assert H._trip_count(instr) == 22.0
    assert H._called_comps(instr) == ["b", "c"] or set(
        H._called_comps(instr)) == {"b", "c"}


def test_loop_free_dot_flops_match_xla():
    def f(a, b):
        return (a @ b).sum()

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    cost = H.analyze_hlo(compiled.as_text())
    assert cost.dot_flops == 2 * 128 * 256 * 512
    xla = H.xla_cost_analysis(compiled).get("flops", 0)
    assert abs(cost.flops - xla) / xla < 0.05


def test_scan_multiplies_by_trip_count():
    N = 7

    def f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, ws)[0].sum()

    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((N, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    cost = H.analyze_hlo(compiled.as_text())
    assert cost.dot_flops == N * 2 * 16 * 64 * 64
    assert cost.max_while_trip_count == N
    # XLA's own analysis undercounts while bodies — ours must exceed it
    xla = H.xla_cost_analysis(compiled).get("flops", 0)
    assert cost.flops > xla


def test_replica_group_iota_materialization():
    class FakeInstr:
        rest = "replica_groups=[4,2]<=[2,4]T(1,0), use_global_device_ids=true"
    groups = H.parse_replica_groups(FakeInstr())
    assert groups == [[0, 4], [1, 5], [2, 6], [3, 7]]

    class Explicit:
        rest = "replica_groups={{0,1},{2,3}}, bla"
    assert H.parse_replica_groups(Explicit()) == [[0, 1], [2, 3]]


def test_dcn_classification():
    # groups crossing the pod boundary (pod size 4)
    assert H.groups_cross_pod([[0, 4]], 4) is True
    assert H.groups_cross_pod([[0, 1, 2, 3]], 4) is False
    assert H.groups_cross_pod([[0, 1]], None) is False


def test_collective_cost_conventions():
    hlo = textwrap.dedent("""\
        HloModule m, num_partitions=4
        ENTRY %main (p: f32[8,8]) -> f32[8,8] {
          %p = f32[8,8]{1,0} parameter(0)
          %ag = f32[8,8]{1,0} all-gather(%p), replica_groups=[1,4]<=[4], dimensions={0}
          ROOT %ar = f32[8,8]{1,0} all-reduce(%ag), replica_groups=[1,4]<=[4], to_apply=%add
        }
    """)
    cost = H.analyze_hlo(hlo)
    kinds = {c.kind: c for c in cost.collectives}
    # all-gather: operand = result/group
    assert kinds["all-gather"].operand_bytes == 8 * 8 * 4 / 4
    # all-reduce: operand = result; ring wire = 2(g-1)/g * operand
    ar = kinds["all-reduce"]
    assert ar.operand_bytes == 8 * 8 * 4
    assert ar.wire_bytes == pytest.approx(2 * (3 / 4) * 8 * 8 * 4)


def test_fusion_bodies_do_not_double_count_bytes():
    def f(a):
        return jnp.tanh(a) * 2.0 + 1.0  # fuses into one kernel

    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    compiled = jax.jit(f).lower(a).compile()
    cost = H.analyze_hlo(compiled.as_text())
    nbytes = 1024 * 1024 * 4
    # in + out, allow some slack for copies
    assert nbytes * 1.5 <= cost.hbm_bytes <= nbytes * 4
