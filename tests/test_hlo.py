"""HLO-text cost analyzer: exactness on loop-free graphs, loop
multiplicities, collective classification, call-graph multiplicity
propagation and the parse/cost cache."""

import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core import hlo as H


def test_shape_parsing():
    assert H.shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert H.shape_bytes("bf16[3]{0}") == 6
    assert H.shape_bytes("(f32[2,2], s8[4]{0})") == 16 + 4
    assert H.shape_bytes("pred[]") == 1
    assert H.shape_elems("f32[0]{0}") == 0
    # tuple with /*index=N*/ comments (the real-HLO format)
    t = "(s32[], bf16[16,256]{1,0}, /*index=5*/f32[4]{0})"
    assert H.shape_bytes(t) == 4 + 16 * 256 * 2 + 16


def test_instr_line_parser_handles_index_comments():
    line = ("  %while.485 = (s32[], bf16[16,256]{1,0}, /*index=5*/f32[4]{0}) "
            "while(%tuple.392), condition=%c, body=%b, "
            'backend_config={"known_trip_count":{"n":"22"}}')
    instr = H._parse_instr_line(line)
    assert instr is not None and instr.op == "while"
    assert H._trip_count(instr) == 22.0
    assert H._called_comps(instr) == ["b", "c"] or set(
        H._called_comps(instr)) == {"b", "c"}


def test_loop_free_dot_flops_match_xla():
    def f(a, b):
        return (a @ b).sum()

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    cost = H.analyze_hlo(compiled.as_text())
    assert cost.dot_flops == 2 * 128 * 256 * 512
    xla = H.xla_cost_analysis(compiled).get("flops", 0)
    assert abs(cost.flops - xla) / xla < 0.05


def test_scan_multiplies_by_trip_count():
    N = 7

    def f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, ws)[0].sum()

    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((N, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    cost = H.analyze_hlo(compiled.as_text())
    assert cost.dot_flops == N * 2 * 16 * 64 * 64
    assert cost.max_while_trip_count == N
    # XLA's own analysis undercounts while bodies — ours must exceed it
    xla = H.xla_cost_analysis(compiled).get("flops", 0)
    assert cost.flops > xla


def test_replica_group_iota_materialization():
    class FakeInstr:
        rest = "replica_groups=[4,2]<=[2,4]T(1,0), use_global_device_ids=true"
    groups = H.parse_replica_groups(FakeInstr())
    assert groups == [[0, 4], [1, 5], [2, 6], [3, 7]]

    class Explicit:
        rest = "replica_groups={{0,1},{2,3}}, bla"
    assert H.parse_replica_groups(Explicit()) == [[0, 1], [2, 3]]


def test_dcn_classification():
    # groups crossing the pod boundary (pod size 4)
    assert H.groups_cross_pod([[0, 4]], 4) is True
    assert H.groups_cross_pod([[0, 1, 2, 3]], 4) is False
    assert H.groups_cross_pod([[0, 1]], None) is False


def test_collective_cost_conventions():
    hlo = textwrap.dedent("""\
        HloModule m, num_partitions=4
        ENTRY %main (p: f32[8,8]) -> f32[8,8] {
          %p = f32[8,8]{1,0} parameter(0)
          %ag = f32[8,8]{1,0} all-gather(%p), replica_groups=[1,4]<=[4], dimensions={0}
          ROOT %ar = f32[8,8]{1,0} all-reduce(%ag), replica_groups=[1,4]<=[4], to_apply=%add
        }
    """)
    cost = H.analyze_hlo(hlo)
    kinds = {c.kind: c for c in cost.collectives}
    # all-gather: operand = result/group
    assert kinds["all-gather"].operand_bytes == 8 * 8 * 4 / 4
    # all-reduce: operand = result; ring wire = 2(g-1)/g * operand
    ar = kinds["all-reduce"]
    assert ar.operand_bytes == 8 * 8 * 4
    assert ar.wire_bytes == pytest.approx(2 * (3 / 4) * 8 * 8 * 4)


def test_fusion_bodies_do_not_double_count_bytes():
    def f(a):
        return jnp.tanh(a) * 2.0 + 1.0  # fuses into one kernel

    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    compiled = jax.jit(f).lower(a).compile()
    cost = H.analyze_hlo(compiled.as_text())
    nbytes = 1024 * 1024 * 4
    # in + out, allow some slack for copies
    assert nbytes * 1.5 <= cost.hbm_bytes <= nbytes * 4


# ---------------------------------------------------------------------------
# call-graph correctness (the hbm_bytes=0.0 regression class)
# ---------------------------------------------------------------------------

# Hand-written module: ENTRY -> call -> while(trip_count=5) -> fusion.
# Exercises every multiplicity rule at once: call bodies count in full,
# while bodies multiply by the trip count, fusion bodies roll up.
_NESTED_HLO = textwrap.dedent("""\
    HloModule nested

    %fused_mul (fp: f32[16,16]) -> f32[16,16] {
      %fp = f32[16,16]{1,0} parameter(0)
      %fm = f32[16,16]{1,0} multiply(f32[16,16]{1,0} %fp, f32[16,16]{1,0} %fp)
      ROOT %fa = f32[16,16]{1,0} add(f32[16,16]{1,0} %fm, f32[16,16]{1,0} %fp)
    }

    %loop_body (bp: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
      %bp = (s32[], f32[16,16]{1,0}) parameter(0)
      %bi = s32[] get-tuple-element((s32[], f32[16,16]{1,0}) %bp), index=0
      %bx = f32[16,16]{1,0} get-tuple-element((s32[], f32[16,16]{1,0}) %bp), index=1
      %bone = s32[] constant(1)
      %binc = s32[] add(s32[] %bi, s32[] %bone)
      %bfus = f32[16,16]{1,0} fusion(f32[16,16]{1,0} %bx), kind=kLoop, calls=%fused_mul
      ROOT %btup = (s32[], f32[16,16]{1,0}) tuple(s32[] %binc, f32[16,16]{1,0} %bfus)
    }

    %loop_cond (cp: (s32[], f32[16,16])) -> pred[] {
      %cp = (s32[], f32[16,16]{1,0}) parameter(0)
      %ci = s32[] get-tuple-element((s32[], f32[16,16]{1,0}) %cp), index=0
      %cn = s32[] constant(5)
      ROOT %clt = pred[] compare(s32[] %ci, s32[] %cn), direction=LT
    }

    %called_body (kp: f32[16,16]) -> f32[16,16] {
      %kp = f32[16,16]{1,0} parameter(0)
      %kzero = s32[] constant(0)
      %ktup = (s32[], f32[16,16]{1,0}) tuple(s32[] %kzero, f32[16,16]{1,0} %kp)
      %kwhile = (s32[], f32[16,16]{1,0}) while((s32[], f32[16,16]{1,0}) %ktup), condition=%loop_cond, body=%loop_body, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %kout = f32[16,16]{1,0} get-tuple-element((s32[], f32[16,16]{1,0}) %kwhile), index=1
    }

    ENTRY %main (p: f32[16,16]) -> f32[16,16] {
      %p = f32[16,16]{1,0} parameter(0)
      ROOT %c = f32[16,16]{1,0} call(f32[16,16]{1,0} %p), to_apply=%called_body
    }
""")


def test_nested_call_while_fusion_byte_accounting():
    """Pinned hand-computed totals for nested call + while + fusion."""
    cost = H.analyze_hlo(_NESTED_HLO)
    S = 16 * 16 * 4  # one f32[16,16] buffer
    # loop_body x5: s32 add (4+4+4) + fusion site (in+out = 2S); tuple/gte free
    # loop_cond x5: pred compare (1+4+4)
    assert cost.hbm_bytes == 5 * (12 + 2 * S) + 5 * 9
    # fused elementwise: (256 mul + 256 add) x5; plus s32 add + compare x5
    assert cost.flops == 5 * (256 + 256) + 5 + 5
    assert cost.max_while_trip_count == 5
    assert cost.dot_flops == 0.0


def test_per_computation_breakdown_kinds_and_rollup():
    cost = H.analyze_hlo(_NESTED_HLO)
    pc = cost.per_computation
    assert pc["main"].kind == "entry" and pc["main"].multiplicity == 1.0
    assert pc["called_body"].kind == "called" and pc["called_body"].multiplicity == 1.0
    assert pc["loop_body"].kind == "while_body" and pc["loop_body"].multiplicity == 5.0
    assert pc["loop_cond"].kind == "while_cond" and pc["loop_cond"].multiplicity == 5.0
    assert pc["fused_mul"].kind == "fusion"
    # fusion bodies contribute FLOPs but never HBM (rolled into the call site)
    assert pc["fused_mul"].flops == 5 * 512 and pc["fused_mul"].hbm_bytes == 0.0
    # entry + call wrapper own no HBM traffic themselves here
    assert pc["main"].hbm_bytes == 0.0 and pc["called_body"].hbm_bytes == 0.0
    # the breakdown partitions the totals exactly
    assert sum(c.hbm_bytes for c in pc.values()) == cost.hbm_bytes
    assert sum(c.flops for c in pc.values()) == cost.flops
    top = cost.top_computations(1)[0]
    assert top.name == "loop_body"


def test_call_body_counted_from_real_xla_dump():
    """The exact seed regression: XLA's CPU backend wraps parallel fusions in
    an un-fused `call`; its body must contribute HBM traffic."""
    def f(a):
        return jnp.tanh(a) * 2.0 + 1.0

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    ).compile()
    text = compiled.as_text()
    cost = H.analyze_hlo(text)
    assert cost.flops > 0
    if "to_apply" in text and " call(" in text:
        called = [c for c in cost.per_computation.values() if c.kind == "called"]
        assert sum(c.hbm_bytes for c in called) > 0


def test_async_collective_done_not_double_counted():
    """-start carries the modeled cost; the -done half must contribute
    nothing (it previously fell through to generic HBM accounting)."""
    hlo = textwrap.dedent("""\
        HloModule async
        ENTRY %main (p: f32[8]) -> f32[8] {
          %p = f32[8]{0} parameter(0)
          %ars = f32[8]{0} all-reduce-start(f32[8]{0} %p), replica_groups={{0,1}}, to_apply=%add
          ROOT %ard = f32[8]{0} all-reduce-done(f32[8]{0} %ars)
        }
    """)
    cost = H.analyze_hlo(hlo)
    assert cost.hbm_bytes == 32 + 32          # operand + result, exactly once
    assert cost.collective_counts() == {"all-reduce": 1}
    assert "all-reduce-done" not in cost.op_counts
    assert cost.op_counts["all-reduce"] == 1.0


def test_shared_computation_multiplicity_sums_over_call_sites():
    hlo = textwrap.dedent("""\
        HloModule shared
        %work (wp: f32[8]) -> f32[8] {
          %wp = f32[8]{0} parameter(0)
          ROOT %wt = f32[8]{0} tanh(f32[8]{0} %wp)
        }
        ENTRY %main (p: f32[8]) -> f32[8] {
          %p = f32[8]{0} parameter(0)
          %c1 = f32[8]{0} call(f32[8]{0} %p), to_apply=%work
          ROOT %c2 = f32[8]{0} call(f32[8]{0} %c1), to_apply=%work
        }
    """)
    cost = H.analyze_hlo(hlo)
    assert cost.per_computation["work"].multiplicity == 2.0
    assert cost.flops == 2 * 8                 # tanh over 8 elems, twice
    assert cost.hbm_bytes == 2 * (32 + 32)     # in + out per execution


# ---------------------------------------------------------------------------
# parse/cost cache
# ---------------------------------------------------------------------------


def _big_module_text(n_comps: int = 150) -> str:
    from benchmarks.common import synthetic_call_chain_hlo

    return synthetic_call_chain_hlo(n_comps)


def test_analyze_hlo_cache_hit_is_5x_faster_and_identical():
    # distinct module names -> three independent cold parses; min-of-k on
    # both sides keeps the ratio assertion robust on loaded CI runners
    # (local margin is ~20-50x against the required 5x)
    texts = [
        _big_module_text().replace("HloModule call_chain", f"HloModule call_chain{i}")
        for i in range(3)
    ]
    H.clear_caches()
    t_cold = min(_timed(lambda t=t: H.analyze_hlo(t)) for t in texts)
    cold = H.analyze_hlo(texts[0])  # cached now
    t_warm = min(
        _timed(lambda: H.analyze_hlo(texts[0])) for _ in range(5)
    )
    warm = H.analyze_hlo(texts[0])
    assert warm.hbm_bytes == cold.hbm_bytes and warm.flops == cold.flops
    assert len(warm.per_computation) == len(cold.per_computation)
    assert t_cold >= 5 * t_warm, (t_cold, t_warm)


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def test_cached_result_is_isolated_from_caller_mutation():
    text = _big_module_text(10)
    H.clear_caches()
    first = H.analyze_hlo(text)
    first.hbm_bytes = -1.0
    first.per_computation.clear()
    second = H.analyze_hlo(text)
    assert second.hbm_bytes > 0 and second.per_computation
