"""Record schema v2 -> v3: typed ComputationCounters + loader migration.

Runs without optional deps (unlike test_records.py's hypothesis suite) —
the migration contract is the merge-history loop's load-bearing wall.
"""

import json

from repro.core import folder as FD
from repro.core.records import (
    GLOBAL_REGION,
    SCHEMA_VERSION,
    ComputationCounters,
    RegionCounters,
    RegionMeasurements,
    RegionRecord,
    ResourceConfig,
    RunRecord,
)


def make_run(ts="2026-07-13T10:00:00"):
    r = RunRecord(
        app_name="app",
        resources=ResourceConfig(num_hosts=1, devices_per_host=4),
        timestamp=ts,
    )
    r.regions[GLOBAL_REGION] = RegionRecord(
        name=GLOBAL_REGION,
        measurements=RegionMeasurements(elapsed_s=1.0, num_steps=5),
        counters=RegionCounters(useful_flops=1e9),
        pop={"parallel_efficiency": 0.9},
    )
    return r


def _v2_payload(comp_name="while_body.fusion.7", hbm=5e9):
    """A run record JSON exactly as the v2 monitor wrote it: per-computation
    breakdown only in the untyped metadata blob."""
    d = make_run().to_json()
    d["schema_version"] = 2
    for rd in d["regions"].values():
        rd.pop("computations", None)
    d["metadata"]["per_computation"] = {
        GLOBAL_REGION: [
            {"name": comp_name, "kind": "while_body", "multiplicity": 12,
             "num_instructions": 40, "flops": 1e9, "dot_flops": 8e8,
             "hbm_bytes": hbm, "collective_operand_bytes": 1e8},
        ]
    }
    return d


def test_computations_roundtrip_v3():
    run = make_run()
    run.global_region.computations["entry"] = ComputationCounters(
        name="entry", kind="entry", flops=2e9, hbm_bytes=3e9,
        collective_operand_bytes=1e7, multiplicity=1.0, num_instructions=9,
    )
    back = RunRecord.from_json(run.to_json())
    cc = back.global_region.computations["entry"]
    assert cc.name == "entry" and cc.kind == "entry"
    assert cc.flops == 2e9 and cc.hbm_bytes == 3e9
    assert back.schema_version == SCHEMA_VERSION == 3


def test_computation_counters_scaled():
    cc = ComputationCounters(name="c", flops=2.0, dot_flops=1.0,
                             hbm_bytes=4.0, collective_operand_bytes=8.0,
                             multiplicity=3.0, num_instructions=7)
    s = cc.scaled(10)
    assert (s.flops, s.dot_flops, s.hbm_bytes, s.collective_operand_bytes) == \
        (20.0, 10.0, 40.0, 80.0)
    # structural fields do not scale
    assert s.multiplicity == 3.0 and s.num_instructions == 7


def test_v2_metadata_blob_migrates_to_typed_computations():
    back = RunRecord.from_json(_v2_payload())
    assert "per_computation" not in back.metadata  # side-channel lifted
    cc = back.global_region.computations["while_body.fusion.7"]
    assert cc.kind == "while_body" and cc.hbm_bytes == 5e9
    assert cc.multiplicity == 12 and cc.num_instructions == 40
    # migrated record re-saves as v3
    assert RunRecord.from_json(back.to_json()).global_region.computations


def test_v1_record_without_blob_still_loads():
    d = make_run().to_json()
    d["schema_version"] = 1
    d["metadata"].pop("per_computation", None)
    back = RunRecord.from_json(d)
    assert back.global_region.computations == {}
    assert back.schema_version == SCHEMA_VERSION


def test_malformed_v2_blob_is_ignored_not_fatal():
    d = _v2_payload()
    d["metadata"]["per_computation"] = {"nonexistent_region": [{"name": "x"}],
                                        GLOBAL_REGION: "garbage"}
    back = RunRecord.from_json(d)  # must not raise
    assert back.global_region.computations == {}


def test_v2_and_v3_records_merge_in_one_experiment(tmp_path):
    """Acceptance criterion: v2 JSON records still load and merge with v3
    records in one experiment folder (the paper's merge-history loop)."""
    cur, hist = tmp_path / "cur", tmp_path / "hist"
    v3 = make_run(ts="2026-07-14T10:00:00")
    v3.global_region.computations["entry"] = ComputationCounters(
        name="entry", kind="entry", hbm_bytes=1e9)
    v3.save(cur / "exp" / "run_new.json")
    (hist / "exp").mkdir(parents=True)
    with open(hist / "exp" / "run_old.json", "w") as f:
        json.dump(_v2_payload(), f)
    assert FD.merge_history(str(hist), str(cur)) == 1
    exps = FD.scan(str(cur))
    assert len(exps) == 1 and len(exps[0].runs) == 2
    for run in exps[0].runs:
        assert run.global_region.computations  # both carry a typed breakdown
        assert run.schema_version == SCHEMA_VERSION
