"""POP factor hierarchy: identities, adaptation semantics, edge cases."""

import math

import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional test dependency (requirements-dev.txt); property tests skipped",
)
from hypothesis import given, settings, strategies as st

from repro.core import factors as F
from repro.core.hardware import TPU_V5E, TPU_V5P
from repro.core.records import (
    RegionCounters,
    RegionMeasurements,
    RegionRecord,
    ResourceConfig,
)


def region(flops=1e12, bytes_=1e11, ici=1e9, dcn=0.0, elapsed=10.0,
           device=9.0, data_lb=None, expert_lb=None, host_lb=None,
           in_pod=None, inter_pod=None, model_flops=0.0, steps=10):
    return RegionRecord(
        name="r",
        measurements=RegionMeasurements(
            elapsed_s=elapsed, num_visits=1, num_steps=steps,
            device_time_s=device, data_lb=data_lb, expert_lb=expert_lb,
            host_lb=host_lb, in_pod_lb=in_pod, inter_pod_lb=inter_pod,
        ),
        counters=RegionCounters(
            useful_flops=flops, hlo_bytes=bytes_,
            collective_bytes_ici=ici, collective_bytes_dcn=dcn,
            model_flops=model_flops,
        ),
    )


RES = ResourceConfig(num_hosts=4, devices_per_host=4)


nonneg = st.floats(min_value=0.0, max_value=1e18, allow_nan=False)
lb01 = st.one_of(st.none(), st.floats(min_value=1e-3, max_value=1.0))


@settings(max_examples=200, deadline=None)
@given(
    flops=nonneg, bytes_=nonneg, ici=nonneg, dcn=nonneg,
    elapsed=st.floats(min_value=1e-6, max_value=1e6),
    device=st.floats(min_value=0.0, max_value=1e6),
    data_lb=lb01, expert_lb=lb01, host_lb=lb01,
    overlap=st.floats(min_value=0.0, max_value=1.0),
)
def test_identities_hold_for_any_input(
    flops, bytes_, ici, dcn, elapsed, device, data_lb, expert_lb, host_lb, overlap
):
    r = region(flops, bytes_, ici, dcn, elapsed, device,
               data_lb, expert_lb, host_lb)
    pop = F.compute_pop(r, RES, TPU_V5E, overlap_fraction=overlap)
    assert F.validate_pop(pop) == []
    # efficiencies of the parallel branch live in [0, 1]
    for key in (F.PARALLEL_EFF, F.DISPATCH_EFF, F.COMM_EFF, F.ICI_COMM_EFF,
                F.DCN_COMM_EFF, F.LOAD_BALANCE):
        assert -1e-9 <= pop[key] <= 1.0 + 1e-9, (key, pop[key])


def test_comm_efficiency_splits_multiplicatively():
    r = region(flops=1e15, bytes_=1e12, ici=5e10, dcn=2e10)
    pop = F.absolute_factors(r, RES, TPU_V5E)
    assert pop[F.COMM_EFF] == pytest.approx(
        pop[F.ICI_COMM_EFF] * pop[F.DCN_COMM_EFF]
    )
    # more collective bytes => lower comm efficiency
    r2 = region(flops=1e15, bytes_=1e12, ici=5e11, dcn=2e10)
    pop2 = F.absolute_factors(r2, RES, TPU_V5E)
    assert pop2[F.COMM_EFF] < pop[F.COMM_EFF]


def test_no_collectives_means_perfect_comm_eff():
    pop = F.absolute_factors(region(ici=0.0, dcn=0.0), RES, TPU_V5E)
    assert pop[F.COMM_EFF] == 1.0


def test_overlap_fraction_raises_comm_eff():
    r = region(ici=1e11)
    e0 = F.absolute_factors(r, RES, TPU_V5E, overlap_fraction=0.0)[F.COMM_EFF]
    e5 = F.absolute_factors(r, RES, TPU_V5E, overlap_fraction=0.5)[F.COMM_EFF]
    e1 = F.absolute_factors(r, RES, TPU_V5E, overlap_fraction=1.0)[F.COMM_EFF]
    assert e0 < e5 < e1 == 1.0


def test_dispatch_efficiency_measures_host_stall():
    busy = F.absolute_factors(region(elapsed=10.0, device=10.0), RES, TPU_V5E)
    stalled = F.absolute_factors(region(elapsed=10.0, device=5.0), RES, TPU_V5E)
    assert busy[F.DISPATCH_EFF] == pytest.approx(1.0)
    assert stalled[F.DISPATCH_EFF] == pytest.approx(0.5)


def test_scaling_mode_detection_follows_paper_rule():
    # weak: flops per device constant
    runs = [
        (region(flops=1e12), ResourceConfig(1, 4)),
        (region(flops=2e12), ResourceConfig(2, 4)),
    ]
    assert F.detect_scaling_mode(runs) == F.WEAK
    # strong: total flops constant
    runs = [
        (region(flops=1e12), ResourceConfig(1, 4)),
        (region(flops=1.05e12), ResourceConfig(2, 4)),
    ]
    assert F.detect_scaling_mode(runs) == F.STRONG


def test_strong_scaling_flop_inflation_is_inefficiency():
    ref = (region(flops=1e12, device=10.0), ResourceConfig(1, 4))
    # doubled executed flops on the same problem => flop_scaling 0.5
    cur = region(flops=2e12, device=10.0)
    sc = F.scalability_factors(cur, ResourceConfig(2, 4), *ref, mode=F.STRONG)
    assert sc[F.FLOP_SCALING] == pytest.approx(0.5)
    # frequency scaling is identity on TPU
    assert sc[F.FREQUENCY_SCALING] == 1.0


def test_throughput_scaling_relative_flop_rate():
    ref_r = region(flops=1e12, device=10.0)   # 1e11/dev/s on 1x4
    cur = region(flops=1e12, device=2.5)      # on 2x4: 5e10... compute directly
    sc = F.scalability_factors(
        cur, ResourceConfig(2, 4), ref_r, ResourceConfig(1, 4), mode=F.STRONG
    )
    # cur: 1e12/(8*2.5)=5e10 ; ref: 1e12/(4*10)=2.5e10 -> 2x
    assert sc[F.THROUGHPUT_SCALING] == pytest.approx(2.0)


def test_spec_independence_of_measured_factors():
    """Hardware spec changes modeled comm terms but not measured LBs."""
    r = region(data_lb=0.9, expert_lb=0.8)
    a = F.absolute_factors(r, RES, TPU_V5E)
    b = F.absolute_factors(r, RES, TPU_V5P)
    assert a[F.DATA_LB] == b[F.DATA_LB] == 0.9
    assert a[F.EXPERT_LB] == b[F.EXPERT_LB] == 0.8
    assert a[F.COMM_EFF] != b[F.COMM_EFF]  # modeled: spec-dependent


def test_host_lb_split_composes():
    r = region(in_pod=0.9, inter_pod=0.8)
    pop = F.absolute_factors(r, RES, TPU_V5E)
    assert pop[F.HOST_LB] == pytest.approx(0.72)


def test_flop_usefulness_exposes_remat_waste():
    r = region(flops=4e12, model_flops=3e12)
    pop = F.absolute_factors(r, RES, TPU_V5E)
    assert pop[F.FLOP_USEFULNESS] == pytest.approx(0.75)


def test_tree_iteration_covers_display_names():
    for key, depth in F.iter_tree():
        assert key in F.DISPLAY_NAMES
        assert depth <= 4
