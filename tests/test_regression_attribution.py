"""Regression explanation descends below the factor leaf (schema v3):
a cost shift localized to one HLO computation is named in the Finding."""

import math

import pytest

from repro.core import factors as F
from repro.core import regression as R
from repro.core.records import (
    ComputationCounters,
    RegionCounters,
    RegionMeasurements,
    RegionRecord,
    ResourceConfig,
    RunRecord,
)
from repro.core.timeseries import build_series

HOT = "while_body.all_gather_fusion.3"


def _run(ts, elapsed, device_time, coll_ici, hot_coll, hot_hbm=1e9):
    """One synthetic run: two computations, all cost movement funnelled into
    the HOT one via the arguments."""
    run = RunRecord("app", ResourceConfig(num_hosts=1, devices_per_host=8), ts)
    reg = RegionRecord(
        name="timestep",
        measurements=RegionMeasurements(
            elapsed_s=elapsed, num_steps=10, device_time_s=device_time
        ),
        counters=RegionCounters(
            useful_flops=1e10, hlo_bytes=1e9 + hot_hbm,
            collective_bytes_ici=coll_ici,
        ),
        computations={
            HOT: ComputationCounters(
                name=HOT, kind="while_body", multiplicity=24,
                flops=1e9, hbm_bytes=hot_hbm, collective_operand_bytes=hot_coll,
            ),
            "entry": ComputationCounters(
                name="entry", kind="entry",
                flops=9e9, hbm_bytes=1e9, collective_operand_bytes=1e7,
            ),
        },
    )
    reg.pop = F.compute_pop(reg, run.resources, "tpu_v5e")
    run.regions["timestep"] = reg
    return run


def detect_single_series(runs):
    cs = build_series(runs)[0]
    return R.detect(cs.regions["timestep"], cs.label)


def test_localized_collective_regression_names_computation():
    """Acceptance criterion: a synthetic regression whose cost shift is
    localized to one HLO computation produces a Finding whose describe()
    names that computation."""
    runs = [
        _run("2026-07-01T00:00:00", 1.0, 0.95, coll_ici=2e8, hot_coll=1.9e8),
        _run("2026-07-02T00:00:00", 1.4, 1.30, coll_ici=2e9, hot_coll=1.99e9),
    ]
    findings = detect_single_series(runs)
    assert len(findings) == 1
    fd = findings[0]
    assert fd.kind == "regression"
    # factor walk reaches the communication branch...
    assert F.COMM_EFF in fd.explanation or F.ICI_COMM_EFF in fd.explanation
    # ...and the computation level pins the shifted computation
    assert fd.computations and fd.computations[0].name == HOT
    assert fd.computations[0].metric == "collective_operand_bytes"
    assert HOT in fd.describe()
    # serialization carries it (findings.json contract)
    assert fd.computations[0].to_json()["name"] == HOT


def test_attribution_without_factor_path_uses_best_metric():
    """Elapsed moves but no factor crosses the threshold: attribution still
    names the computation via the largest cross-metric share shift."""
    shifts = R.explain_computations(
        before={HOT: {"flops": 1e9, "hbm_bytes": 1e9, "collective_operand_bytes": 0.0},
                "entry": {"flops": 9e9, "hbm_bytes": 1e9, "collective_operand_bytes": 0.0}},
        after={HOT: {"flops": 1e9, "hbm_bytes": 4e9, "collective_operand_bytes": 0.0},
               "entry": {"flops": 9e9, "hbm_bytes": 1e9, "collective_operand_bytes": 0.0}},
    )
    assert shifts and shifts[0].name == HOT and shifts[0].metric == "hbm_bytes"
    assert shifts[0].rel_change == pytest.approx(3.0)


def test_attribution_ranks_by_share_not_relative_change():
    """A tiny computation with a huge relative jump must not outrank the
    computation that actually moved the region total."""
    before = {
        "big": {"flops": 0.0, "hbm_bytes": 1e10, "collective_operand_bytes": 0.0},
        "tiny": {"flops": 0.0, "hbm_bytes": 1e3, "collective_operand_bytes": 0.0},
    }
    after = {
        "big": {"flops": 0.0, "hbm_bytes": 2e10, "collective_operand_bytes": 0.0},
        "tiny": {"flops": 0.0, "hbm_bytes": 1e6, "collective_operand_bytes": 0.0},
    }
    shifts = R.explain_computations(before, after, metric="hbm_bytes")
    assert shifts[0].name == "big"
    # tiny's share shift (~5e-5) is below the significance floor
    assert all(s.name != "tiny" for s in shifts)


def test_new_computation_reported_as_new():
    """A computation absent before and too heavy (by the truncation rank
    metric) to have been below the cut is genuinely new."""
    shifts = R.explain_computations(
        before={"entry": {"flops": 1e9, "hbm_bytes": 1e9, "collective_operand_bytes": 0.0}},
        after={"entry": {"flops": 1e9, "hbm_bytes": 1e9, "collective_operand_bytes": 0.0},
               "all_gather.9": {"flops": 0.0, "hbm_bytes": 2e9,
                                "collective_operand_bytes": 5e8}},
        metric="collective_operand_bytes",
    )
    assert shifts and shifts[0].name == "all_gather.9"
    assert math.isinf(shifts[0].rel_change)
    assert "new" in shifts[0].describe()
    # inf must not leak into findings.json (invalid JSON token)
    assert shifts[0].to_json()["rel_change"] is None


def test_below_cut_computation_not_reported_as_new():
    """A computation absent from the (top-N truncated) before breakdown but
    smaller than before's smallest retained entry may simply have been below
    the cut — it must not be reported as a huge 'new' shift."""
    shifts = R.explain_computations(
        before={"big": {"flops": 0.0, "hbm_bytes": 1e10, "collective_operand_bytes": 0.0},
                "small": {"flops": 0.0, "hbm_bytes": 1e9, "collective_operand_bytes": 0.0}},
        after={"big": {"flops": 0.0, "hbm_bytes": 1e10, "collective_operand_bytes": 0.0},
               "small": {"flops": 0.0, "hbm_bytes": 1e9, "collective_operand_bytes": 0.0},
               "riser": {"flops": 0.0, "hbm_bytes": 9e8, "collective_operand_bytes": 0.0}},
        metric="hbm_bytes",
    )
    assert all(s.name != "riser" for s in shifts)


def test_one_sided_breakdown_yields_no_attribution():
    """Mixed-era folder: a pre-v3 point (no breakdown) next to a v3 point
    must not mark every computation 'new'."""
    comps = {"entry": {"flops": 1e9, "hbm_bytes": 1e9, "collective_operand_bytes": 0.0}}
    assert R.explain_computations({}, comps) == []
    assert R.explain_computations(comps, {}) == []


def test_timeseries_exposes_computation_series():
    runs = [
        _run("2026-07-01T00:00:00", 1.0, 0.95, coll_ici=2e8, hot_coll=1e8, hot_hbm=1e9),
        _run("2026-07-02T00:00:00", 1.0, 0.95, coll_ici=2e8, hot_coll=1e8, hot_hbm=3e9),
    ]
    cs = build_series(runs)[0]
    rs = cs.regions["timestep"]
    series = rs.computation_series("hbm_bytes")
    assert series[HOT] == [1e9, 3e9]
    assert rs.top_computation_names(1, "hbm_bytes") == [HOT]
    # a point missing the computation yields NaN (not a crash)
    rs.points[0].computations.pop(HOT)
    gaps = rs.computation_series("hbm_bytes")[HOT]
    assert math.isnan(gaps[0]) and gaps[1] == 3e9


def test_records_without_breakdown_yield_plain_findings():
    """v1/v2-era records (no computations) must keep detecting regressions
    with the factor-only explanation."""
    runs = [
        _run("2026-07-01T00:00:00", 1.0, 0.95, coll_ici=2e8, hot_coll=1.9e8),
        _run("2026-07-02T00:00:00", 1.4, 1.30, coll_ici=2e9, hot_coll=1.99e9),
    ]
    for run in runs:
        run.regions["timestep"].computations = {}
    findings = detect_single_series(runs)
    assert len(findings) == 1
    assert findings[0].computations == []
    assert "explained by" in findings[0].describe()
