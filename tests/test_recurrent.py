"""Chunkwise-parallel vs recurrent equivalence for Mamba2 SSD and xLSTM."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers import recurrent as R

KEY = jax.random.PRNGKey(3)


def test_ssd_chunked_matches_stepwise():
    b, s, h, p, n, chunk = 2, 64, 4, 8, 16, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B_ = jax.random.normal(ks[3], (b, s, 1, n))
    C_ = jax.random.normal(ks[4], (b, s, 1, n))

    y_chunk, state_chunk = R._ssd_chunked(x, dt, A, B_, C_, chunk)

    # stepwise recurrence oracle
    state = jnp.zeros((b, h, p, n))
    ys = []
    Bh = jnp.repeat(B_, h, axis=2)
    Ch = jnp.repeat(C_, h, axis=2)
    for t in range(s):
        dA = jnp.exp(dt[:, t] * A[None, :])  # (b,h)
        state = state * dA[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt[:, t], Bh[:, t], x[:, t]
        )
        ys.append(jnp.einsum("bhn,bhpn->bhp", Ch[:, t], state))
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state_chunk), np.asarray(state),
                               atol=1e-4, rtol=1e-4)


def test_ssd_chunk_size_invariance():
    b, s, h, p, n = 1, 48, 2, 4, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    B_ = jax.random.normal(ks[3], (b, s, 1, n))
    C_ = jax.random.normal(ks[4], (b, s, 1, n))
    y1, _ = R._ssd_chunked(x, dt, A, B_, C_, chunk=16)
    y2, _ = R._ssd_chunked(x, dt, A, B_, C_, chunk=48)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_mlstm_chunked_matches_stepwise():
    b, s, h, p, chunk = 1, 32, 2, 8, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, s, h, p))
    k = jax.random.normal(ks[1], (b, s, h, p))
    v = jax.random.normal(ks[2], (b, s, h, p))
    log_i = jax.random.normal(ks[3], (b, s, h)) * 0.5
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, s, h)) + 1.0)

    y_chunk, (C_c, n_c, m_c) = R._mlstm_chunked(q, k, v, log_i, log_f, chunk)

    # stepwise stabilized recurrence oracle
    import math
    C = jnp.zeros((b, h, p, p))
    n = jnp.zeros((b, h, p))
    m = jnp.full((b, h), -jnp.inf)
    ys = []
    for t in range(s):
        li, lf = log_i[:, t], log_f[:, t]
        m_new = jnp.maximum(lf + m, li)
        alpha = jnp.exp(lf + m - m_new)
        alpha = jnp.where(jnp.isinf(m)[..., None] if False else jnp.isneginf(m), 0.0, alpha)
        C = C * alpha[..., None, None] + jnp.exp(li - m_new)[..., None, None] * jnp.einsum(
            "bhp,bho->bhpo", k[:, t], v[:, t])
        n = n * alpha[..., None] + jnp.exp(li - m_new)[..., None] * k[:, t]
        qf = q[:, t] / math.sqrt(p)
        num = jnp.einsum("bhp,bhpo->bho", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", qf, n)), jnp.exp(-m_new))
        ys.append(num / den[..., None])
        m = m_new
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(m_c), np.asarray(m), atol=1e-5)


def test_causal_conv_state_carry():
    b, s, c, k = 2, 12, 6, 4
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (b, s, c))
    w = jax.random.normal(ks[1], (k, c))
    y_full, _ = R.causal_conv1d(x, w)
    # split into two halves with state carry
    y1, st = R.causal_conv1d(x[:, :7], w, state=jnp.zeros((b, k - 1, c)))
    y2, _ = R.causal_conv1d(x[:, 7:], w, state=st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y_full),
        atol=1e-5,
    )
