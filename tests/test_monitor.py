"""Monitor backend: on-the-fly accumulation semantics, driven through the
``repro.session`` facade (the only supported construction path since the
legacy ``repro.core.TalpMonitor`` alias was removed)."""

import numpy as np
import pytest

from repro.core import GLOBAL_REGION, ResourceConfig, StepProfile, validate_pop
from repro.session import PerfSession, SessionConfig


def clocked_session(resources=None, **kw):
    t = [0.0]

    def clock():
        return t[0]

    ses = PerfSession(
        SessionConfig(app_name="t", backend="monitor", clock=clock,
                      sync_regions=False, respect_env=False, **kw),
        resources or ResourceConfig(num_hosts=2, devices_per_host=4),
    )
    return ses, t


def test_global_region_implicit_and_elapsed():
    ses, t = clocked_session()
    ses.start()
    t[0] = 5.0
    ses.stop()
    run = ses.finalize()
    assert run.regions[GLOBAL_REGION].measurements.elapsed_s == 5.0
    assert run.regions[GLOBAL_REGION].measurements.num_visits == 1


def test_region_accumulates_over_visits():
    ses, t = clocked_session()
    ses.start()
    for _ in range(3):
        with ses.region("timestep"):
            t[0] += 2.0
        t[0] += 1.0
    run_region = ses.finalize().regions["timestep"]
    assert run_region.measurements.elapsed_s == pytest.approx(6.0)
    assert run_region.measurements.num_visits == 3


def test_nested_regions_both_counted():
    ses, t = clocked_session()
    ses.start()
    with ses.region("outer"):
        t[0] += 1.0
        with ses.region("inner"):
            t[0] += 2.0
        t[0] += 1.0
    run = ses.finalize()
    assert run.regions["outer"].measurements.elapsed_s == pytest.approx(4.0)
    assert run.regions["inner"].measurements.elapsed_s == pytest.approx(2.0)


def test_observe_step_counts_and_device_time():
    ses, t = clocked_session()
    ses.start()
    with ses.region("step"):
        for _ in range(4):
            t[0] += 0.5  # device work
            ses.observe_step()
            t[0] += 0.25  # host-only gap
            ses.mark_device()
    m = ses.finalize().regions["step"].measurements
    assert m.num_steps == 4
    assert m.device_time_s == pytest.approx(2.0)
    assert m.elapsed_s == pytest.approx(3.0)


def test_lb_accumulators_sample_every_step_when_configured():
    ses, t = clocked_session(lb_sample_every=1)
    ses.start()
    with ses.region("step"):
        ses.observe_step(tokens_per_shard=[100, 50], expert_load=[3, 1, 0, 0])
        ses.observe_step(tokens_per_shard=[100, 100])
    m = ses.finalize().regions["step"].measurements
    assert m.data_lb == pytest.approx((0.75 + 1.0) / 2)
    assert m.expert_lb == pytest.approx(1.0 / 3)


def test_host_times_split_in_pod_inter_pod():
    ses, t = clocked_session(
        resources=ResourceConfig(num_hosts=4, devices_per_host=2, num_pods=2),
        lb_sample_every=1,
    )
    ses.start()
    with ses.region("step"):
        # pods: [1.0, 1.0] and [1.0, 2.0] -> in-pod mean(1, 0.75), inter 2/3...
        ses.observe_step(host_times=[1.0, 1.0, 1.0, 2.0], pod_size=2)
    m = ses.finalize().regions["step"].measurements
    assert m.in_pod_lb == pytest.approx((1.0 + 0.75) / 2)
    assert m.inter_pod_lb == pytest.approx(((1.0 + 2.0) / 2) / 2.0)


def test_static_counters_scale_with_steps():
    ses, t = clocked_session()
    prof = StepProfile(num_devices=8, flops=100.0, hbm_bytes=10.0,
                       collective_bytes_ici=1.0, model_flops=80.0)
    ses.attach_static("step", prof)
    ses.start()
    with ses.region("step"):
        for _ in range(5):
            ses.observe_step()
    run = ses.finalize()
    c = run.regions["step"].counters
    assert c.useful_flops == 500.0
    assert c.model_flops == 400.0
    # Global inherits child counters
    assert run.global_region.counters.useful_flops == 500.0


def test_finalized_pop_validates():
    ses, t = clocked_session(lb_sample_every=1)
    prof = StepProfile(num_devices=8, flops=1e12, hbm_bytes=1e10,
                       collective_bytes_ici=1e8)
    ses.attach_static("step", prof)
    ses.start()
    with ses.region("step"):
        t[0] += 1.0
        ses.observe_step(tokens_per_shard=[5, 10])
    for reg in ses.finalize().regions.values():
        assert validate_pop(reg.pop) == []


def test_monitor_overhead_is_o1_memory():
    """State size must not grow with steps (TALP's core property)."""
    ses, t = clocked_session(lb_sample_every=1)
    ses.start()
    mon = ses.collector  # the monitor backend's accumulator state
    with ses.region("step"):
        ses.observe_step(tokens_per_shard=[1, 2])
    size_10 = len(mon._regions)
    with ses.region("step"):
        for _ in range(1000):
            ses.observe_step(tokens_per_shard=[1, 2])
    assert len(mon._regions) == size_10  # no per-step state
    st = mon._regions["step"]
    assert isinstance(st.data_lb.total, float)  # scalar accumulators only
