"""Dry-run machinery units (no 512-device compile here — that's the
launch-level sweep): shape specs, skip rules, batch-axis divisibility."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import (
    SHAPE_BY_NAME, SHAPES, effective_mode, get_config, list_archs, skip_reason,
)
from repro.data.pipeline import batch_specs
from repro.distributed import sharding as SH
from repro.launch.mesh import make_host_mesh


def test_40_cells_defined():
    assert len(list_archs()) == 10
    assert len(SHAPES) == 4


def test_skip_rules():
    enc = get_config("hubert-xlarge")
    assert skip_reason(enc, SHAPE_BY_NAME["decode_32k"]) is not None
    assert skip_reason(enc, SHAPE_BY_NAME["long_500k"]) is not None
    assert skip_reason(enc, SHAPE_BY_NAME["train_4k"]) is None
    assert effective_mode(enc, SHAPE_BY_NAME["prefill_32k"]) == "encoder"

    dense = get_config("tinyllama-1.1b")
    assert "full-attention" in skip_reason(dense, SHAPE_BY_NAME["long_500k"])

    for arch in ("zamba2-2.7b", "xlstm-350m"):
        assert skip_reason(get_config(arch), SHAPE_BY_NAME["long_500k"]) is None


def test_expected_cell_counts():
    """40 cells: count runnable vs skipped explicitly."""
    runnable = skipped = 0
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES:
            if skip_reason(cfg, shape):
                skipped += 1
            else:
                runnable += 1
    assert runnable + skipped == 40
    # 10 train + 10 prefill + 9 decode (hubert out) + 2 long (zamba, xlstm)
    assert runnable == 31
    assert skipped == 9


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "hubert-xlarge",
                                  "llava-next-mistral-7b"])
@pytest.mark.parametrize("mode", ["train", "prefill", "decode"])
def test_batch_specs_cover_every_model_input(arch, mode):
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME["train_4k"]
    specs = batch_specs(cfg, shape, mode)
    assert all(isinstance(v, jax.ShapeDtypeStruct) for v in specs.values())
    if mode == "train":
        assert specs["labels"].shape == (1, shape.global_batch, shape.seq_len)
        if cfg.frontend == "vlm":
            total = specs["frontend"].shape[2] + specs["tokens"].shape[2]
            assert total == shape.seq_len
    if mode == "decode":
        assert specs["tokens"].shape == (shape.global_batch, 1)


class _FakeDevices:
    def __init__(self, shape):
        self.shape = shape
        self.size = 1
        for s in shape:
            self.size *= s


class _FakeMesh:
    """Duck-typed mesh for sharding-rule tests (1 real device in-process)."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = _FakeDevices(shape)


def test_divisible_batch_axes():
    mesh = _FakeMesh((2, 2), ("data", "model"))
    assert SH.divisible_batch_axes(mesh, 4) == "data"
    assert SH.divisible_batch_axes(mesh, 1) is None
    assert SH.divisible_batch_axes(mesh, 3) is None
    mp = _FakeMesh((2, 4, 2), ("pod", "data", "model"))
    assert SH.divisible_batch_axes(mp, 16) == ("pod", "data")
    assert SH.divisible_batch_axes(mp, 2) == "pod"


def test_effective_strategy_fallback():
    mesh = make_host_mesh()  # 1 device: model axis = 1 -> all divisible
    assert SH.effective_strategy(get_config("tinyllama-1.1b"), mesh) == "megatron"
    assert SH.effective_strategy(get_config("gemma2-2b"), mesh) == "fsdp"


def test_shape_aware_pspec_backoff():
    from repro.layers.common import LogicalConstraints

    mesh = _FakeMesh((2, 2), ("data", "model"))
    lc = LogicalConstraints(mesh, {"batch": ("data", "model")})
    # divisible by 4 -> both axes
    assert lc.pspec_for((8, 3), "batch", None)[0] == ("data", "model")
    # divisible by 2 only -> back off to ("data",)
    assert lc.pspec_for((2, 3), "batch", None)[0] == "data"
    # not divisible -> replicated
    assert lc.pspec_for((3, 3), "batch", None)[0] is None


def test_vocab_padding_divisible_by_256():
    for arch in list_archs():
        cfg = get_config(arch)
        assert cfg.vocab_padded % 256 == 0
        assert cfg.vocab_padded >= cfg.vocab
