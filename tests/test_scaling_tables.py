"""Scaling-efficiency table construction rules (paper §Scaling-efficiency)."""

import pytest

from repro.core import factors as F
from repro.core import scaling as S
from repro.core.records import (
    GLOBAL_REGION,
    RegionCounters,
    RegionMeasurements,
    RegionRecord,
    ResourceConfig,
    RunRecord,
)


def run(hosts, devs, flops, ts="2026-07-13T10:00:00", device_s=10.0):
    r = RunRecord(
        app_name="a",
        resources=ResourceConfig(num_hosts=hosts, devices_per_host=devs),
        timestamp=ts,
    )
    r.regions[GLOBAL_REGION] = RegionRecord(
        name=GLOBAL_REGION,
        measurements=RegionMeasurements(
            elapsed_s=device_s * 1.1, num_steps=10, device_time_s=device_s
        ),
        counters=RegionCounters(useful_flops=flops, hlo_bytes=flops / 100,
                                collective_bytes_ici=flops / 1000),
    )
    return r


def test_latest_per_config_wins():
    runs = [
        run(1, 4, 1e12, ts="2026-07-01T00:00:00"),
        run(1, 4, 2e12, ts="2026-07-02T00:00:00"),
        run(2, 4, 1e12),
    ]
    latest = S.latest_per_config(runs)
    assert len(latest) == 2
    assert latest[0].regions[GLOBAL_REGION].counters.useful_flops == 2e12


def test_reference_is_least_resources():
    t = S.build_table([run(4, 4, 1e12), run(1, 4, 1e12), run(2, 4, 1e12)])
    assert t.columns[0].is_reference
    assert t.columns[0].label == "1x4"
    assert [c.label for c in t.columns] == ["1x4", "2x4", "4x4"]


def test_reference_column_has_identity_scalability():
    t = S.build_table([run(1, 4, 1e12), run(2, 4, 1.25e12)])
    ref = t.columns[0].pop
    assert ref[F.COMP_SCALABILITY] == pytest.approx(1.0)
    assert ref[F.FLOP_SCALING] == pytest.approx(1.0)
    # strong scaling: flop inflation 1.25x -> scaling 0.8
    assert t.columns[1].pop[F.FLOP_SCALING] == pytest.approx(0.8)
    assert t.mode == F.STRONG


def test_weak_scaling_uses_per_device_instructions():
    t = S.build_table([run(1, 4, 1e12), run(2, 4, 2.1e12)])
    assert t.mode == F.WEAK
    # per-device: ref 2.5e11, cur 2.625e11 -> 0.952
    assert t.columns[1].pop[F.FLOP_SCALING] == pytest.approx(
        2.5e11 / 2.625e11, rel=1e-6
    )


def test_global_efficiency_composes():
    t = S.build_table([run(1, 4, 1e12), run(2, 4, 1e12)])
    for c in t.columns:
        assert c.pop[F.GLOBAL_EFF] == pytest.approx(
            c.pop[F.PARALLEL_EFF] * c.pop[F.COMP_SCALABILITY]
        )


def test_missing_region_returns_none():
    assert S.build_table([run(1, 4, 1e12)], region="nope") is None


def test_render_text_contains_rows_and_mode():
    t = S.build_table([run(1, 4, 1e12), run(2, 4, 1e12)])
    txt = S.render_text(t)
    assert "Global efficiency" in txt
    assert "1x4" in txt and "2x4" in txt
    assert "strong" in txt


def test_table_is_order_invariant():
    runs = [run(2, 4, 1e12), run(1, 4, 1e12), run(4, 4, 1e12)]
    a = S.build_table(runs)
    b = S.build_table(list(reversed(runs)))
    assert [c.label for c in a.columns] == [c.label for c in b.columns]
    for ca, cb in zip(a.columns, b.columns):
        assert ca.pop == cb.pop
