"""Run-record schema: roundtrip, atomicity, folder conventions."""

import json
import os

import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional test dependency (requirements-dev.txt); property tests skipped",
)
from hypothesis import given, settings, strategies as st

from repro.core import folder as FD
from repro.core.records import (
    GLOBAL_REGION,
    RegionCounters,
    RegionMeasurements,
    RegionRecord,
    ResourceConfig,
    RunRecord,
)

finite = st.floats(min_value=0, max_value=1e15, allow_nan=False)


def make_run(label=(1, 4), ts="2026-07-13T10:00:00", app="app", **meta):
    r = RunRecord(
        app_name=app,
        resources=ResourceConfig(num_hosts=label[0], devices_per_host=label[1]),
        timestamp=ts,
        metadata=dict(meta),
    )
    r.regions[GLOBAL_REGION] = RegionRecord(
        name=GLOBAL_REGION,
        measurements=RegionMeasurements(elapsed_s=1.0, num_steps=5),
        counters=RegionCounters(useful_flops=1e9),
        pop={"parallel_efficiency": 0.9},
    )
    return r


@settings(max_examples=50, deadline=None)
@given(
    elapsed=finite, flops=finite, steps=st.integers(0, 10**9),
    data_lb=st.one_of(st.none(), st.floats(0, 1)),
)
def test_json_roundtrip(elapsed, flops, steps, data_lb):
    run = make_run()
    run.regions["timestep"] = RegionRecord(
        name="timestep",
        measurements=RegionMeasurements(
            elapsed_s=elapsed, num_steps=steps, data_lb=data_lb
        ),
        counters=RegionCounters(useful_flops=flops),
    )
    back = RunRecord.from_json(run.to_json())
    t = back.regions["timestep"]
    assert t.measurements.elapsed_s == elapsed
    assert t.measurements.num_steps == steps
    assert t.measurements.data_lb == data_lb
    assert t.counters.useful_flops == flops
    assert back.resources.label == run.resources.label


def test_save_is_atomic(tmp_path):
    run = make_run()
    path = tmp_path / "a" / "run.json"
    run.save(path)
    assert not os.path.exists(str(path) + ".tmp")
    assert RunRecord.load(path).app_name == "app"


def test_newer_schema_rejected():
    d = make_run().to_json()
    d["schema_version"] = 99
    with pytest.raises(ValueError):
        RunRecord.from_json(d)


def test_series_timestamp_prefers_git_commit_time():
    run = make_run(ts="2026-07-13T10:00:00",
                   git_commit_timestamp="2026-07-01T00:00:00")
    assert run.series_timestamp == "2026-07-01T00:00:00"
    assert make_run().series_timestamp == "2026-07-13T10:00:00"


def test_folder_scan_finds_experiments(tmp_path):
    make_run().save(tmp_path / "mesh1" / "strong" / "a.json")
    make_run().save(tmp_path / "mesh1" / "strong" / "b.json")
    make_run().save(tmp_path / "mesh2" / "weak" / "c.json")
    (tmp_path / "mesh2" / "empty").mkdir(parents=True)
    exps = FD.scan(str(tmp_path))
    assert sorted(e.rel_path for e in exps) == [
        os.path.join("mesh1", "strong"), os.path.join("mesh2", "weak")
    ]
    assert len(exps[0].runs) == 2


def test_folder_scan_tolerates_foreign_json(tmp_path):
    make_run().save(tmp_path / "exp" / "good.json")
    (tmp_path / "exp" / "bad.json").write_text("{not json")
    (tmp_path / "exp" / "other.json").write_text('{"foo": 1}')
    exps = FD.scan(str(tmp_path))
    # bad file skipped, "other" parses as empty run record
    assert len(exps) == 1 and len(exps[0].runs) >= 1


def test_merge_history_never_overwrites(tmp_path):
    cur, hist = tmp_path / "cur", tmp_path / "hist"
    make_run(app="new").save(cur / "exp" / "run1.json")
    make_run(app="old").save(hist / "exp" / "run1.json")
    make_run(app="old2").save(hist / "exp" / "run0.json")
    merged = FD.merge_history(str(hist), str(cur))
    assert merged == 1
    assert RunRecord.load(cur / "exp" / "run1.json").app_name == "new"
    assert RunRecord.load(cur / "exp" / "run0.json").app_name == "old2"


def test_add_metadata_is_idempotent_and_non_clobbering(tmp_path):
    make_run(git_commit="keepme").save(tmp_path / "e" / "r.json")
    n = FD.add_metadata(str(tmp_path), {"git_commit": "new", "ci": "yes"})
    assert n == 1
    run = RunRecord.load(tmp_path / "e" / "r.json")
    assert run.metadata["git_commit"] == "keepme"
    assert run.metadata["ci"] == "yes"
    assert FD.add_metadata(str(tmp_path), {"ci": "yes"}) == 0
