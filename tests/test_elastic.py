"""Elastic scaling: checkpoints restore across different mesh sizes.

These run in subprocesses because the forced host-device count must be set
before jax initializes (tests in this process stay single-device).
"""

import subprocess
import sys
import textwrap


def _run(ndev: int, code: str) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
        import sys
        sys.path.insert(0, {repr(sys.path[0] + "/../src")})
        sys.path.insert(0, "src")
    """) + textwrap.dedent(code)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs import smoke_config
from repro.layers.common import init_params, param_pspecs
from repro.models import transformer as T
from repro.distributed import sharding as SH
from repro.checkpoint import save_checkpoint, load_checkpoint
from jax.sharding import NamedSharding
cfg = smoke_config("tinyllama-1.1b")
mesh = compat.make_mesh(MESH_SHAPE, ("data", "model"))
pspecs = param_pspecs(T.model_params(cfg), SH.param_rules(cfg, mesh), mesh)
shardings = jax.tree_util.tree_map(lambda p: NamedSharding(mesh, p), pspecs,
    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
"""


def test_checkpoint_reshards_across_meshes(tmp_path):
    # save on 8 devices (4x2)
    _run(8, f"MESH_SHAPE=(4,2)\n{COMMON}" + f"""
params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
params = jax.tree_util.tree_map(jax.device_put, params, shardings)
save_checkpoint(params, {str(tmp_path)!r}, 1)
print("saved", sum(x.size for x in jax.tree_util.tree_leaves(params)))
""")
    # restore on 4 devices (2x2) with resharding, verify values
    out = _run(4, f"MESH_SHAPE=(2,2)\n{COMMON}" + f"""
template = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
restored, step = load_checkpoint(template, {str(tmp_path)!r}, shardings=shardings)
ok = all(
    np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    for a, b in zip(jax.tree_util.tree_leaves(template),
                    jax.tree_util.tree_leaves(restored))
)
shards = jax.tree_util.tree_leaves(restored)[0].sharding
print("restored step", step, "values_equal", ok, "ndev", len(jax.devices()))
""")
    assert "values_equal True" in out
    assert "ndev 4" in out


def test_train_state_survives_mesh_growth(tmp_path):
    """Shrink->grow: 4-device optimizer state restores on 8 devices and one
    further train step runs (the elastic-scaling end-to-end path)."""
    save = """
from repro.train.train import TrainConfig, init_state, make_train_step, train_state_pspecs
from repro.data.pipeline import DataConfig, SyntheticLM
tcfg = TrainConfig()
st = init_state(cfg, tcfg, jax.random.PRNGKey(0))
state = {"params": st.params, "opt_state": st.opt_state, "step": st.step}
data = SyntheticLM(DataConfig(global_batch=4, seq_len=32, vocab=cfg.vocab))
with compat.use_mesh(mesh):
    step = jax.jit(make_train_step(cfg, mesh, tcfg))
    state, _ = step(state, data.batch_at(0))
save_checkpoint(state, CKPT, 1)
print("saved")
"""
    _run(4, f"MESH_SHAPE=(2,2)\nCKPT={str(tmp_path)!r}\n{COMMON}{save}")
    out = _run(8, f"MESH_SHAPE=(4,2)\nCKPT={str(tmp_path)!r}\n{COMMON}" + """
from repro.train.train import TrainConfig, init_state, make_train_step, train_state_pspecs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed.elastic import reshard_state
tcfg = TrainConfig()
st = init_state(cfg, tcfg, jax.random.PRNGKey(0))
template = {"params": st.params, "opt_state": st.opt_state, "step": st.step}
sp = train_state_pspecs(cfg, mesh, tcfg)
sh = jax.tree_util.tree_map(lambda p: NamedSharding(mesh, p), sp,
    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
state, step_no = load_checkpoint(template, CKPT, shardings=sh)
data = SyntheticLM(DataConfig(global_batch=4, seq_len=32, vocab=cfg.vocab))
with compat.use_mesh(mesh):
    stepf = jax.jit(make_train_step(cfg, mesh, tcfg))
    state, metrics = stepf(state, data.batch_at(1))
import numpy as np
print("resumed_step", step_no, "loss", float(metrics["loss"]),
      "finite", bool(np.isfinite(float(metrics["loss"]))))
""")
    assert "resumed_step 1" in out and "finite True" in out


def test_host_lb_measured_on_multidevice_mesh():
    """Host load-balance observables flow end-to-end on a multi-device mesh."""
    out = _run(8, """
import jax, jax.numpy as jnp
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.train import TrainConfig
cfg = smoke_config("qwen3-moe-30b-a3b")
data = SyntheticLM(DataConfig(global_batch=4, seq_len=32, vocab=cfg.vocab,
                              pad_fraction=0.2))
loop = TrainLoop(cfg, make_host_mesh(model=2), TrainConfig(), data,
                 LoopConfig(steps=3, lb_sample_every=1))
loop.run()
run = loop.finalize_run()
m = run.regions["train_step"].measurements
print("steps", m.num_steps, "data_lb", m.data_lb, "expert_lb", m.expert_lb)
assert m.num_steps == 3 and m.data_lb is not None and m.expert_lb is not None
assert 0 < m.expert_lb <= 1.0
print("OK")
""")
    assert "OK" in out
