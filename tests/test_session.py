"""PerfSession — the single instrumentation surface (api redesign).

Covers: backend selection by config and by environment (the LD_PRELOAD
analogue), region as context manager and decorator, wrap_step profile
derivation and step counting, the null backend's zero-footprint contract,
monitor/tracer backend parity on the POP factors (the paper's Tables 6/7
cross-tool agreement check, as a unit test), one-call finalize into the CI
folder layout, top-level re-exports, and the legacy deprecation shims.
"""

import os
import warnings

import numpy as np
import pytest

import repro
from repro.core import factors as F
from repro.core.profile import StepProfile
from repro.core.records import GLOBAL_REGION, ResourceConfig, RunRecord
from repro.session import (
    ENV_BACKEND,
    ENV_ENABLE,
    ENV_OUT,
    NullCollector,
    PerfSession,
    SessionConfig,
    env_backend,
)

RES = ResourceConfig(num_hosts=2, devices_per_host=4)


def make_session(backend, tmp_path=None, metadata=None, **kw):
    """A clocked session immune to the ambient environment."""
    t = [0.0]
    cfg = SessionConfig(
        app_name="t", backend=backend, sync_regions=False, lb_sample_every=1,
        clock=lambda: t[0], respect_env=False,
        trace_dir=str(tmp_path / "trace") if tmp_path is not None else "",
        **kw,
    )
    return PerfSession(cfg, RES, metadata=metadata), t


# ---------------------------------------------------------------------------
# env activation — zero code change, the LD_PRELOAD analogue
# ---------------------------------------------------------------------------


def test_env_backend_resolution(monkeypatch):
    monkeypatch.delenv(ENV_ENABLE, raising=False)
    monkeypatch.delenv(ENV_BACKEND, raising=False)
    assert env_backend() is None
    monkeypatch.setenv(ENV_ENABLE, "1")
    assert env_backend() == "monitor"
    assert env_backend(default="tracer") == "tracer"
    monkeypatch.setenv(ENV_BACKEND, "tracer")
    assert env_backend() == "tracer"
    monkeypatch.setenv(ENV_ENABLE, "0")
    assert env_backend() == "null"
    monkeypatch.setenv(ENV_ENABLE, "1")
    monkeypatch.setenv(ENV_BACKEND, "bogus")
    with pytest.raises(ValueError):
        env_backend()


def test_env_enables_disabled_session(monkeypatch):
    monkeypatch.setenv(ENV_ENABLE, "1")
    monkeypatch.delenv(ENV_BACKEND, raising=False)
    ses = PerfSession()  # default config: backend="null"
    assert ses.enabled and ses.backend == "monitor"


def test_env_kill_switch_overrides_config(monkeypatch):
    monkeypatch.setenv(ENV_ENABLE, "0")
    ses = PerfSession(SessionConfig(backend="monitor"))
    assert not ses.enabled and isinstance(ses.collector, NullCollector)


def test_respect_env_false_ignores_environment(monkeypatch):
    monkeypatch.setenv(ENV_ENABLE, "1")
    ses = PerfSession(SessionConfig(backend="null", respect_env=False))
    assert not ses.enabled


# ---------------------------------------------------------------------------
# regions: context manager AND decorator
# ---------------------------------------------------------------------------


def test_region_context_manager_accumulates():
    ses, t = make_session("monitor")
    ses.start()
    for _ in range(3):
        with ses.region("r"):
            t[0] += 2.0
        t[0] += 1.0
    run = ses.finalize(git=False)
    assert run.regions["r"].measurements.elapsed_s == pytest.approx(6.0)
    assert run.regions["r"].measurements.num_visits == 3
    assert run.regions[GLOBAL_REGION].measurements.elapsed_s == pytest.approx(9.0)


def test_region_as_decorator():
    ses, t = make_session("monitor")
    ses.start()

    @ses.region("work")
    def work():
        t[0] += 0.5
        return 42

    assert work() == 42 and work() == 42
    run = ses.finalize(git=False)
    assert run.regions["work"].measurements.num_visits == 2
    assert run.regions["work"].measurements.elapsed_s == pytest.approx(1.0)


def test_null_region_is_shared_noop():
    ses, _ = make_session("null")
    r1, r2 = ses.region("a"), ses.region("b")
    assert r1 is r2  # one shared handle, no per-visit allocation
    with r1:
        pass
    fn = lambda: 1
    assert r1(fn) is fn  # decorator returns the function unchanged


# ---------------------------------------------------------------------------
# wrap_step
# ---------------------------------------------------------------------------


def test_wrap_step_null_returns_function_unchanged():
    ses, _ = make_session("null")
    fn = lambda x: x
    assert ses.wrap_step(fn, region="step") is fn
    assert ses.finalize() is None


def test_wrap_step_derives_profile_from_compiled_and_counts_steps():
    import jax
    import jax.numpy as jnp

    compiled = jax.jit(lambda a, b: jnp.tanh(a @ b).sum()).lower(
        jnp.ones((16, 16)), jnp.ones((16, 16))
    ).compile()
    ses, t = make_session("monitor")
    ses.start()
    step = ses.wrap_step(compiled, region="step", num_devices=1)
    for _ in range(4):
        t[0] += 0.1
        step(jnp.ones((16, 16)), jnp.ones((16, 16)))
    run = ses.finalize(git=False)
    reg = run.regions["step"]
    assert reg.measurements.num_steps == 4
    one_step = StepProfile.from_compiled(compiled, num_devices=1)
    assert reg.counters.useful_flops == pytest.approx(4 * one_step.flops)
    assert reg.computations  # schema-v3 breakdown flows through the facade


def test_wrap_step_lazily_lowers_jitted_functions():
    import jax
    import jax.numpy as jnp

    jitted = jax.jit(lambda a: (a @ a).sum())
    ses, t = make_session("monitor")
    ses.start()
    step = ses.wrap_step(jitted, region="step", derive=True, num_devices=1)
    for _ in range(3):
        step(jnp.ones((8, 8)))
    run = ses.finalize(git=False)
    reg = run.regions["step"]
    assert reg.measurements.num_steps == 3
    assert reg.counters.useful_flops > 0  # profile derived on first call


def test_wrap_step_observe_hook_feeds_load_balance():
    ses, t = make_session("monitor")
    ses.start()
    step = ses.wrap_step(
        lambda x: {"tokens_per_shard": [100, 50]},
        region="step",
    )
    step(None)
    run = ses.finalize(git=False)
    assert run.regions["step"].measurements.data_lb == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# backend parity — the paper's cross-tool agreement check (Tables 6/7)
# ---------------------------------------------------------------------------

PROFILE = StepProfile(
    num_devices=8, flops=1e12, hbm_bytes=1e10, collective_bytes_ici=1e8,
    model_flops=8e11, collective_counts={"all-reduce": 3, "all-gather": 2},
)


def _drive(ses, t, steps=20):
    """The same synthetic workload, whichever backend is plugged in."""
    ses.attach_static("timestep", PROFILE)
    ses.start()
    with ses.region("timestep"):
        for _ in range(steps):
            t[0] += 0.01
            ses.observe_step(
                tokens_per_shard=[100, 90], expert_load=[5, 3, 2, 0]
            )
    return ses.finalize(git=False)


def test_monitor_and_tracer_backends_agree_on_pop_factors(tmp_path):
    runs = {}
    for backend in ("monitor", "tracer"):
        ses, t = make_session(backend, tmp_path=tmp_path / backend)
        runs[backend] = _drive(ses, t)

    a = runs["monitor"].regions["timestep"]
    b = runs["tracer"].regions["timestep"]
    assert a.measurements.num_steps == b.measurements.num_steps == 20
    np.testing.assert_allclose(a.measurements.data_lb, b.measurements.data_lb,
                               rtol=1e-6)
    np.testing.assert_allclose(a.measurements.expert_lb,
                               b.measurements.expert_lb, rtol=1e-6)
    assert a.counters.useful_flops == b.counters.useful_flops
    for key in (F.DATA_LB, F.EXPERT_LB, F.COMM_EFF, F.ICI_COMM_EFF,
                F.PARALLEL_EFF):
        np.testing.assert_allclose(a.pop[key], b.pop[key], rtol=1e-5,
                                   err_msg=key)
    # both backends carry the same typed per-computation contract
    assert set(a.computations) == set(b.computations)


# ---------------------------------------------------------------------------
# finalize: git metadata + CI folder layout in one call
# ---------------------------------------------------------------------------


def test_finalize_saves_into_ci_folder_layout(tmp_path):
    ses, t = make_session("monitor")
    ses.start()
    with ses.region("r"):
        t[0] += 1.0
    run = ses.finalize(str(tmp_path / "talp" / "case" / "history"))
    assert run is not None and ses.last_record_path is not None
    reloaded = RunRecord.load(ses.last_record_path)
    assert reloaded.schema_version == 3
    assert reloaded.regions["r"].measurements.elapsed_s == pytest.approx(1.0)
    # the `talp metadata` step happened inside finalize (repo has git)
    assert "git_commit" in reloaded.metadata


def test_env_out_redirects_artifacts(tmp_path, monkeypatch):
    monkeypatch.delenv(ENV_ENABLE, raising=False)
    monkeypatch.setenv(ENV_OUT, str(tmp_path / "redirected"))
    t = [0.0]
    ses = PerfSession(
        SessionConfig(app_name="t", backend="monitor", clock=lambda: t[0]),
        RES,
    )
    ses.start()
    run = ses.finalize(str(tmp_path / "ignored"))
    assert run is not None
    assert ses.last_record_path.startswith(str(tmp_path / "redirected"))


def test_respect_env_false_never_writes_to_env_out(tmp_path, monkeypatch):
    """A benchmark/fixture session must not leak synthetic records into a
    globally exported TALP_OUT (it would corrupt the real CI history)."""
    monkeypatch.setenv(ENV_OUT, str(tmp_path / "ci_history"))
    ses, t = make_session("monitor")  # respect_env=False
    ses.start()
    run = ses.finalize(git=False)
    assert run is not None
    assert ses.last_record_path is None
    assert not (tmp_path / "ci_history").exists()


def test_tracer_finalize_without_start_yields_empty_valid_run(tmp_path):
    ses, _ = make_session("tracer", tmp_path=tmp_path)  # trace_dir configured
    run = ses.finalize(git=False)
    assert run is not None and run.regions[GLOBAL_REGION] is not None


def test_pre_start_hooks_are_safe_on_every_backend(tmp_path):
    """The zero-code-change backend swap means a program that is valid
    under one backend must not crash under another."""
    for backend in ("monitor", "tracer", "null"):
        ses, _ = make_session(backend, tmp_path=tmp_path / backend)
        ses.observe_step({"loss": 1.0})  # before start: silently ignored
        ses.mark_device()
        ses.attach_static("r", PROFILE)


def test_explicit_metadata_wins_over_git():
    ses, t = make_session("monitor", metadata={"git_commit_short": "cafe1234"})
    ses.start()
    run = ses.finalize()
    assert run.metadata["git_commit_short"] == "cafe1234"


# ---------------------------------------------------------------------------
# top-level re-exports + deprecation shims
# ---------------------------------------------------------------------------


def test_top_level_exports(monkeypatch):
    monkeypatch.delenv(ENV_ENABLE, raising=False)
    assert repro.PerfSession is PerfSession
    assert repro.SessionConfig is SessionConfig
    ses = repro.start("x")  # off unless the environment enables it
    assert isinstance(ses, PerfSession) and not ses.enabled
    import repro.session as session_mod

    assert repro.session is session_mod


def test_legacy_constructor_aliases_are_gone():
    """The one-release deprecation window (PR 3) is over: ``repro.core`` no
    longer exposes the collector constructors — PerfSession is the only way
    to build one."""
    import repro.core as core

    assert not hasattr(core, "TalpMonitor")
    assert not hasattr(core, "TraceRecorder")
    assert "TalpMonitor" not in core.__all__
    assert "TraceRecorder" not in core.__all__


def test_session_backends_do_not_warn(tmp_path):
    """The session backends construct the implementation classes directly —
    no deprecation noise from the supported path."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        ses, t = make_session("monitor")
        ses.start()
        ses.finalize(git=False)
        ses2, _ = make_session("tracer", tmp_path=tmp_path)
        ses2.start()
        ses2.finalize(git=False)
