"""Fault injection + self-healing: seeded chaos schedules, and recovery
that is bitwise invisible to every surviving stream."""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.layers.common import init_params
from repro.models import transformer as T
from repro.launch.mesh import make_host_mesh
from repro.serve.faults import (
    FaultConfig,
    FaultEvent,
    FaultInjector,
    generate_faults,
    page_edit_step,
    page_fingerprint_step,
)
from repro.serve.serve import BatchScheduler, ServeConfig


# ---------------------------------------------------------------------------
# schedule + injector units (no model)
# ---------------------------------------------------------------------------


def test_fault_schedule_pure_function_of_config():
    fcfg = FaultConfig(seed=7, n_nan=3, n_page_corrupt=2, n_alloc_spike=2,
                       n_hang=1)
    a, b = generate_faults(fcfg), generate_faults(fcfg)
    assert a == b, "same config must generate the same schedule bit-for-bit"
    assert len(a) == 8
    assert a != generate_faults(dataclasses.replace(fcfg, seed=8))
    kinds = {e.kind for e in a}
    assert kinds == {"nan", "page_corrupt", "alloc_spike", "hang"}
    assert all(1 <= e.tick <= fcfg.horizon_ticks for e in a)


def test_invalid_fault_configs_rejected():
    with pytest.raises(ValueError, match="corrupt_mode"):
        FaultConfig(corrupt_mode="flip")
    with pytest.raises(ValueError, match="horizon"):
        FaultConfig(horizon_ticks=0)


def test_injector_due_and_defer():
    events = [FaultEvent(kind="nan", tick=2), FaultEvent(kind="hang", tick=5)]
    inj = FaultInjector(events=events)
    assert inj.due(1) == []
    ready = inj.due(3)
    assert [e.kind for e in ready] == ["nan"]
    # no applicable target: the event comes due again next tick, counted
    inj.defer(ready[0], 3)
    assert inj.counters["deferrals"] == 1
    assert [e.kind for e in inj.due(4)] == ["nan"]
    assert not inj.exhausted
    assert [e.kind for e in inj.due(10)] == ["hang"]
    assert inj.exhausted
    inj.record("alloc_spike")
    assert inj.counters["alloc_spikes"] == 1


# ---------------------------------------------------------------------------
# device-side page edits + fingerprints (tiny synthetic pool)
# ---------------------------------------------------------------------------


def _tiny_caches():
    # mimics the paged-pool pytree shape: the paged leaves carry "pages" in
    # their path, others must pass through edits untouched
    k = jnp.arange(2 * 4 * 8 * 2 * 4, dtype=jnp.float32).reshape(2, 4, 8, 2, 4)
    return {"pages_k": k, "pages_v": k + 1.0, "state": jnp.ones((3, 3))}


def test_page_edit_nan_zero_and_bitflip_roundtrip():
    caches = _tiny_caches()
    ref = jax.tree_util.tree_map(lambda x: np.asarray(x), caches)
    nan_ed = page_edit_step("nan")(jax.tree_util.tree_map(jnp.copy, caches), 2)
    assert np.all(np.isnan(np.asarray(nan_ed["pages_k"])[:, 2]))
    np.testing.assert_array_equal(np.asarray(nan_ed["pages_k"])[:, 1],
                                  ref["pages_k"][:, 1])
    np.testing.assert_array_equal(np.asarray(nan_ed["state"]), ref["state"])
    zeroed = page_edit_step("zero")(nan_ed, 2)
    assert np.all(np.asarray(zeroed["pages_k"])[:, 2] == 0)
    # bitflip is an XOR: applying it twice restores the page exactly
    once = page_edit_step("bitflip")(
        jax.tree_util.tree_map(jnp.copy, caches), 1
    )
    assert not np.array_equal(np.asarray(once["pages_v"])[:, 1],
                              ref["pages_v"][:, 1])
    twice = page_edit_step("bitflip")(once, 1)
    np.testing.assert_array_equal(np.asarray(twice["pages_k"]),
                                  ref["pages_k"])


def test_page_fingerprint_moves_on_any_edit():
    caches = _tiny_caches()
    fp = page_fingerprint_step()
    base = int(fp(caches, 1))
    assert int(fp(caches, 1)) == base, "fingerprint must be deterministic"
    assert int(fp(caches, 2)) != base
    flipped = page_edit_step("bitflip")(
        jax.tree_util.tree_map(jnp.copy, caches), 1
    )
    assert int(fp(flipped, 1)) != base, "a bit flip must move the checksum"


# ---------------------------------------------------------------------------
# scheduler-level recovery: identity, quarantine, watchdog, spike, shed,
# checksum validation (tinyllama smoke in f32 — scheduler logic, not argmax
# near-ties, must decide every comparison)
# ---------------------------------------------------------------------------


@functools.cache
def _fixtures(arch="tinyllama-1.1b"):
    cfg = smoke_config(arch).replace(
        compute_dtype_name="float32", param_dtype_name="float32"
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, mesh, params


def _chaos_run(cfg, mesh, params, *, events=None, fcfg=None, greedy=True,
               prompts=None, max_new=6, **scfg_kw):
    injector = None
    if events is not None or fcfg is not None:
        injector = FaultInjector(fcfg, events=events)
    kw = dict(max_len=64, batch=2, prefill_chunk=4, paged=True, page_size=8,
              num_pages=16, watchdog_deadline_s=0.05)
    if not greedy:
        kw.update(greedy=False, temperature=0.8, top_k=20, sample_seed=3)
    kw.update(scfg_kw)
    prompts = prompts or [list(range(4, 14)), list(range(30, 38))]
    with mesh:
        sched = BatchScheduler(cfg, mesh, ServeConfig(**kw), params,
                               fault_injector=injector)
        for rid, p in enumerate(prompts):
            sched.submit(p, request_id=rid, max_new=max_new)
        sched.drain()
    return sched, injector


def _tokens(sched):
    return {r["id"]: r["generated"] for r in sched.completed}


@pytest.mark.parametrize("greedy", [True, False])
def test_nan_retry_stream_identity(greedy):
    """A poisoned decode dispatch must be invisible in the output: the
    victim retries through recompute-resume and every stream — victim and
    neighbor — is bitwise identical to the unfaulted run, greedy AND
    sampled."""
    cfg, mesh, params = _fixtures()
    base, _ = _chaos_run(cfg, mesh, params, greedy=greedy)
    events = [FaultEvent(kind="nan", tick=4), FaultEvent(kind="nan", tick=9)]
    chaos, inj = _chaos_run(cfg, mesh, params, events=events, greedy=greedy)
    assert inj.counters["nan_injected"] == 2
    assert chaos.stats["retries"] >= 1
    assert chaos.stats["backoff_total_ticks"] >= chaos.stats["retries"]
    assert _tokens(chaos) == _tokens(base)
    assert chaos._alloc.used == 0, "pages leaked across fault retries"


def test_quarantine_frees_pages_neighbors_untouched():
    """Retries exhausted: exactly the pinned victim ends terminal
    ``failed`` with its pages freed; its co-resident's stream is bitwise
    unchanged and nothing leaks."""
    cfg, mesh, params = _fixtures()
    base, _ = _chaos_run(cfg, mesh, params, max_retries=2)
    events = [FaultEvent(kind="nan", tick=3 + 3 * i, request_id=0)
              for i in range(3)]
    quar, inj = _chaos_run(cfg, mesh, params, events=events, max_retries=2)
    assert inj.counters["nan_injected"] == 3
    assert [r["id"] for r in quar.failed] == [0]
    assert quar.failed[0]["_status"] == "failed"
    assert quar.stats["quarantined"] == 1
    assert _tokens(quar) == {k: v for k, v in _tokens(base).items() if k != 0}
    assert quar._alloc.used == 0, "quarantine leaked pages"


def test_watchdog_trip_and_alloc_spike_recover():
    """A hung dispatch trips the watchdog and the victim retries; a
    transient allocator spike parks work through the normal pressure path
    — both recover to the exact unfaulted streams."""
    cfg, mesh, params = _fixtures()
    base, _ = _chaos_run(cfg, mesh, params, num_pages=6)
    fcfg = FaultConfig(hang_s=0.2, spike_pages=2, spike_ticks=3)
    events = [FaultEvent(kind="hang", tick=4),
              FaultEvent(kind="alloc_spike", tick=6)]
    chaos, inj = _chaos_run(cfg, mesh, params, events=events, fcfg=fcfg,
                            num_pages=6)
    assert inj.counters["hangs"] == 1 and inj.counters["alloc_spikes"] == 1
    assert chaos.stats["watchdog_trips"] >= 1
    assert not chaos._spike_holds, "spike pages not released"
    assert _tokens(chaos) == _tokens(base)
    assert chaos._alloc.used == 0


def test_shed_queue_depth_drops_lowest_priority_youngest():
    """Admission past ``shed_queue_depth`` sheds the lowest-priority
    youngest waiter with a terminal ``shed`` status — the handle reports
    it, nothing raises, and survivors complete normally."""
    cfg, mesh, params = _fixtures()
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=1, prefill_chunk=4, paged=True,
                        page_size=8, num_pages=16, shed_queue_depth=2),
            params,
        )
        handles = [
            sched.submit(list(range(4 + 3 * i, 10 + 3 * i)), request_id=i,
                         max_new=3, priority=(1 if i == 2 else 0))
            for i in range(4)
        ]
        sched.drain()
    shed_ids = [r["id"] for r in sched.shed]
    assert sched.stats["shed"] == len(shed_ids) > 0
    # the priority-1 arrival must never be the one shed
    assert 2 not in shed_ids
    for h in handles:
        assert h.done
        if h.request_id in shed_ids:
            assert h.status == "shed" and h.tokens == []
        else:
            assert h.status == "done" and len(h.tokens) == 3
    assert sched._alloc.used == 0


def test_checksum_catches_bitflip_and_evicts_subtree():
    """A silent bit flip in a trie-cached page stays finite — only the
    per-page checksum at prefix-share time can catch it. The corrupted
    subtree is evicted, the request re-prefills from scratch, and its
    stream matches the donor's bit-for-bit."""
    cfg, mesh, params = _fixtures()
    prompt = list(range(4, 22))  # 2 full pages land in the trie
    events = [FaultEvent(kind="page_corrupt", tick=40)]
    injector = FaultInjector(FaultConfig(corrupt_mode="bitflip"),
                             events=events)
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4, paged=True,
                        page_size=8, num_pages=16, prefix_cache=True,
                        checksum_pages=True),
            params, fault_injector=injector,
        )
        first = sched.submit(prompt, request_id="a", max_new=4).result()
        # idle past the event tick: the corruption lands on a page only the
        # trie still pins (finite garbage, invisible to the NaN sentinel)
        while not injector.exhausted:
            sched.step()
        assert injector.counters["pages_corrupted"] == 1
        second = sched.submit(prompt, request_id="b", max_new=4).result()
        sched.drain()
    assert sched.stats["checksum_failures"] >= 1
    assert second == first, "post-eviction re-prefill changed the stream"
    assert sched._alloc.used - sched._prefix.size == 0


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "zamba2-2.7b"])
def test_fault_isolation_coresident(arch):
    """Satellite isolation on attention-only AND hybrid stacks: NaN poison
    plus a NaN page corruption pinned to one request of a full batch leave
    the neighbor's stream bitwise unchanged, with zero leaks."""
    cfg, mesh, params = _fixtures(arch)
    base, _ = _chaos_run(cfg, mesh, params, max_new=5)
    events = [FaultEvent(kind="nan", tick=5, request_id=0),
              FaultEvent(kind="page_corrupt", tick=7, request_id=0)]
    chaos, inj = _chaos_run(cfg, mesh, params, events=events, max_new=5)
    assert inj.counters["nan_injected"] == 1
    # a pinned page corruption needs an unshared page of request 0's slot;
    # it may defer off the run's end on some grids, but must never touch
    # the neighbor when it lands
    assert _tokens(chaos) == _tokens(base)
    assert chaos._alloc.used == 0, "pages leaked under co-resident faults"
