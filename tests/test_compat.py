"""JAX version-compat layer: both sides of every feature-detected shim are
exercised by monkeypatching the detection flags — the suite stays meaningful
no matter which JAX the CI host pins — plus the repo-wide policy check that
version-gated attribute access lives only in compat.py."""

import contextlib
import pathlib
import re

import jax
import pytest

from repro import compat


# ---------------------------------------------------------------------------
# make_mesh across API generations
# ---------------------------------------------------------------------------


def _mesh_fingerprint(mesh):
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape))


def test_make_mesh_old_api_omits_axis_types(monkeypatch):
    real = jax.make_mesh
    seen = {}

    def fake(shape, names, **kw):
        seen["kw"] = dict(kw)
        return real(shape, names, **kw)

    monkeypatch.setattr(jax, "make_mesh", fake)
    monkeypatch.setattr(compat, "MAKE_MESH_HAS_AXIS_TYPES", False)
    monkeypatch.setattr(compat, "HAS_AXIS_TYPES", False)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert "axis_types" not in seen["kw"]
    assert _mesh_fingerprint(mesh) == (("data", "model"), (1, 1))


def test_make_mesh_new_api_passes_auto_axis_types(monkeypatch):
    real = jax.make_mesh
    sentinel = object()
    seen = {}

    def fake(shape, names, *, axis_types=None, **kw):
        seen["axis_types"] = axis_types
        return real(shape, names, **kw)

    monkeypatch.setattr(jax, "make_mesh", fake)
    monkeypatch.setattr(compat, "MAKE_MESH_HAS_AXIS_TYPES", True)
    monkeypatch.setattr(compat, "HAS_AXIS_TYPES", True)
    monkeypatch.setattr(compat, "AXIS_TYPE_AUTO", sentinel)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert seen["axis_types"] == (sentinel, sentinel)
    assert _mesh_fingerprint(mesh) == (("data", "model"), (1, 1))


def test_make_mesh_old_and_new_paths_build_identical_mesh(monkeypatch):
    real = jax.make_mesh

    monkeypatch.setattr(compat, "MAKE_MESH_HAS_AXIS_TYPES", False)
    monkeypatch.setattr(compat, "HAS_AXIS_TYPES", False)
    old = compat.make_mesh((1, 1), ("data", "model"))

    monkeypatch.setattr(jax, "make_mesh",
                        lambda shape, names, *, axis_types=None, **kw: real(shape, names, **kw))
    monkeypatch.setattr(compat, "MAKE_MESH_HAS_AXIS_TYPES", True)
    monkeypatch.setattr(compat, "HAS_AXIS_TYPES", True)
    monkeypatch.setattr(compat, "AXIS_TYPE_AUTO", object())
    new = compat.make_mesh((1, 1), ("data", "model"))

    assert _mesh_fingerprint(old) == _mesh_fingerprint(new)
    assert [d.id for d in old.devices.flat] == [d.id for d in new.devices.flat]


def test_make_mesh_without_jax_make_mesh_falls_back(monkeypatch):
    monkeypatch.delattr(jax, "make_mesh")
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert _mesh_fingerprint(mesh) == (("data", "model"), (1, 1))


# ---------------------------------------------------------------------------
# use_mesh
# ---------------------------------------------------------------------------


def test_use_mesh_falls_back_to_mesh_context(monkeypatch):
    monkeypatch.setattr(jax.sharding, "use_mesh", None, raising=False)
    monkeypatch.setattr(jax, "set_mesh", None, raising=False)
    events = []

    class FakeMesh:
        def __enter__(self):
            events.append("enter")
            return self

        def __exit__(self, *exc):
            events.append("exit")
            return False

    with compat.use_mesh(FakeMesh()):
        assert events == ["enter"]
    assert events == ["enter", "exit"]


def test_use_mesh_prefers_new_api(monkeypatch):
    used = []

    @contextlib.contextmanager
    def fake_use_mesh(mesh):
        used.append(mesh)
        yield mesh

    monkeypatch.setattr(jax.sharding, "use_mesh", fake_use_mesh, raising=False)
    mesh = object()  # never entered directly -> no __enter__ needed
    with compat.use_mesh(mesh) as m:
        assert m is mesh
    assert used == [mesh]


def test_use_mesh_does_not_swallow_body_exceptions(monkeypatch):
    monkeypatch.setattr(jax.sharding, "use_mesh", None, raising=False)
    monkeypatch.setattr(jax, "set_mesh", None, raising=False)

    class FakeMesh:
        def __enter__(self):
            return self

        def __exit__(self, *exc):
            return False

    with pytest.raises(TypeError, match="from the body"):
        with compat.use_mesh(FakeMesh()):
            raise TypeError("from the body")


# ---------------------------------------------------------------------------
# compiled-executable accessors
# ---------------------------------------------------------------------------


def test_cost_analysis_normalizes_old_list_format():
    class C:
        def cost_analysis(self):
            return [{"flops": 3, "utilization": "n/a"}]

    assert compat.cost_analysis(C()) == {"flops": 3.0}


def test_cost_analysis_normalizes_dict_and_errors():
    class D:
        def cost_analysis(self):
            return {"flops": 5.0, "bytes accessed": 7}

    class E:
        def cost_analysis(self):
            raise RuntimeError("unsupported backend")

    assert compat.cost_analysis(D()) == {"flops": 5.0, "bytes accessed": 7.0}
    assert compat.cost_analysis(E()) == {}


def test_memory_stats_normalizes_and_survives_absence():
    class MS:
        argument_size_in_bytes = 128
        temp_size_in_bytes = 64

    class C:
        def memory_analysis(self):
            return MS()

    class E:
        def memory_analysis(self):
            raise NotImplementedError

    out = compat.memory_stats(C())
    assert out == {"argument_size_in_bytes": 128.0, "temp_size_in_bytes": 64.0}
    assert compat.memory_stats(E()) == {}


def test_compiled_text_raises_instead_of_returning_empty():
    """'' would flow into analyze_hlo as a silent all-zero cost — the
    accessor must fail loudly instead."""

    class Broken:
        def as_text(self):
            raise RuntimeError("backend cannot dump HLO")

    with pytest.raises(RuntimeError):
        compat.compiled_text(Broken())
    with pytest.raises(AttributeError):
        compat.compiled_text(object())


def test_accessors_on_real_compiled_executable():
    import jax.numpy as jnp

    compiled = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 4), jnp.float32),
    ).compile()
    assert compat.cost_analysis(compiled).get("flops", 0) > 0
    assert "argument_size_in_bytes" in compat.memory_stats(compiled)
    assert "ENTRY" in compat.compiled_text(compiled)


# ---------------------------------------------------------------------------
# policy: version-gated JAX access only inside compat.py
# ---------------------------------------------------------------------------


def test_no_version_gated_jax_access_outside_compat():
    root = pathlib.Path(__file__).resolve().parent.parent
    gated = re.compile(
        r"jax\.sharding\.AxisType|axis_types\s*=|\bjax\.make_mesh\b"
        r"|jax\.sharding\.use_mesh|\bjax\.set_mesh\b"
    )
    offenders = []
    for sub in ("src", "benchmarks", "examples"):
        for p in (root / sub).rglob("*.py"):
            if p.name == "compat.py":
                continue
            if gated.search(p.read_text()):
                offenders.append(str(p.relative_to(root)))
    # tests may *simulate* the APIs (this file); production trees may not
    for p in (root / "tests").rglob("*.py"):
        if p.name == "test_compat.py":
            continue
        if "jax.sharding.AxisType" in p.read_text():
            offenders.append(str(p.relative_to(root)))
    assert not offenders, f"version-gated JAX access outside compat.py: {offenders}"


def test_pallas_imported_only_via_compat():
    """Kernel code (flash_attention, rmsnorm, paged_attention, and whatever
    comes next) reaches the Pallas modules through ``compat.pallas()`` /
    ``compat.pallas_tpu()`` — the experimental namespace moves between JAX
    releases and may be absent on minimal builds, so the import is a
    version-gated access like any other and lives only in compat.py."""
    root = pathlib.Path(__file__).resolve().parent.parent
    gated = re.compile(
        r"from\s+jax\.experimental\s+import\s+pallas|jax\.experimental\.pallas"
    )
    offenders = []
    for sub in ("src", "benchmarks", "examples", "tests"):
        for p in (root / sub).rglob("*.py"):
            if p.name in ("compat.py", "test_compat.py"):
                continue
            if gated.search(p.read_text()):
                offenders.append(str(p.relative_to(root)))
    assert not offenders, f"Pallas imported outside compat.py: {offenders}"


def test_pallas_accessors_raise_informatively_when_absent(monkeypatch):
    monkeypatch.setattr(compat, "_pallas_mod", None)
    monkeypatch.setattr(compat, "_pallas_tpu_mod", None)
    with pytest.raises(ImportError, match="reference"):
        compat.pallas()
    with pytest.raises(ImportError, match="reference"):
        compat.pallas_tpu()


def test_pallas_accessors_return_modules_when_present():
    if not compat.HAS_PALLAS:
        pytest.skip("no Pallas in this JAX build")
    assert hasattr(compat.pallas(), "pallas_call")
    if compat.HAS_PALLAS_TPU:
        assert hasattr(compat.pallas_tpu(), "PrefetchScalarGridSpec")


# ---------------------------------------------------------------------------
# policy: one instrumentation surface — collectors are constructed only
# behind the repro.session facade (same grep style as the compat rule)
# ---------------------------------------------------------------------------


def test_collectors_constructed_only_behind_the_session_facade():
    """All code — production AND tests — reaches instrumentation through
    PerfSession; the concrete ``TalpMonitor``/``TraceRecorder`` constructors
    are private to the session module and their defining modules. The
    one-release deprecation shims in ``repro.core`` are gone (PR 3's window
    ended), so the former tests-may-exercise-the-legacy-path carve-out is
    gone with them."""
    root = pathlib.Path(__file__).resolve().parent.parent
    construct = re.compile(r"\b(?:TalpMonitor|TraceRecorder)\s*\(")
    allowed = {
        "src/repro/session.py",       # the facade's backends
        "src/repro/core/monitor.py",  # the implementations themselves
        "src/repro/core/tracer.py",
    }
    offenders = []
    for sub in ("src", "benchmarks", "examples", "tests"):
        for p in (root / sub).rglob("*.py"):
            rel = str(p.relative_to(root))
            if rel in allowed:
                continue
            if construct.search(p.read_text()):
                offenders.append(rel)
    assert not offenders, (
        f"direct collector construction outside repro.session: {offenders}"
    )
