"""Traffic harness: workloads are pure functions of their config, and the
replay driver measures the scheduler without changing what it computes."""

import dataclasses

import jax
import pytest

from repro.configs import smoke_config
from repro.layers.common import init_params
from repro.models import transformer as T
from repro.launch.mesh import make_host_mesh
from repro.serve.serve import BatchScheduler, ServeConfig
from repro.serve.traffic import TrafficConfig, generate_workload, replay


def test_workload_is_pure_function_of_config():
    tcfg = TrafficConfig(n_requests=32, seed=7, arrival="burst",
                         cancel_frac=0.3)
    a, b = generate_workload(tcfg), generate_workload(tcfg)
    assert a == b, "same config must replay the same workload bit-for-bit"
    c = generate_workload(dataclasses.replace(tcfg, seed=8))
    assert a != c, "seed must actually drive the draw"


@pytest.mark.parametrize("arrival", ["poisson", "burst"])
def test_workload_shape(arrival):
    tcfg = TrafficConfig(n_requests=64, seed=3, arrival=arrival,
                         cancel_frac=0.25, priorities=(0, 5),
                         priority_weights=(0.8, 0.2))
    reqs = generate_workload(tcfg)
    assert len(reqs) == 64
    assert [r.request_id for r in reqs] == list(range(64))
    ticks = [r.arrival_tick for r in reqs]
    assert ticks == sorted(ticks)
    assert all(tcfg.prompt_short[0] <= len(r.prompt) <= tcfg.prompt_long[1]
               for r in reqs)
    assert all(tcfg.max_new_short[0] <= r.max_new <= tcfg.max_new_long[1]
               for r in reqs)
    assert {r.priority for r in reqs} <= {0, 5}
    cancels = [r for r in reqs if r.cancel_tick is not None]
    assert cancels, "cancel_frac=0.25 over 64 requests must schedule some"
    assert all(r.cancel_tick > r.arrival_tick for r in cancels)


def test_burst_arrivals_cluster_more_than_poisson():
    """The Markov-modulated process must actually produce bursts: for the
    same mean-ish load, its peak per-tick arrival count exceeds the
    memoryless baseline's (deterministic — both sides are seeded)."""
    def peak(arrival):
        reqs = generate_workload(TrafficConfig(
            n_requests=128, seed=11, arrival=arrival, rate=0.4,
            burst_mult=8.0,
        ))
        counts: dict[int, int] = {}
        for r in reqs:
            counts[r.arrival_tick] = counts.get(r.arrival_tick, 0) + 1
        return max(counts.values())

    assert peak("burst") > peak("poisson")


def test_invalid_configs_rejected():
    with pytest.raises(ValueError, match="arrival"):
        TrafficConfig(arrival="uniform")
    with pytest.raises(ValueError, match="weights"):
        TrafficConfig(priorities=(0, 1), priority_weights=(1.0,))


def test_replay_end_to_end_under_pressure():
    """A bursty workload with cancellations through a deliberately tight
    page pool: every request is accounted for (completed/cancelled), the
    pressure counters surface in the metrics, completed streams match the
    stop-the-world reference, and NOTHING leaks after drain."""
    cfg = smoke_config("tinyllama-1.1b").replace(
        compute_dtype_name="float32", param_dtype_name="float32"
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    tcfg = TrafficConfig(
        n_requests=6, seed=5, arrival="burst", rate=1.5, burst_mult=4.0,
        prompt_short=(4, 8), prompt_long=(10, 14), max_new_short=(3, 5),
        max_new_long=(6, 8), cancel_frac=0.3, cancel_delay=(2, 6),
        vocab_hi=cfg.vocab,
    )
    workload = generate_workload(tcfg)

    def run(num_pages):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4, paged=True,
                            page_size=8, num_pages=num_pages), params,
            )
            metrics = replay(sched, workload)
        return sched, metrics

    sched, m = run(num_pages=4)  # 2 slots x 2 pages: pressure guaranteed
    assert m["completed"] + m["cancelled"] + m["failed"] == len(workload)
    assert m["failed"] == 0, "pressure must preempt, not fail"
    assert m["good_tokens"] > 0 and m["goodput_tokens_per_sec"] > 0
    # goodput accounting regression pin: goodput counts COMPLETED streams
    # only; work burned on later-cancelled streams is reported separately
    # as cancelled_tokens, never mixed into good_tokens
    assert m["good_tokens"] == sum(
        len(r["generated"]) for r in sched.completed
    )
    assert m["cancelled_tokens"] == sum(
        len(r["generated"]) for r in sched.cancelled
    )
    assert m["cancelled"] > 0, "workload must actually exercise cancels"
    assert m["ttft_p99_s"] >= m["ttft_p50_s"] >= 0
    assert m["cancellations"] == m["cancelled"]
    assert sched._alloc.used == 0, "pages leaked after drain"
    # the replay itself is deterministic in WHAT it computes (wall-clock
    # metrics aside): a second run generates the same streams
    _, m2 = run(num_pages=4)
    assert m2["generated"] == m["generated"]
    # ...and pool pressure never changes tokens, only timing: an ample run
    # of the same workload completes the same requests with the same bits
    _, ample = run(num_pages=16)
    assert ample["generated"] == m["generated"]


def test_chaos_replay_composes_faults_with_workload():
    """replay(faults=...) attaches the seeded injector: the same
    (TrafficConfig, FaultConfig) pair replays the same streams bit-for-bit,
    the recovery counters surface in the metrics, and recovery never
    changes WHAT a surviving request computed — only when."""
    from repro.serve.faults import FaultConfig, FaultInjector

    cfg = smoke_config("tinyllama-1.1b").replace(
        compute_dtype_name="float32", param_dtype_name="float32"
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    tcfg = TrafficConfig(
        n_requests=5, seed=9, arrival="burst", rate=1.0,
        prompt_short=(4, 8), prompt_long=(10, 14), max_new_short=(3, 5),
        max_new_long=(6, 8), cancel_frac=0.0, vocab_hi=cfg.vocab,
    )
    workload = generate_workload(tcfg)
    fcfg = FaultConfig(seed=2, horizon_ticks=16, n_nan=1, n_page_corrupt=0,
                       n_alloc_spike=1, n_hang=0)

    def run(chaos):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4, paged=True,
                            page_size=8, num_pages=8), params,
            )
            m = replay(sched, workload,
                       faults=FaultInjector(fcfg) if chaos else None)
        return sched, m

    sched, m = run(chaos=True)
    assert m["recovery"]["retries"] >= 1
    assert m["recovery"]["injected"]["nan_injected"] == 1
    assert m["completed"] == len(workload) and m["failed"] == 0
    assert sched._alloc.used == 0, "chaos replay leaked pages"
    _, m2 = run(chaos=True)
    assert m2["generated"] == m["generated"], "chaos replay must be seeded"
    _, base = run(chaos=False)
    assert base["generated"] == m["generated"], \
        "fault recovery changed surviving streams"
