"""core.folder: merge-history semantics, nested experiment dirs, and the
skip-unreadable-json resilience paths (no optional test deps required)."""

import json
import os

from repro.core import folder as FD
from repro.core.records import (
    GLOBAL_REGION,
    RegionCounters,
    RegionMeasurements,
    RegionRecord,
    ResourceConfig,
    RunRecord,
)


def make_run(app="app", ts="2026-07-13T10:00:00", elapsed=1.0):
    r = RunRecord(
        app_name=app,
        resources=ResourceConfig(num_hosts=1, devices_per_host=4),
        timestamp=ts,
    )
    r.regions[GLOBAL_REGION] = RegionRecord(
        name=GLOBAL_REGION,
        measurements=RegionMeasurements(elapsed_s=elapsed, num_steps=5),
        counters=RegionCounters(useful_flops=1e9),
    )
    return r


def test_merge_history_current_pipeline_wins(tmp_path):
    """Same relative path on both sides: the CURRENT pipeline's file must
    survive untouched, and only genuinely-new history files are copied."""
    cur, hist = tmp_path / "cur", tmp_path / "hist"
    make_run(app="current", elapsed=2.0).save(cur / "exp" / "run.json")
    make_run(app="historic", elapsed=9.0).save(hist / "exp" / "run.json")
    make_run(app="historic").save(hist / "exp" / "older.json")

    merged = FD.merge_history(str(hist), str(cur))
    assert merged == 1  # only older.json; run.json collision keeps current
    kept = RunRecord.load(cur / "exp" / "run.json")
    assert kept.app_name == "current"
    assert kept.global_region.measurements.elapsed_s == 2.0
    assert RunRecord.load(cur / "exp" / "older.json").app_name == "historic"
    # idempotent: a second merge copies nothing
    assert FD.merge_history(str(hist), str(cur)) == 0


def test_merge_history_preserves_nested_experiment_dirs(tmp_path):
    """Nested experiment folders (mesh1/strong, mesh1/weak, root-level) keep
    their relative layout through a merge, including a record directly in
    the history root (rel == '.')."""
    cur, hist = tmp_path / "cur", tmp_path / "hist"
    os.makedirs(cur, exist_ok=True)
    make_run().save(hist / "mesh1" / "strong" / "a.json")
    make_run().save(hist / "mesh1" / "weak" / "b.json")
    make_run().save(hist / "mesh2" / "c.json")
    make_run().save(hist / "root.json")

    assert FD.merge_history(str(hist), str(cur)) == 4
    exps = FD.scan(str(cur))
    assert sorted(e.rel_path for e in exps) == [
        ".",
        os.path.join("mesh1", "strong"),
        os.path.join("mesh1", "weak"),
        "mesh2",
    ]
    # non-json files are not merged
    (hist / "mesh2" / "notes.txt").write_text("ignore me")
    assert FD.merge_history(str(hist), str(cur)) == 0


def test_scan_skips_unreadable_json_but_keeps_experiment(tmp_path, capsys):
    make_run().save(tmp_path / "exp" / "good.json")
    (tmp_path / "exp" / "broken.json").write_text("{definitely not json")
    # a too-new schema version is also skipped, not fatal
    too_new = make_run().to_json()
    too_new["schema_version"] = 99
    (tmp_path / "exp" / "future.json").write_text(json.dumps(too_new))

    exps = FD.scan(str(tmp_path))
    assert len(exps) == 1
    assert [r.app_name for r in exps[0].runs] == ["app"]
    out = capsys.readouterr().out
    assert "skipping unreadable run" in out


def test_scan_drops_experiment_with_only_unreadable_json(tmp_path):
    (tmp_path / "exp").mkdir()
    (tmp_path / "exp" / "broken.json").write_text("nope")
    assert FD.scan(str(tmp_path)) == []


def test_add_metadata_skips_unreadable_json(tmp_path):
    make_run().save(tmp_path / "exp" / "good.json")
    (tmp_path / "exp" / "broken.json").write_text("{]")
    n = FD.add_metadata(str(tmp_path), {"ci": "yes"})
    assert n == 1  # only the readable record was updated
    assert RunRecord.load(tmp_path / "exp" / "good.json").metadata["ci"] == "yes"
    assert (tmp_path / "exp" / "broken.json").read_text() == "{]"  # untouched
