"""Checkpointing: roundtrip, atomicity, restart, rolling GC."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.checkpoint import latest_step
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train.loop import InjectedFailure, LoopConfig, TrainLoop
from repro.train.train import TrainConfig


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "step": jnp.asarray(7)},
        "list": [jnp.zeros(3), jnp.full((2,), 2.5)],
    }


def assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32)
        )
        assert x.dtype == y.dtype


def test_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(t, str(tmp_path), 3)
    restored, step = load_checkpoint(t, str(tmp_path))
    assert step == 3
    assert_tree_equal(t, restored)


def test_async_save_then_join(tmp_path):
    t = tree()
    join = save_checkpoint(t, str(tmp_path), 1, async_=True)
    join()
    restored, _ = load_checkpoint(t, str(tmp_path))
    assert_tree_equal(t, restored)


def test_no_tmp_left_and_latest_ignores_partial(tmp_path):
    save_checkpoint(tree(), str(tmp_path), 1)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    # simulate a crashed save: partial tmp dir without manifest
    os.makedirs(tmp_path / "step_00000002.tmp")
    os.makedirs(tmp_path / "step_00000005")  # committed dir but no manifest
    assert latest_step(str(tmp_path)) == 1


def test_manager_rolls_old_checkpoints(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_=False)
    for s in (1, 2, 3, 4):
        mgr.save(tree(), s)
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]


def test_missing_key_raises(tmp_path):
    save_checkpoint({"a": jnp.ones(3)}, str(tmp_path), 1)
    with pytest.raises(KeyError):
        load_checkpoint({"a": jnp.ones(3), "b": jnp.ones(2)}, str(tmp_path))


def _loop(ckpt_dir, steps, fail_at=None):
    cfg = smoke_config("tinyllama-1.1b")
    data = SyntheticLM(DataConfig(global_batch=2, seq_len=32, vocab=cfg.vocab))
    return TrainLoop(
        cfg, make_host_mesh(), TrainConfig(), data,
        LoopConfig(steps=steps, ckpt_every=2, ckpt_dir=str(ckpt_dir),
                   fail_at_step=fail_at),
    )


def test_crash_restart_resumes_from_checkpoint(tmp_path):
    """The paper-grade fault-tolerance contract: crash at step 5, restart,
    and the run completes with exactly the remaining steps."""
    loop = _loop(tmp_path, steps=8, fail_at=5)
    with pytest.raises(InjectedFailure):
        loop.run()
    assert loop.ckpt.latest() == 4  # checkpoints at 2 and 4

    resumed = _loop(tmp_path, steps=8, fail_at=None)
    resumed.run()
    executed = [m["step"] for m in resumed.metrics_history]
    assert executed == [4, 5, 6, 7]  # resumed exactly after last checkpoint
    assert int(resumed.final_state["step"]) == 8


def test_restarted_run_matches_uninterrupted_run(tmp_path):
    """Determinism across restart: same final loss as a run that never
    crashed (data pipeline is step-indexed; RNG folded from seed)."""
    a = _loop(tmp_path / "a", steps=6, fail_at=None)
    a.run()

    b1 = _loop(tmp_path / "b", steps=6, fail_at=3)
    with pytest.raises(InjectedFailure):
        b1.run()
    b2 = _loop(tmp_path / "b", steps=6, fail_at=None)
    b2.run()

    la = a.metrics_history[-1]["loss"]
    lb = b2.metrics_history[-1]["loss"]
    np.testing.assert_allclose(la, lb, rtol=1e-4)


def test_straggler_detection_fires(tmp_path):
    cfg = smoke_config("tinyllama-1.1b")
    data = SyntheticLM(DataConfig(global_batch=2, seq_len=32, vocab=cfg.vocab))
    events = []
    loop = TrainLoop(
        cfg, make_host_mesh(), TrainConfig(), data,
        LoopConfig(
            steps=4, lb_sample_every=1,
            host_times_fn=lambda s: [1.0, 1.0, 1.0, 3.0] if s >= 2 else [1.0] * 4,
            straggler_threshold=0.8,
        ),
        on_straggler=lambda step, lb: events.append((step, lb)),
    )
    loop.run()
    assert events and events[0][0] == 2
    assert loop.straggler_events
    run = loop.finalize_run()
    assert run.regions["train_step"].measurements.host_lb < 1.0
