"""Training semantics: convergence, grad accumulation, data determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train.train import TrainConfig, init_state, make_train_step
from repro.optim import AdamWConfig, compress_int8, decompress_int8, cosine_schedule


def _setup(arch="tinyllama-1.1b", **tkw):
    cfg = smoke_config(arch)
    mesh = make_host_mesh()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-2), **tkw)
    st = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    state = {"params": st.params, "opt_state": st.opt_state, "step": st.step}
    return cfg, mesh, tcfg, state


def test_loss_decreases_on_fixed_batch():
    cfg, mesh, tcfg, state = _setup()
    data = SyntheticLM(DataConfig(global_batch=4, seq_len=32, vocab=cfg.vocab))
    batch = data.batch_at(0)
    with mesh:
        step = jax.jit(make_train_step(cfg, mesh, tcfg))
        losses = []
        for _ in range(12):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_grad_accumulation_equivalence():
    """A=2 with half microbatch == A=1 with full batch (same total batch)."""
    cfg, mesh, tcfg, state = _setup()
    data = SyntheticLM(DataConfig(global_batch=8, seq_len=32, vocab=cfg.vocab))
    big = data.batch_at(0)  # (1, 8, 32)
    small = jax.tree_util.tree_map(
        lambda x: x.reshape((2, 4) + x.shape[2:]), big
    )
    with mesh:
        step = jax.jit(make_train_step(cfg, mesh, tcfg))
        s1, m1 = step(state, big)
        s2, m2 = step(state, small)
    np.testing.assert_allclose(
        float(m1["loss"]), float(m2["loss"]), rtol=2e-2
    )
    g1, g2 = float(m1["grad_norm"]), float(m2["grad_norm"])
    assert abs(g1 - g2) / g1 < 5e-2


def test_data_pipeline_is_step_indexed_and_deterministic():
    dc = DataConfig(global_batch=4, seq_len=16, vocab=100, pad_fraction=0.2)
    a, b = SyntheticLM(dc), SyntheticLM(dc)
    for step in (0, 5, 1000):
        ba, bb = a.batch_at(step), b.batch_at(step)
        np.testing.assert_array_equal(np.asarray(ba["tokens"]), np.asarray(bb["tokens"]))
    assert not np.array_equal(
        np.asarray(a.batch_at(1)["tokens"]), np.asarray(a.batch_at(2)["tokens"])
    )


def test_padding_produces_data_imbalance_signal():
    dc = DataConfig(global_batch=8, seq_len=64, vocab=100, pad_fraction=0.3)
    batch = SyntheticLM(dc).batch_at(0)
    labels = np.asarray(batch["labels"][0])
    per_sample = (labels >= 0).sum(axis=-1)
    assert per_sample.min() < per_sample.max()  # real imbalance exists


def test_metrics_include_monitor_observables():
    cfg, mesh, tcfg, state = _setup("qwen3-moe-30b-a3b")
    data = SyntheticLM(DataConfig(global_batch=4, seq_len=32, vocab=cfg.vocab))
    with mesh:
        step = jax.jit(make_train_step(cfg, mesh, tcfg))
        _, metrics = step(state, data.batch_at(0))
    assert "tokens_per_shard" in metrics
    assert "expert_load" in metrics
    assert metrics["expert_load"].shape == (cfg.moe.n_experts,)
    assert float(metrics["expert_load"].sum()) == 4 * 32 * cfg.moe.top_k * cfg.n_layers


def test_int8_compression_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.1
    q, s, meta = compress_int8(g)
    back = decompress_int8(q, s, meta)
    err = np.abs(np.asarray(back - g))
    scale = np.abs(np.asarray(g)).max()
    assert err.max() <= scale / 127 + 1e-7
    assert q.dtype == jnp.int8


def test_int8_stochastic_rounding_roughly_unbiased():
    g = jnp.full((4096,), 0.01)
    keys = jax.random.split(jax.random.PRNGKey(1), 16)
    outs = [decompress_int8(*compress_int8(g, k)[:2], compress_int8(g, k)[2]) for k in keys]
    mean = np.mean([np.asarray(o).mean() for o in outs])
    assert abs(mean - 0.01) < 5e-4


def test_compressed_grads_still_train():
    cfg, mesh, tcfg, state = _setup(compress_dcn_grads=True)
    data = SyntheticLM(DataConfig(global_batch=4, seq_len=32, vocab=cfg.vocab))
    batch = data.batch_at(0)
    with mesh:
        step = jax.jit(make_train_step(cfg, mesh, tcfg))
        losses = []
        for _ in range(8):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(10, warmup=10, total=100)) == pytest.approx(1.0)
    end = float(cosine_schedule(100, warmup=10, total=100))
    assert end == pytest.approx(0.1, abs=1e-3)
