"""``talp ci-report`` CLI round-trip smoke test (run in CI next to
``benchmarks/run.py --check``): a tmp folder mixing v2 and v3 records must
produce an HTML index, rendered badges, and the per-computation drill-down.
"""

import json
import os

import pytest

from repro.core.pages import main
from repro.core.records import (
    GLOBAL_REGION,
    ComputationCounters,
    RegionCounters,
    RegionMeasurements,
    RegionRecord,
    ResourceConfig,
    RunRecord,
)


def _base_run(ts, commit, elapsed):
    run = RunRecord(
        app_name="smoke",
        resources=ResourceConfig(num_hosts=1, devices_per_host=8),
        timestamp=ts,
        metadata={"git_commit_short": commit, "git_commit_timestamp": ts},
    )
    reg = RegionRecord(
        name=GLOBAL_REGION,
        measurements=RegionMeasurements(
            elapsed_s=elapsed, num_steps=10, device_time_s=elapsed * 0.9
        ),
        counters=RegionCounters(useful_flops=1e12, hlo_bytes=1e10,
                                collective_bytes_ici=1e8, model_flops=8e11),
    )
    from repro.core import factors as F

    reg.pop = F.compute_pop(reg, run.resources, run.hardware)
    run.regions[GLOBAL_REGION] = reg
    return run


def _write_v2(path, ts, commit, elapsed):
    """A record as the v2 monitor wrote it (breakdown in metadata blob)."""
    d = _base_run(ts, commit, elapsed).to_json()
    d["schema_version"] = 2
    for rd in d["regions"].values():
        rd.pop("computations", None)
    d["metadata"]["per_computation"] = {
        GLOBAL_REGION: [
            {"name": "while_body.fusion.1", "kind": "while_body",
             "multiplicity": 24, "num_instructions": 30, "flops": 8e11,
             "dot_flops": 6e11, "hbm_bytes": 9e9,
             "collective_operand_bytes": 1e8},
        ]
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(d, f)


def _write_v3(path, ts, commit, elapsed):
    run = _base_run(ts, commit, elapsed)
    run.global_region.computations = {
        "while_body.fusion.1": ComputationCounters(
            name="while_body.fusion.1", kind="while_body", multiplicity=24,
            num_instructions=30, flops=8e11, dot_flops=6e11, hbm_bytes=9e9,
            collective_operand_bytes=1e8,
        ),
    }
    run.save(path)


@pytest.fixture()
def mixed_folder(tmp_path):
    talp = tmp_path / "talp"
    _write_v2(str(talp / "exp" / "run_0.json"), "2026-07-10T00:00:00", "c00", 1.00)
    _write_v2(str(talp / "exp" / "run_1.json"), "2026-07-11T00:00:00", "c01", 1.02)
    _write_v3(str(talp / "exp" / "run_2.json"), "2026-07-12T00:00:00", "c02", 1.01)
    return talp


def test_ci_report_roundtrip_over_v2_and_v3_records(mixed_folder, tmp_path):
    site = tmp_path / "site"
    rc = main(["ci-report", "-i", str(mixed_folder), "-o", str(site),
               "--top-computations", "4"])
    assert rc == 0

    index = site / "index.html"
    assert index.exists()
    html = index.read_text()
    assert "Scaling efficiency" in html
    assert "HLO computation breakdown" in html
    assert "while_body.fusion.1" in html  # v2 blob made it into the drill-down
    assert os.path.exists(site / "findings.json")

    badges = [n for n in os.listdir(site) if n.startswith("badge_")]
    assert badges
    assert "<svg" in (site / badges[0]).read_text()  # badge renders


def test_ci_report_top_computations_zero_disables_breakdown(mixed_folder, tmp_path):
    site = tmp_path / "site0"
    rc = main(["ci-report", "-i", str(mixed_folder), "-o", str(site),
               "--top-computations", "0"])
    assert rc == 0
    html = (site / "index.html").read_text()
    assert "HLO computation breakdown" not in html


def test_badge_cli_from_mixed_folder(mixed_folder, tmp_path):
    out = tmp_path / "badge.svg"
    rc = main(["badge", "-i", str(mixed_folder), "-o", str(out)])
    assert rc == 0
    assert "<svg" in out.read_text()
