"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import (
    gather_pages,
    paged_attention_reference,
)
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_reference

KEY = jax.random.PRNGKey(7)


def _qkv(B, Sq, Sk, Hq, Hkv, D, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, Sq, Hq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, Sk, Hkv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, Sk, Hkv, D), jnp.float32).astype(dtype)
    return q, k, v


FLASH_CASES = [
    # B, Sq, Sk, Hq, Hkv, D, causal, window, softcap
    (2, 256, 256, 4, 2, 64, True, None, None),
    (1, 128, 128, 4, 4, 64, False, None, None),
    (1, 256, 256, 2, 1, 64, True, 64, None),      # sliding window
    (2, 64, 64, 8, 2, 32, True, None, 30.0),      # softcap (gemma2)
    (1, 200, 200, 2, 2, 48, True, None, None),    # non-multiple-of-block seq
    (1, 96, 96, 2, 1, 100, False, 32, 50.0),      # padding in D + win + cap
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=[str(c[:6]) for c in FLASH_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_reference(case, dtype):
    B, Sq, Sk, Hq, Hkv, D, causal, window, softcap = case
    q, k, v = _qkv(B, Sq, Sk, Hq, Hkv, D, dtype)
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=64, block_kv=64, interpret=True,
    )
    ref = attention_reference(q, k, v, causal=causal, window=window, softcap=softcap)
    assert out.shape == ref.shape and out.dtype == ref.dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_block_shape_independence():
    """Block size is a tuning knob — results must not depend on it."""
    q, k, v = _qkv(1, 192, 192, 2, 2, 64, jnp.float32)
    outs = [
        flash_attention_pallas(q, k, v, causal=True, block_q=bq, block_kv=bk,
                               interpret=True)
        for bq, bk in [(32, 32), (64, 128), (128, 64)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=1e-5)


def test_flash_matches_model_flash_path():
    """The model's chunked-scan attention and the kernel agree (so the
    kernel can be swapped in on TPU without changing semantics)."""
    from repro.layers.attention import flash_attention as model_flash

    q, k, v = _qkv(2, 128, 128, 4, 2, 64, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    a = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_kv=64,
                               interpret=True)
    b = model_flash(q, k, v, q_positions=pos, k_positions=pos, causal=True,
                    q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# paged decode attention: Pallas scalar-prefetch kernel vs gather oracle
# ---------------------------------------------------------------------------


def _paged_case(B, Hq, Hkv, D, psize, nL, P, lens, dtype, seed=0):
    """Random pool + a scrambled (non-identity) block table + ragged lens."""
    rng = np.random.default_rng(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D), jnp.float32).astype(dtype)
    k_pages = jax.random.normal(ks[1], (P, psize, Hkv, D), jnp.float32).astype(dtype)
    v_pages = jax.random.normal(ks[2], (P, psize, Hkv, D), jnp.float32).astype(dtype)
    perm = rng.permutation(P)
    tbl = np.full((B, nL), -1, np.int32)
    used = 0
    for b, ln in enumerate(lens):
        n = -(-ln // psize)
        tbl[b, :n] = perm[used : used + n]
        used += n
    lens = jnp.asarray(lens, jnp.int32)
    return q, k_pages, v_pages, jnp.asarray(tbl), lens, lens - 1


PAGED_CASES = [
    # B, Hq, Hkv, D, psize, nL, P, lens, window, softcap
    (3, 4, 2, 64, 4, 4, 12, (6, 3, 11), None, None),
    (2, 4, 4, 64, 16, 4, 9, (50, 17), None, None),
    (2, 2, 1, 64, 4, 8, 20, (29, 13), 6, None),     # window crosses pages
    (2, 8, 2, 32, 8, 3, 8, (20, 9), None, 30.0),    # softcap (gemma2)
    (1, 2, 2, 100, 8, 4, 6, (27,), 11, 50.0),       # D padding + win + cap
]


@pytest.mark.parametrize("case", PAGED_CASES, ids=[str(c[:7]) for c in PAGED_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_reference(case, dtype):
    """Kernel-vs-ref parity in interpret mode: the in-kernel block-table
    gather + online softmax must agree with the gather oracle across GQA,
    ragged lengths, windows that straddle page boundaries, softcap, and
    head-dim padding."""
    B, Hq, Hkv, D, psize, nL, P, lens, window, softcap = case
    q, kp, vp, tbl, lens, qpos = _paged_case(B, Hq, Hkv, D, psize, nL, P,
                                             lens, dtype)
    out = paged_attention_pallas(
        q, kp, vp, tbl, q_position=qpos, cache_len=lens,
        window=window, softcap=softcap, interpret=True,
    )
    ref = paged_attention_reference(
        q, kp, vp, tbl, q_position=qpos, cache_len=lens,
        window=window, softcap=softcap,
    )
    assert out.shape == ref.shape and out.dtype == ref.dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


def test_paged_reference_bitwise_matches_dense_decode_attention():
    """The bridge that makes scheduler-level paged-vs-dense token identity
    hold: the paged oracle over (pool, table) is BITWISE equal to the
    model's dense ``decode_attention`` over the gathered dense view —
    including with garbage (another slot's data) in the masked tail."""
    from repro.layers.attention import decode_attention

    for window, softcap in [(None, None), (5, None), (None, 30.0), (7, 30.0)]:
        q, kp, vp, tbl, lens, qpos = _paged_case(
            3, 4, 2, 64, 4, 4, 12, (6, 3, 11), jnp.float32, seed=2
        )
        ref = paged_attention_reference(
            q, kp, vp, tbl, q_position=qpos, cache_len=lens,
            window=window, softcap=softcap,
        )
        dense = decode_attention(
            q, gather_pages(kp, tbl), gather_pages(vp, tbl),
            q_position=qpos, cache_len=lens, window=window, softcap=softcap,
        )
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(dense))


def test_paged_ops_wrapper_routes_to_reference_on_cpu():
    from repro.kernels import paged_attention

    q, kp, vp, tbl, lens, qpos = _paged_case(
        2, 4, 2, 64, 4, 4, 10, (9, 5), jnp.float32, seed=3
    )
    out = paged_attention(q, kp, vp, tbl, q_position=qpos, cache_len=lens)
    ref = paged_attention_reference(q, kp, vp, tbl, q_position=qpos,
                                    cache_len=lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


# ---------------------------------------------------------------------------
# paged prefill attention (S>1): multi-token chunk reads over block tables
# ---------------------------------------------------------------------------


def _paged_prefill_case(B, C, Hq, Hkv, D, psize, nL, P, starts, dtype, seed=0):
    """A prefill chunk of C tokens per sequence at ragged start offsets,
    over a random pool + scrambled block table (like ``_paged_case`` but
    with multi-row queries: the serve path's chunked-prefill reads)."""
    rng = np.random.default_rng(seed)
    ks = jax.random.split(jax.random.PRNGKey(seed + 11), 3)
    q = jax.random.normal(ks[0], (B, C, Hq, D), jnp.float32).astype(dtype)
    k_pages = jax.random.normal(ks[1], (P, psize, Hkv, D), jnp.float32).astype(dtype)
    v_pages = jax.random.normal(ks[2], (P, psize, Hkv, D), jnp.float32).astype(dtype)
    perm = rng.permutation(P)
    lens = [s + C for s in starts]
    tbl = np.full((B, nL), -1, np.int32)
    used = 0
    for b, ln in enumerate(lens):
        n = -(-ln // psize)
        tbl[b, :n] = perm[used : used + n]
        used += n
    qpos = np.asarray(starts)[:, None] + np.arange(C)[None]
    return (q, k_pages, v_pages, jnp.asarray(tbl),
            jnp.asarray(qpos, jnp.int32), jnp.asarray(lens, jnp.int32))


PREFILL_CASES = [
    # B, C, Hq, Hkv, D, psize, nL, P, starts, window, softcap
    (2, 8, 4, 2, 64, 4, 6, 14, (0, 8), None, None),     # ragged starts, GQA
    (1, 16, 4, 4, 64, 16, 2, 3, (16,), None, None),     # page == chunk
    (2, 8, 2, 1, 64, 4, 8, 18, (4, 12), 6, None),       # window crosses pages
    (2, 8, 8, 2, 32, 8, 3, 7, (0, 16), None, 30.0),     # softcap (gemma2)
    (1, 8, 2, 2, 100, 8, 4, 5, (8,), 5, 50.0),          # D padding + win + cap
]


@pytest.mark.parametrize("case", PREFILL_CASES,
                         ids=[str(c[:9]) for c in PREFILL_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_prefill_matches_reference(case, dtype):
    """S>1 kernel-vs-ref parity in interpret mode: per-row causal masking
    inside the chunk (row r attends through start+r, not just cache_len)
    across GQA, ragged starts, windows crossing page boundaries, softcap,
    and head-dim padding."""
    from repro.kernels.paged_attention.kernel import paged_prefill_attention_pallas
    from repro.kernels.paged_attention.ref import paged_prefill_attention_reference

    B, C, Hq, Hkv, D, psize, nL, P, starts, window, softcap = case
    q, kp, vp, tbl, qpos, lens = _paged_prefill_case(
        B, C, Hq, Hkv, D, psize, nL, P, starts, dtype
    )
    out = paged_prefill_attention_pallas(
        q, kp, vp, tbl, q_positions=qpos, cache_len=lens,
        causal=True, window=window, softcap=softcap, interpret=True,
    )
    ref = paged_prefill_attention_reference(
        q, kp, vp, tbl, q_positions=qpos, cache_len=lens,
        causal=True, window=window, softcap=softcap,
    )
    assert out.shape == ref.shape and out.dtype == ref.dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


def test_paged_prefill_reference_bitwise_matches_dense_flash():
    """The S>1 bridge behind scheduler-level paged-vs-dense token identity:
    the paged prefill oracle over (pool, table) is BITWISE equal to the
    model's dense ``flash_attention`` over the gathered view with the same
    chunk grid — including garbage (another slot's data) past cache_len."""
    from repro.kernels.paged_attention.ref import paged_prefill_attention_reference
    from repro.layers.attention import flash_attention as model_flash

    for window, softcap in [(None, None), (6, None), (None, 30.0), (5, 30.0)]:
        q, kp, vp, tbl, qpos, lens = _paged_prefill_case(
            2, 8, 4, 2, 64, 4, 6, 14, (0, 8), jnp.float32, seed=5
        )
        ref = paged_prefill_attention_reference(
            q, kp, vp, tbl, q_positions=qpos, cache_len=lens,
            window=window, softcap=softcap, q_chunk=64, kv_chunk=64,
        )
        k_dense, v_dense = gather_pages(kp, tbl), gather_pages(vp, tbl)
        Smax = k_dense.shape[1]
        kpos = jnp.broadcast_to(jnp.arange(Smax)[None], (q.shape[0], Smax))
        dense = model_flash(
            q, k_dense, v_dense, q_positions=qpos, k_positions=kpos,
            kv_len=lens, causal=True, causal_skip=False,
            window=window, softcap=softcap, q_chunk=64, kv_chunk=64,
        )
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(dense))


def test_paged_prefill_ops_wrapper_routes_to_reference_on_cpu():
    from repro.kernels import paged_prefill_attention
    from repro.kernels.paged_attention.ref import paged_prefill_attention_reference

    q, kp, vp, tbl, qpos, lens = _paged_prefill_case(
        2, 8, 4, 2, 64, 4, 6, 14, (0, 8), jnp.float32, seed=6
    )
    out = paged_prefill_attention(q, kp, vp, tbl, q_positions=qpos,
                                  cache_len=lens)
    ref = paged_prefill_attention_reference(q, kp, vp, tbl, q_positions=qpos,
                                            cache_len=lens)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # and the interpret route runs the kernel end to end through the wrapper
    interp = paged_prefill_attention(q, kp, vp, tbl, q_positions=qpos,
                                     cache_len=lens, impl="interpret")
    np.testing.assert_allclose(np.asarray(interp), np.asarray(ref), atol=2e-5)


RMS_CASES = [(4, 128), (3, 300), (1, 1024), (17, 96)]


@pytest.mark.parametrize("rows,d", RMS_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("zero_centered", [False, True])
def test_rmsnorm_matches_reference(rows, d, dtype, zero_centered):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (rows, d), jnp.float32).astype(dtype)
    s = jax.random.normal(k2, (d,), jnp.float32)
    out = rmsnorm_pallas(x, s, zero_centered=zero_centered, block_rows=64,
                         interpret=True)
    ref = rmsnorm_reference(x, s, zero_centered=zero_centered)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


def test_rmsnorm_3d_shape():
    x = jax.random.normal(KEY, (2, 5, 256), jnp.float32)
    s = jnp.ones((256,))
    out = rmsnorm_pallas(x, s, interpret=True)
    assert out.shape == x.shape
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(rmsnorm_reference(x, s)), atol=1e-5
    )


def test_ops_wrappers_route_to_reference_on_cpu():
    from repro.kernels import flash_attention, rmsnorm

    q, k, v = _qkv(1, 64, 64, 2, 2, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    x = jax.random.normal(KEY, (4, 128))
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, jnp.ones(128))),
        np.asarray(rmsnorm_reference(x, jnp.ones(128))), atol=1e-6,
    )


def test_flash_fully_masked_block_with_negative_scores():
    """Regression: a fully-masked kv block must not poison the running max.

    With true row maxima << 0, returning a 0-sentinel from the masked block
    made max(m,0)=0 underflow the rescale factor, collapsing l to zero —
    silently wrong outputs and NaN gradients (hit by any multi-block causal
    run at init scale). The block must report its TRUE masked max.
    """
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32)) * 40   # |scores| ~ 1e3
    k = jax.random.normal(ks[1], (2, 128, 2, 32)) * 40
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    from repro.layers.attention import flash_attention as model_flash

    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    ref = attention_reference(q, k, v, causal=True)
    for skip in (False, True):
        def loss(q):
            o = model_flash(q, k, v, q_positions=pos, k_positions=pos,
                            causal=True, q_chunk=64, kv_chunk=64,
                            causal_skip=skip)
            return jnp.sum(o.astype(jnp.float32) ** 2)

        out = model_flash(q, k, v, q_positions=pos, k_positions=pos,
                          causal=True, q_chunk=64, kv_chunk=64,
                          causal_skip=skip)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4)
        g = jax.grad(loss)(q)
        assert np.isfinite(np.asarray(g)).all()
