# NOTE: deliberately no XLA_FLAGS here — smoke tests and benches run on the
# single real CPU device. Multi-device behaviour (sharding, elastic
# resharding, host load balance) is tested through subprocesses that set
# --xla_force_host_platform_device_count themselves (see test_elastic.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so tests can import shared fixtures from benchmarks/
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
