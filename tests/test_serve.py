"""Serving correctness: decode path must agree with the full forward pass."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.layers.common import init_params
from repro.models import transformer as T
from repro.launch.mesh import make_host_mesh
from repro.serve.serve import BatchScheduler, ServeConfig, make_decode_step, make_prefill_step


@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b", "gemma2-2b", "qwen3-moe-30b-a3b", "zamba2-2.7b",
    "xlstm-350m",
])
def test_decode_matches_forward_logits(arch):
    """Prefill+decode must reproduce the teacher-forced forward logits —
    the strongest end-to-end consistency check for every cache type
    (KV, conv, ssm, mLSTM, sLSTM)."""
    cfg = smoke_config(arch)
    if arch in ("zamba2-2.7b", "xlstm-350m"):
        # chunked-prefill vs stepwise-decode recurrences are mathematically
        # identical but round differently; the recurrent denominators
        # (mLSTM max(|q.n|, exp(-m))) amplify reassociation noise roughly
        # exponentially with depth. Run the cache-logic consistency check
        # in f32 at one pattern repeat — deeper stacks diverge numerically,
        # not logically (see DESIGN.md numerics notes).
        cfg = cfg.replace(compute_dtype_name="float32",
                          param_dtype_name="float32")
    if arch == "xlstm-350m":
        cfg = cfg.replace(repeats=1)
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    Bs, prompt_len, total = 2, 16, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (Bs, total), 4, cfg.vocab)

    # xlstm: jit-vs-eager op fusion perturbs the mLSTM state slightly and
    # its denominator amplifies that; keep both sides in the same
    # compilation mode so the check isolates cache logic.
    jit_ = (lambda f: f) if arch == "xlstm-350m" else jax.jit
    with mesh:
        full_logits, _ = jit_(lambda p, b: T.apply_logits(p, b, cfg))(
            params, {"tokens": toks}
        )
        caches = T.init_cache(cfg, Bs, total + 8)
        _, caches = jit_(make_prefill_step(cfg, mesh))(
            params, {"tokens": toks[:, :prompt_len]}, caches
        )
        decode = jax.jit(make_decode_step(cfg, mesh))
        errs = []
        for i in range(prompt_len, total):
            logits, caches = T.decode_step(
                params, toks[:, i : i + 1], jnp.asarray(i, jnp.int32), cfg, caches
            )
            err = np.max(np.abs(
                np.asarray(logits, np.float32)
                - np.asarray(full_logits[:, i], np.float32)
            ))
            errs.append(err)
    assert max(errs) < 0.1, f"{arch}: decode/forward divergence {max(errs)}"


def test_prefill_last_logits_match_forward():
    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 4, cfg.vocab)
    with mesh:
        full_logits, _ = T.apply_logits(params, {"tokens": toks}, cfg)
        caches = T.init_cache(cfg, 2, 32)
        next_tok, _ = make_prefill_step(cfg, mesh)(params, {"tokens": toks}, caches)
    expected = np.argmax(np.asarray(full_logits[:, -1], np.float32), axis=-1)
    np.testing.assert_array_equal(np.asarray(next_tok), expected)


# ---------------------------------------------------------------------------
# BatchScheduler: chunked prefill-on-attach overlapped with in-flight decode
# ---------------------------------------------------------------------------
# f32 so the chunked-prefill-vs-reference and A/B token-identity checks
# isolate scheduler logic from bf16 argmax near-ties. One shared config =
# one shared (decode, prefill) jit pair across every scheduler instance.


@functools.cache
def _serve_fixtures():
    cfg = smoke_config("tinyllama-1.1b").replace(
        compute_dtype_name="float32", param_dtype_name="float32"
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    return cfg, mesh, params


def _run(sched, n_requests, max_ticks=200):
    ticks = 0
    while len(sched.completed) < n_requests and ticks < max_ticks:
        sched.step()
        ticks += 1
    sched.drain()
    return ticks


def _reference_generate(cfg, mesh, params, prompt, max_new, max_len=64):
    """Stop-the-world reference: full one-shot prefill + sequential decode."""
    with mesh:
        caches = T.init_cache(cfg, 1, max_len)
        toks = jnp.asarray([prompt], jnp.int32)
        next_tok, caches = make_prefill_step(cfg, mesh)(
            params, {"tokens": toks}, caches
        )
        out = [int(next_tok[0])]
        pos = len(prompt)
        tok = next_tok.reshape(1, 1)
        while len(out) < max_new:
            logits, caches = T.decode_step(
                params, tok, jnp.asarray(pos, jnp.int32), cfg, caches
            )
            tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(jnp.int32)
            out.append(int(tok[0, 0]))
            pos += 1
    return out


def test_batch_scheduler_completes_requests():
    cfg, mesh, params = _serve_fixtures()
    with mesh:
        sched = BatchScheduler(cfg, mesh, ServeConfig(max_len=64, batch=2), params)
        for rid in range(4):
            sched.submit([1, 2, 3], request_id=rid, max_new=5)
        _run(sched, 4)
    assert len(sched.completed) == 4
    for req in sched.completed:
        assert len(req["generated"]) == 5
        assert all(0 <= t < cfg.vocab_padded for t in req["generated"])


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-2b"])
def test_scheduler_chunked_prefill_matches_reference(arch):
    """Chunked prefill at per-slot offsets + continuous-batching decode must
    reproduce the stop-the-world reference (one-shot prefill + sequential
    decode) token for token — the end-to-end correctness gate for the
    per-slot position vector and the cache-attend prefill path. gemma2 runs
    with a sliding window SMALLER than the prompts so the window actually
    cuts into the cache_attend path at test lengths."""
    if arch == "tinyllama-1.1b":
        cfg, mesh, params = _serve_fixtures()
    else:
        cfg = smoke_config(arch).replace(
            compute_dtype_name="float32", param_dtype_name="float32", window=5
        )
        mesh = make_host_mesh()
        params = init_params(
            T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype
        )
    rng = np.random.default_rng(7)
    prompts = [rng.integers(4, cfg.vocab, size=n).tolist() for n in (3, 9, 14, 6)]
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4), params,
        )
        for rid, p in enumerate(prompts):
            sched.submit(p, request_id=rid, max_new=6)
        _run(sched, len(prompts))
    assert len(sched.completed) == len(prompts)
    for req in sched.completed:
        ref = _reference_generate(cfg, mesh, params, prompts[req["id"]], 6)
        assert req["generated"] == ref, (req["id"], req["generated"], ref)


def test_submit_rejects_nonpositive_max_new():
    """The prefill-completion token is unconditionally the first generated
    token, so a zero (or negative) budget is unsatisfiable — reject it."""
    cfg, mesh, params = _serve_fixtures()
    with mesh:
        sched = BatchScheduler(cfg, mesh, ServeConfig(max_len=64, batch=2), params)
    with pytest.raises(ValueError, match="max_new"):
        sched.submit([1, 2], request_id=0, max_new=0)


def test_attach_during_decode_does_not_change_inflight_outputs():
    """Attaching (and prefilling) request B mid-flight must not perturb
    request A's token stream: the prefill only touches B's cache lines and
    the masked decode write leaves B's lines alone."""
    cfg, mesh, params = _serve_fixtures()
    prompt_a = [5, 6, 7, 8]
    prompt_b = list(range(4, 16))

    def run(with_b):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4), params,
            )
            sched.submit(prompt_a, request_id="a", max_new=10)
            sched.step()
            sched.step()  # A is prefilled and decoding
            if with_b:
                sched.submit(prompt_b, request_id="b", max_new=4)
            _run(sched, 2 if with_b else 1)
        return {req["id"]: req["generated"] for req in sched.completed}

    alone = run(with_b=False)
    together = run(with_b=True)
    assert together["a"] == alone["a"]
    assert together["b"] == _reference_generate(cfg, mesh, params, prompt_b, 4)


def test_per_slot_positions_after_staggered_attach():
    """Slots attached at different times decode at their own positions."""
    cfg, mesh, params = _serve_fixtures()
    prompt_a, prompt_b = list(range(4, 12)), [30, 31, 32]
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=8), params,
        )
        sched.submit(prompt_a, request_id="a", max_new=32)
        sched.step()   # tick 1: prefill A dispatched (1 chunk = whole prompt)
        sched.step()   # tick 2: A decodes its first step
        slot_a = next(i for i, r in enumerate(sched.active)
                      if r is not None and r["id"] == "a")
        assert sched.pos[slot_a] == len(prompt_a) + 1
        sched.submit(prompt_b, request_id="b", max_new=32)
        sched.step()   # tick 3: A decodes; B prefills
        sched.step()   # tick 4: A and B decode together
        slot_b = next(i for i, r in enumerate(sched.active)
                      if r is not None and r["id"] == "b")
        assert slot_b != slot_a
        assert sched.pos[slot_a] == len(prompt_a) + 3
        assert sched.pos[slot_b] == len(prompt_b) + 1
        sched.drain()


def test_eos_retirement_before_max_new():
    """EOS-based early stop: the deferred readback detects the EOS at a
    flush boundary, truncates anything decoded past it, and frees the slot
    before the count budget is reached."""
    cfg, mesh, params = _serve_fixtures()
    prompt = [9, 10, 11, 12, 13]
    free_run = _reference_generate(cfg, mesh, params, prompt, 8)
    eos = free_run[2]
    assert eos not in free_run[:2]  # make the truncation point unambiguous
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, eos_id=eos, eos_check_every=3),
            params,
        )
        sched.submit(prompt, request_id=0, max_new=8)
        ticks = _run(sched, 1)
    (req,) = sched.completed
    assert req["generated"] == free_run[:3]          # ends at the EOS
    assert len(req["generated"]) < 8                 # retired early
    assert ticks < 12  # the slot was freed well before the budget


def test_drain_runs_to_quiescence():
    """drain() completes EVERYTHING still in the system — a mid-flight
    (partial) prefill AND requests still waiting in the admission queue
    that never attached — so a stopped serve loop never strands work."""
    cfg, mesh, params = _serve_fixtures()
    prompt = list(range(4, 16))  # 12 tokens -> 3 chunks of 4
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=1, prefill_chunk=4), params,
        )
        sched.submit(prompt, request_id=0, max_new=8)
        sched.step()  # one tick: exactly one chunk in
        assert sched._prefills and sched._prefills[0]["done"] == 4
        # a second request arrives and (batch=1) stays in the admission
        # queue — the old drain would have silently dropped it
        sched.submit([20, 21, 22], request_id=1, max_new=4)
        assert sched.queue
        sched.drain()
        assert not sched._prefills and not sched.queue
        assert all(r is None for r in sched.active)
    got = {r["id"]: r["generated"] for r in sched.completed}
    assert got[0] == _reference_generate(cfg, mesh, params, prompt, 8)
    assert got[1] == _reference_generate(cfg, mesh, params, [20, 21, 22], 4)


def test_overlap_on_off_identical_tokens_and_no_decode_gap():
    """The acceptance check: overlapped chunked prefill produces bitwise
    identical tokens to stop-the-world prefill, and while a prefill is in
    flight every tick still dispatches a decode step (no gap > one tick)."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(4, cfg.vocab, size=n).tolist() for n in (10, 14, 5)]

    def run(overlap):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                            overlap=overlap),
                params,
            )
            sched.submit(prompts[0], request_id=0, max_new=8)
            sched.step()
            sched.step()
            for rid in (1, 2):
                sched.submit(prompts[rid], request_id=rid, max_new=8)
            _run(sched, 3)
        return sched

    overlapped = run(True)
    stop_world = run(False)
    toks = lambda s: {r["id"]: r["generated"] for r in s.completed}
    assert toks(overlapped) == toks(stop_world)
    # requests 1/2 prefilled while request 0 was decoding: those ticks exist
    # and no decode dispatch ever ran after prefill work in its tick
    assert overlapped.stats["overlap_ticks"] > 0
    assert overlapped.stats["decode_after_prefill_ticks"] == 0
    # stop-the-world never overlaps — and its decode dispatches DID wait
    # behind synchronous prefills (the stall the overlap removes)
    assert stop_world.stats["overlap_ticks"] == 0
    assert stop_world.stats["decode_after_prefill_ticks"] > 0


def test_scheduler_chunked_prefill_recurrent_hybrid():
    """The masked state advance (dt-zeroing, conv-state gather, frozen SSM
    state for padding and inactive decode slots) must hold on a hybrid
    mamba+attention stack too: chunked prefill with a ragged final chunk
    matches the one-shot reference, and overlap on/off agree exactly."""
    cfg = smoke_config("zamba2-2.7b").replace(
        compute_dtype_name="float32", param_dtype_name="float32"
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    prompts = [list(range(4, 4 + n)) for n in (7, 10)]  # ragged vs chunk=4

    def run(overlap):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                            overlap=overlap),
                params,
            )
            sched.submit(prompts[0], request_id=0, max_new=5)
            sched.step()  # request 0 mid-prefill / decoding...
            sched.submit(prompts[1], request_id=1, max_new=5)
            _run(sched, 2)
        return {r["id"]: r["generated"] for r in sched.completed}

    overlapped = run(True)
    assert overlapped == run(False)
    for rid, p in enumerate(prompts):
        ref = _reference_generate(cfg, mesh, params, p, 5)
        assert overlapped[rid] == ref, (rid, overlapped[rid], ref)


def test_recurrent_hybrid_slot_reuse_matches_reference():
    """More requests than slots on a hybrid mamba+attention arch: a freed
    slot's recurrent state (SSM/conv) must be restored to fresh before the
    next request prefills into it. Attention KV is masked by cache_len, but
    recurrent carries are not — without the reset the reused slots' tokens
    continue from the retired request's final state."""
    cfg = smoke_config("zamba2-2.7b").replace(
        compute_dtype_name="float32", param_dtype_name="float32"
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    prompts = [list(range(4, 4 + n)) for n in (7, 10, 5, 8)]  # 4 reqs, 2 slots
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4), params,
        )
        for rid, p in enumerate(prompts):
            sched.submit(p, request_id=rid, max_new=5)
        _run(sched, len(prompts))
    assert len(sched.completed) == len(prompts)
    for req in sched.completed:
        ref = _reference_generate(cfg, mesh, params, prompts[req["id"]], 5)
        assert req["generated"] == ref, (req["id"], req["generated"], ref)


def test_slot_reuse_matches_fresh_scheduler_xlstm():
    """Slot reuse on an xLSTM stack: the reset must restore INITIAL carry
    values, not zeros (sLSTM's stabilizer m starts at -1e30). Identity
    check against a fresh scheduler (same jitted steps, so any stale or
    mis-reset state shows up as a token difference)."""
    cfg = smoke_config("xlstm-350m").replace(
        compute_dtype_name="float32", param_dtype_name="float32", repeats=1
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    prompt_a, prompt_b = [5, 6, 7, 8, 9], [20, 21, 22]

    def run(submit_a):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=1, prefill_chunk=4), params,
            )
            if submit_a:
                sched.submit(prompt_a, request_id="a", max_new=4)
            sched.submit(prompt_b, request_id="b", max_new=6)
            _run(sched, 2 if submit_a else 1)
        return {r["id"]: r["generated"] for r in sched.completed}

    reused = run(submit_a=True)       # "b" runs in the slot "a" retired from
    fresh = run(submit_a=False)       # "b" runs in a never-used slot
    assert reused["b"] == fresh["b"], (reused["b"], fresh["b"])


def test_masked_decode_freezes_inactive_slots_mlstm():
    """Batched masked decode on an mLSTM/sLSTM stack with batch != n_heads:
    the per-slot freeze masks must broadcast over the head axis (a (B,) mask
    against (B,h) carries), inactive slots' state stays bitwise frozen, and
    active slots match the unmasked step exactly."""
    cfg = smoke_config("xlstm-350m").replace(
        compute_dtype_name="float32", param_dtype_name="float32", repeats=1
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    Bs, plen = 3, 6  # 3 slots vs n_heads=4: a wrong-axis broadcast cannot hide
    toks = jax.random.randint(jax.random.PRNGKey(2), (Bs, plen), 4, cfg.vocab)
    with mesh:
        caches = T.init_cache(cfg, Bs, 16)
        _, caches = make_prefill_step(cfg, mesh)(params, {"tokens": toks}, caches)
        step_tok = jax.random.randint(jax.random.PRNGKey(3), (Bs, 1), 4, cfg.vocab)
        pos = jnp.full((Bs,), plen, jnp.int32)
        logits_m, caches_m = T.decode_step(
            params, step_tok, pos, cfg, caches,
            active=jnp.asarray([True, False, True]),
        )
        logits_u, caches_u = T.decode_step(params, step_tok, pos, cfg, caches)
    for before, masked, unmasked in zip(
        jax.tree_util.tree_leaves(caches),
        jax.tree_util.tree_leaves(caches_m),
        jax.tree_util.tree_leaves(caches_u),
    ):
        before, masked, unmasked = map(np.asarray, (before, masked, unmasked))
        np.testing.assert_array_equal(  # inactive slot: no state advance
            masked[:, 1], before[:, 1]
        )
        np.testing.assert_array_equal(  # active slots: same as unmasked
            masked[:, [0, 2]], unmasked[:, [0, 2]]
        )
    np.testing.assert_array_equal(
        np.asarray(logits_m)[[0, 2]], np.asarray(logits_u)[[0, 2]]
    )


def test_stale_seed_dropped_on_reattach():
    """A request retiring in the same tick its prefill completes leaves its
    next-token seed queued; if the freed slot is immediately reattached, the
    stale seed must not race the new request's seed in the scatter."""
    cfg, mesh, params = _serve_fixtures()
    with mesh:
        sched = BatchScheduler(cfg, mesh, ServeConfig(max_len=64, batch=1), params)
        sched.submit([5, 6, 7], request_id="a", max_new=1)
        _run(sched, 1)  # retires at its prefill-completion flush
        # empty prompt: the reattached slot seeds directly (no prefill), the
        # exact duplicate-scatter window the stale seed could race
        sched.submit([], request_id="b", max_new=4)
        _run(sched, 2)
        got = {r["id"]: r["generated"] for r in sched.completed}

        fresh = BatchScheduler(cfg, mesh, ServeConfig(max_len=64, batch=1), params)
        fresh.submit([], request_id="b", max_new=4)
        _run(fresh, 1)
    (ref,) = [r["generated"] for r in fresh.completed]
    assert got["b"] == ref, (got["b"], ref)


# ---------------------------------------------------------------------------
# paged KV cache: shared page pool + per-slot block tables
# ---------------------------------------------------------------------------
# NOTE: every scheduler test above already runs the paged layout — it is the
# ServeConfig default. The tests below pin the paged-specific guarantees:
# bitwise paged/dense identity, allocator lifecycle, exhaustion behavior.


def test_paged_matches_dense_tokens_overlap_on_off():
    """The tentpole acceptance criterion: the paged KV cache produces
    bitwise-identical tokens to the dense layout, with overlap on AND off,
    on a staggered multi-request trace with slot reuse."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(11)
    prompts = [rng.integers(4, cfg.vocab, size=n).tolist()
               for n in (10, 17, 5, 8)]  # 4 requests > 2 slots

    def run(paged, overlap):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                            overlap=overlap, paged=paged, page_size=16),
                params,
            )
            sched.submit(prompts[0], request_id=0, max_new=7)
            sched.step()  # request 0 mid-prefill when the rest arrive
            for rid in (1, 2, 3):
                sched.submit(prompts[rid], request_id=rid, max_new=7)
            _run(sched, len(prompts))
        return {r["id"]: r["generated"] for r in sched.completed}

    dense = run(paged=False, overlap=True)
    for overlap in (True, False):
        paged = run(paged=True, overlap=overlap)
        assert paged == dense, (overlap, paged, dense)


@pytest.mark.parametrize("arch", ["gemma2-2b", "zamba2-2.7b"])
def test_paged_scheduler_matches_reference_small_pages(arch):
    """Paged-vs-reference token identity with page_size SMALLER than the
    attention span: gemma2 runs a sliding window (5) that crosses every
    page boundary (page_size 4), zamba2 covers the hybrid mamba+attention
    stack (recurrent state stays dense per slot while attention pages).
    More requests than slots also exercises block free/realloc on reuse."""
    cfg = smoke_config(arch).replace(
        compute_dtype_name="float32", param_dtype_name="float32",
        **({"window": 5} if arch == "gemma2-2b" else {}),
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(4, cfg.vocab, size=n).tolist() for n in (3, 9, 14, 6)]
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                        paged=True, page_size=4),
            params,
        )
        for rid, p in enumerate(prompts):
            sched.submit(p, request_id=rid, max_new=6)
        _run(sched, len(prompts))
    assert len(sched.completed) == len(prompts)
    for req in sched.completed:
        ref = _reference_generate(cfg, mesh, params, prompts[req["id"]], 6)
        assert req["generated"] == ref, (req["id"], req["generated"], ref)


def test_paged_allocator_frees_and_reallocates_on_slot_reuse():
    """Block lifecycle with more requests than slots: pages are allocated
    as prefill/decode write, freed when a request retires, and the freed
    pages back the next request — the pool never leaks and the block
    tables of retired slots are fully cleared."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(4, cfg.vocab, size=n).tolist()
               for n in (20, 9, 18, 5)]  # 4 requests, 2 slots
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            # pool sized so 4 requests can only complete if retirement
            # actually recycles pages: 2 slots x ceil((20+6)/8) = 8 pages
            ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                        paged=True, page_size=8, num_pages=8),
            params,
        )
        for rid, p in enumerate(prompts):
            sched.submit(p, request_id=rid, max_new=6)
        _run(sched, len(prompts))
    assert len(sched.completed) == len(prompts)
    alloc = sched._alloc
    assert alloc.used == 0, "pages leaked past request retirement"
    assert alloc.peak_used > 0
    assert alloc.peak_used <= alloc.num_pages
    assert (sched._tables == -1).all()
    stats = sched.kv_cache_stats()
    assert stats["layout"] == "paged" and stats["pages_in_use"] == 0
    assert stats["peak_used_pages"] == alloc.peak_used
    # and the recycled pool still produced reference tokens
    for req in sched.completed:
        ref = _reference_generate(cfg, mesh, params, prompts[req["id"]], 6)
        assert req["generated"] == ref, (req["id"], req["generated"], ref)


def test_paged_pool_exhaustion_raises_clean_error():
    """With preempt_policy="never" a dry pool must fail the requester
    loudly BEFORE handing out any page — never remap a neighbor's pages —
    and the failed request must be fully unwound (every page it already
    held released, no leak). The neighbor keeps running correctly
    afterwards. Pool math: 3 pages of 8; "a" (prompt 4, max_new 12) holds
    page 0 and asks for its second page at decode position 8 on the tick
    after "b"'s prefill (20 tokens) has taken the other two — "a" fails,
    "b" completes against the reference."""
    cfg, mesh, params = _serve_fixtures()
    prompt_a, prompt_b = [5, 6, 7, 8], list(range(4, 24))
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                        paged=True, page_size=8, num_pages=3,
                        preempt_policy="never"),
            params,
        )
        sched.submit(prompt_a, request_id="a", max_new=12)
        sched.step()  # "a" owns page 0 (prompt) — 2 pages left
        sched.submit(prompt_b, request_id="b", max_new=4)
        with pytest.raises(RuntimeError, match="exhausted"):
            _run(sched, 2)
        # "a" failed mid-decode and was unwound: its page is back in the
        # free list (the no-leak guarantee) and only "b"'s prefill pages
        # remain live
        (req_a,) = sched.failed
        assert req_a["id"] == "a" and req_a["_status"] == "failed"
        assert sched._alloc.used == 2
        _run(sched, 1)
    got = {r["id"]: r["generated"] for r in sched.completed}
    assert got["b"] == _reference_generate(cfg, mesh, params, prompt_b, 4)
    assert sched._alloc.used == 0, "pages leaked past retirement"
    # whatever "a" produced before failing is a clean prefix of its
    # reference stream — the unwind never corrupted its (or b's) pages
    ref = _reference_generate(cfg, mesh, params, prompt_a, 12)
    assert req_a["generated"] == ref[: len(req_a["generated"])]


def test_paged_rejects_indivisible_max_len():
    cfg, mesh, params = _serve_fixtures()
    with pytest.raises(ValueError, match="divisible"):
        BatchScheduler(
            cfg, mesh, ServeConfig(max_len=60, batch=2, page_size=16), params
        )


# ---------------------------------------------------------------------------
# sampling: temperature/top-k with per-request on-device PRNG keys
# ---------------------------------------------------------------------------


def test_sampling_deterministic_and_reset_on_slot_reuse():
    """With greedy=False the decode/prefill-chunk steps sample on device
    from ``fold_in(request_key, position)`` — stateless, so a request's
    stream depends only on (params, prompt, request_id, seed): running it after
    a predecessor retired from the slot must reproduce the fresh-scheduler
    stream exactly."""
    cfg, mesh, params = _serve_fixtures()
    scfg = ServeConfig(max_len=64, batch=1, prefill_chunk=4,
                       greedy=False, temperature=0.8, top_k=20, sample_seed=3)
    prompt_a, prompt_b = [5, 6, 7, 8, 9], [20, 21, 22]

    def run(submit_a):
        with mesh:
            sched = BatchScheduler(cfg, mesh, scfg, params)
            if submit_a:
                sched.submit(prompt_a, request_id="a", max_new=5)
            sched.submit(prompt_b, request_id="b", max_new=8)
            _run(sched, 2 if submit_a else 1)
        return {r["id"]: r["generated"] for r in sched.completed}

    reused = run(submit_a=True)    # "b" samples in the slot "a" retired from
    fresh = run(submit_a=False)    # "b" samples in a never-used slot
    assert reused["b"] == fresh["b"], (reused["b"], fresh["b"])
    # determinism: the same scheduler run twice is bitwise repeatable
    assert run(submit_a=True) == reused
    # sampled ids stay inside the real vocab (padded ids are masked out)
    for toks in reused.values():
        assert all(0 <= t < cfg.vocab for t in toks)


def test_sampling_independent_of_coresident_traffic():
    """A sampled request's stream must not depend on what the OTHER slots
    are doing: attaching it late (after another request decoded for a few
    ticks) or toggling overlap must reproduce the solo stream bit for bit.
    The stateless fold_in(request_key, position) keying guarantees it — a
    carried-and-split key would advance with every batched decode and
    fail this."""
    cfg, mesh, params = _serve_fixtures()
    prompt_x, prompt_b = list(range(4, 14)), [20, 21, 22]

    def scfg(overlap=True):
        return ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                           greedy=False, temperature=0.8, top_k=20,
                           sample_seed=3, overlap=overlap)

    def stream_of_b(sched, late):
        sched.submit(prompt_x, request_id="x", max_new=10)
        if late:
            sched.step()
            sched.step()  # x decodes alone for a while
        sched.submit(prompt_b, request_id="b", max_new=6)
        _run(sched, 2)
        return {r["id"]: r["generated"] for r in sched.completed}["b"]

    with mesh:
        # solo-ish baseline: b attaches immediately alongside x
        base = stream_of_b(BatchScheduler(cfg, mesh, scfg(), params), late=False)
        late = stream_of_b(BatchScheduler(cfg, mesh, scfg(), params), late=True)
        sw = stream_of_b(BatchScheduler(cfg, mesh, scfg(False), params),
                         late=True)
    assert base == late, (base, late)
    assert late == sw, (late, sw)


def test_sampling_greedy_flag_matches_historical_argmax():
    """greedy=True (the default) must stay bitwise identical to the
    pre-sampling scheduler — the reference generator IS the historical
    argmax path."""
    cfg, mesh, params = _serve_fixtures()
    prompt = [9, 10, 11, 12]
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, temperature=0.7, top_k=5),
            params,
        )  # temperature/top_k are inert while greedy=True
        sched.submit(prompt, request_id=0, max_new=6)
        _run(sched, 1)
    (req,) = sched.completed
    assert req["generated"] == _reference_generate(cfg, mesh, params, prompt, 6)


# ---------------------------------------------------------------------------
# cross-request prefix cache: radix trie + copy-on-write pages
# ---------------------------------------------------------------------------
# The guarantee under test everywhere below: prefix sharing is a pure
# memory/compute optimization — generated tokens are bitwise identical with
# the cache on or off, because a shared page holds exactly the K/V the
# request would have prefilled itself.


def _run_shared_prefix(cfg, mesh, params, prompts, *, prefix_cache,
                       page_size=8, num_pages=None, prefill_chunk=4,
                       max_new=6, batch=2, trie_capacity=None):
    """Warm-first schedule: request 0 completes (inserting its prompt pages
    into the trie when sharing is on), then the rest attach against it."""
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=batch, prefill_chunk=prefill_chunk,
                        paged=True, page_size=page_size, num_pages=num_pages,
                        prefix_cache=prefix_cache,
                        prefix_trie_capacity=trie_capacity),
            params,
        )
        sched.submit(prompts[0], request_id=0, max_new=max_new)
        _run(sched, 1)
        for rid, p in enumerate(prompts[1:], start=1):
            sched.submit(p, request_id=rid, max_new=max_new)
        _run(sched, len(prompts))
    return sched


def _tokens(sched):
    return {r["id"]: r["generated"] for r in sched.completed}


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-2b", "zamba2-2.7b"])
def test_prefix_sharing_identical_tokens(arch):
    """Sharing on/off bitwise token identity on a shared-system-prompt
    workload — across a plain KV stack, a sliding window SMALLER than the
    shared prefix (gemma2: the window crosses shared-page boundaries), and
    a hybrid mamba+attention stack (zamba2: attention pages are shared for
    the memory win but no prefill compute is skipped, because the recurrent
    state must still advance over every prompt token)."""
    if arch == "tinyllama-1.1b":
        cfg, mesh, params = _serve_fixtures()
    else:
        cfg = smoke_config(arch).replace(
            compute_dtype_name="float32", param_dtype_name="float32",
            **({"window": 5} if arch == "gemma2-2b" else {}),
        )
        mesh = make_host_mesh()
        params = init_params(
            T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype
        )
    rng = np.random.default_rng(13)
    system = rng.integers(4, cfg.vocab, size=24).tolist()  # 3 pages of 8
    prompts = [system + rng.integers(4, cfg.vocab, size=int(n)).tolist()
               for n in rng.integers(3, 8, size=5)]

    on = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=True)
    off = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=False)
    assert _tokens(on) == _tokens(off)
    pc = on.kv_cache_stats()["prefix_cache"]
    assert pc["hits"] == len(prompts) - 1  # everyone after the warmup hits
    assert pc["pages_saved_by_sharing"] > 0
    if arch == "zamba2-2.7b":
        # hybrid: pages shared (memory), no compute skipped (the recurrent
        # state has no positional mask to fast-forward through)
        assert pc["prefill_tokens_skipped"] == 0
    else:
        assert pc["prefill_tokens_skipped"] > 0
        assert on.stats["prefill_chunks"] < off.stats["prefill_chunks"]
    # strictly fewer live pages at peak, trie pins included
    assert (on.kv_cache_stats()["peak_used_pages"]
            < off.kv_cache_stats()["peak_used_pages"])


def test_prefix_cow_mid_page_divergence():
    """Prompts diverging MID-page: the fully-matched pages are shared
    read-only, the partially-matched page is copy-on-write (fresh page,
    device copy of the donor's rows, divergent tokens prefilled over the
    tail) — and the tokens still match the no-sharing run exactly."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(17)
    common = rng.integers(4, cfg.vocab, size=20).tolist()  # 2.5 pages of 8
    prompts = [common + rng.integers(4, cfg.vocab, size=4).tolist()
               for _ in range(3)]  # diverge at token 20, mid-page 2

    on = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=True)
    off = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=False)
    assert _tokens(on) == _tokens(off)
    pc = on.kv_cache_stats()["prefix_cache"]
    assert pc["cow_copies"] >= 1
    assert pc["hit_tokens"] >= 20  # 2 full pages + 4 donor rows per hit


def test_prefix_refcounts_no_leak_under_churn():
    """Slot-reuse churn with sharing on: after every request retires, the
    only pages still allocated are the trie's own pins (one reference
    each); clear() then returns the pool to empty and the block tables of
    all slots are fully cleared — no leaked references either way."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(19)
    system = rng.integers(4, cfg.vocab, size=16).tolist()
    prompts = [system + rng.integers(4, cfg.vocab, size=int(n)).tolist()
               for n in rng.integers(3, 8, size=8)]  # 8 requests, 2 slots

    sched = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=True)
    assert len(sched.completed) == len(prompts)
    alloc, trie = sched._alloc, sched._prefix
    assert alloc.used == trie.size, "pages leaked past request retirement"
    assert all(c == 1 for c in alloc.refs.values()), (
        "dangling non-trie references after all requests retired"
    )
    assert (sched._tables == -1).all()
    trie.clear()
    assert alloc.used == 0 and trie.size == 0
    assert not alloc.refs


def test_prefix_trie_eviction_under_pool_pressure():
    """A pool too small to hold every retired prompt's pages forces LRU
    trie eviction on attach; the evicted entries' neighbors (still-cached
    prefixes AND in-flight requests) are unharmed — every request still
    matches the no-sharing tokens, and eviction provably happened."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(23)
    # 4 DISTINCT 16-token prompts (2 pages each) + decode growth vs an
    # 8-page pool: the trie cannot keep them all pinned
    prompts = [rng.integers(4, cfg.vocab, size=16).tolist() for _ in range(4)]

    on = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=True,
                            num_pages=8, batch=2)
    off = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=False,
                             num_pages=8, batch=2)
    assert _tokens(on) == _tokens(off)
    pc = on.kv_cache_stats()["prefix_cache"]
    assert pc["evicted_pages"] >= 1
    assert on._alloc.used == on._prefix.size  # pins accounted, nothing leaked


def test_prefix_trie_capacity_lru_trim():
    """prefix_trie_capacity bounds the trie's pinned pages: inserts past
    the cap LRU-trim other paths, size never exceeds the cap, and sharing
    still works for the prefixes that stay resident."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(29)
    system = rng.integers(4, cfg.vocab, size=16).tolist()
    prompts = [system + rng.integers(4, cfg.vocab, size=int(n)).tolist()
               for n in rng.integers(3, 8, size=5)]

    sched = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=True,
                               trie_capacity=2)
    off = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=False)
    assert _tokens(sched) == _tokens(off)
    assert sched._prefix.size <= 2
    assert sched.kv_cache_stats()["prefix_cache"]["hits"] > 0


def test_prefix_cache_requires_paged_layout():
    """ServeConfig must reject prefix_cache on the dense layout at
    construction — a shared page cannot be expressed in (batch, max_len)
    buffers, and failing at attach time would be far harder to debug."""
    with pytest.raises(ValueError, match="paged"):
        ServeConfig(max_len=64, batch=2, paged=False, prefix_cache=True)


def test_prefix_sharing_sampled_streams_identical():
    """Sampling composes with sharing: streams are keyed on
    fold_in(request_key, position) — a function of the request and the
    position it samples, not of how the KV for earlier positions got
    there — so sampled tokens are bitwise identical with sharing on or
    off."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(31)
    system = rng.integers(4, cfg.vocab, size=16).tolist()
    prompts = [system + rng.integers(4, cfg.vocab, size=int(n)).tolist()
               for n in rng.integers(3, 8, size=4)]

    def run(prefix_cache):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                            paged=True, page_size=8,
                            prefix_cache=prefix_cache,
                            greedy=False, temperature=0.8, top_k=20,
                            sample_seed=3),
                params,
            )
            sched.submit(prompts[0], request_id=0, max_new=6)
            _run(sched, 1)
            for rid, p in enumerate(prompts[1:], start=1):
                sched.submit(p, request_id=rid, max_new=6)
            _run(sched, len(prompts))
        return _tokens(sched)

    assert run(True) == run(False)


def test_batch_scheduler_batches_token_readback(monkeypatch):
    """Decode steps must NOT pay one host round-trip each: readbacks are
    deferred and flushed in a single device_get at completion boundaries."""
    cfg, mesh, params = _serve_fixtures()
    calls = {"n": 0}
    real_get = jax.device_get

    def counting_get(x):
        calls["n"] += 1
        return real_get(x)

    with mesh:
        sched = BatchScheduler(cfg, mesh, ServeConfig(max_len=64, batch=2), params)
        for rid in range(4):
            sched.submit([1, 2, 3], request_id=rid, max_new=6)
        monkeypatch.setattr("repro.serve.serve.jax.device_get", counting_get)
        steps = 0
        while len(sched.completed) < 4 and steps < 64:
            sched.step()
            steps += 1
        sched.drain()
    assert len(sched.completed) == 4
    # 4 requests x 6 tokens: per-step readback would pay >= 20 transfers;
    # deferred flushing pays at most one per request-completion boundary
    # (completions stagger by one tick because prefills serialize at one
    # chunk per tick) + the drain
    assert steps >= 12
    assert calls["n"] <= 5, f"{calls['n']} readbacks in {steps} steps"
    for req in sched.completed:
        assert len(req["generated"]) == 6


# ---------------------------------------------------------------------------
# admission queue, preemption under memory pressure, recompute-resume
# ---------------------------------------------------------------------------
# The guarantee under test: preemption is a pure scheduling decision — a
# preempted request's resumed stream is bitwise identical to an ample-pool
# run (recompute rebuilds the prompt KV on the same chunk grid and replays
# the generated history through ordinary decode steps), and neighbors never
# see a difference.


def _run_under_pressure(cfg, mesh, params, prompts, *, num_pages,
                        max_new=8, greedy=True, page_size=8,
                        prefill_chunk=4, batch=2, policy="priority"):
    kw = {} if greedy else dict(greedy=False, temperature=0.8, top_k=20,
                                sample_seed=3)
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=batch, prefill_chunk=prefill_chunk,
                        paged=True, page_size=page_size, num_pages=num_pages,
                        preempt_policy=policy, **kw),
            params,
        )
        for rid, p in enumerate(prompts):
            sched.submit(p, request_id=rid, max_new=max_new)
        sched.drain()
    return sched


@pytest.mark.parametrize("greedy", [True, False])
def test_preempt_resume_identity(greedy):
    """Forced preemption: a 3-page pool cannot hold two 2-page requests, so
    the younger parks itself mid-decode and resumes after the older
    retires — and every token stream is bitwise identical to an ample-pool
    run, greedy AND sampled (per-request sampling keys make the stream
    independent of the slot it resumes into)."""
    cfg, mesh, params = _serve_fixtures()
    prompts = [list(range(4, 12)), list(range(20, 28))]  # 1 page each, grow to 2

    ample = _run_under_pressure(cfg, mesh, params, prompts, num_pages=16,
                                greedy=greedy)
    tight = _run_under_pressure(cfg, mesh, params, prompts, num_pages=3,
                                greedy=greedy)
    assert tight.stats["preemptions"] > 0, "pressure never materialized"
    assert tight.stats["resumes"] > 0
    assert _tokens(tight) == _tokens(ample)
    assert tight._alloc.used == 0, "pages leaked across preempt/resume"
    press = tight.kv_cache_stats()["pressure"]
    assert press["preemptions"] == tight.stats["preemptions"]
    assert press["pages_freed_by_preempt"] > 0


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "xlstm-350m"])
def test_preempt_resume_identity_recurrent(arch):
    """Recompute-resume on recurrent/hybrid stacks: state has no positional
    masking, so resume must re-run it over EVERY token — the full prompt
    through the chunked prefill (the PR 6 done=0 rule) and the generated
    history through replayed decode steps. Tokens must match the
    ample-pool run exactly."""
    cfg = smoke_config(arch).replace(
        compute_dtype_name="float32", param_dtype_name="float32",
        **({"repeats": 1} if arch == "xlstm-350m" else {}),
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    prompts = [list(range(4, 12)), list(range(20, 28))]

    ample = _run_under_pressure(cfg, mesh, params, prompts, num_pages=16,
                                max_new=6)
    tight = _run_under_pressure(cfg, mesh, params, prompts, num_pages=3,
                                max_new=6)
    assert tight.stats["preemptions"] > 0, "pressure never materialized"
    assert _tokens(tight) == _tokens(ample)
    for rid, p in enumerate(prompts):
        ref = _reference_generate(cfg, mesh, params, p, 6)
        assert _tokens(tight)[rid] == ref, (rid, _tokens(tight)[rid], ref)


def test_victim_selection_policies():
    """_pick_victim unit semantics: only strictly-younger (or strictly
    lower-priority) occupants are eligible — the oldest request can never
    be evicted by a newcomer — and each policy orders the eligible set as
    documented."""
    cfg, mesh, params = _serve_fixtures()

    def scheduler(policy):
        with mesh:
            s = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=4, paged=True, page_size=8,
                            num_pages=32, preempt_policy=policy),
                params,
            )
        # hand-place occupants; submit() assigns _seq in call order
        prios = {"w": 0, "x": 0, "y": 0, "z": 1}
        for rid in ("w", "x", "y", "z"):
            s.submit([1, 2, 3], request_id=rid, max_new=4,
                     priority=prios[rid])
        reqs = {r["id"]: r for r in s.queue}
        s.queue.clear()
        for slot, rid in enumerate(("w", "x", "y", "z")):
            s.active[slot] = reqs[rid]
        s._slot_pages[0] = [0]              # w: oldest
        s._slot_pages[1] = [1, 2, 3]        # x: most pages
        s._slot_pages[2] = [4, 5]           # y
        s._slot_pages[3] = [6, 7]           # z: higher priority class
        reqs["x"]["generated"] = [9]        # x: some progress
        reqs["y"]["generated"] = []         # y: least progress
        return s, reqs

    s, reqs = scheduler("priority")
    # requester w (oldest, prio 0): z is NOT eligible (higher priority);
    # among x/y the cheapest class ties and most-pages wins -> x (slot 1)
    assert s._pick_victim(reqs["w"]) == 1
    s, reqs = scheduler("pages")
    assert s._pick_victim(reqs["w"]) == 1   # most pages outright
    s, reqs = scheduler("progress")
    assert s._pick_victim(reqs["w"]) == 2   # y lost the least work
    # anti-livelock: the NEWEST same-priority request sees no eligible
    # victim at all (everyone is older) — it must park itself instead
    s, reqs = scheduler("priority")
    assert s._pick_victim(reqs["y"]) is None
    # ...but a high-priority newcomer may evict older lower-priority work
    assert s._pick_victim(reqs["z"]) == 1


def test_mid_stream_cancel_frees_pages_neighbors_unaffected():
    """cancel() mid-decode frees the victim's pages immediately, leaves
    the prefix trie's own pins resident, and does not perturb the
    co-resident request's stream by a single bit."""
    cfg, mesh, params = _serve_fixtures()
    prompt_a, prompt_b = list(range(4, 14)), list(range(30, 38))

    def run(with_cancel):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                            paged=True, page_size=8, prefix_cache=True),
                params,
            )
            sched.submit(prompt_a, request_id="a", max_new=10)
            handle_b = sched.submit(prompt_b, request_id="b", max_new=10)
            for _ in range(7):  # both prefilled; b decoding mid-stream
                sched.step()
            if with_cancel:
                used_before = sched._alloc.used
                trie_before = sched._prefix.size
                assert handle_b.cancel()
                assert not handle_b.cancel()  # idempotent: already closed
                assert handle_b.status == "cancelled" and handle_b.done
                assert sched._alloc.used < used_before  # pages freed NOW
                assert sched._prefix.size == trie_before  # pins unharmed
            sched.drain()
        return sched

    full = run(False)
    cut = run(True)
    a_full = {r["id"]: r["generated"] for r in full.completed}["a"]
    a_cut = {r["id"]: r["generated"] for r in cut.completed}["a"]
    assert a_cut == a_full, "cancel perturbed the co-resident stream"
    assert [r["id"] for r in cut.cancelled] == ["b"]
    assert all(r["id"] != "b" for r in cut.completed)
    assert cut.stats["cancellations"] == 1
    # nothing leaked: only the trie's pins remain after drain
    assert cut._alloc.used == cut._prefix.size
    # cancelling a request still waiting in the admission queue works too
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=1, prefill_chunk=4), params,
        )
        sched.submit(prompt_a, request_id="a", max_new=4)
        hq = sched.submit(prompt_b, request_id="q", max_new=4)  # queued
        sched.step()
        assert hq.cancel() and not sched.queue
        sched.drain()
    assert {r["id"] for r in sched.completed} == {"a"}


def test_priority_preempts_lower_and_both_match_reference():
    """A strictly-higher-priority arrival behind a full batch evicts the
    lowest-priority occupant; the evicted request resumes afterwards and
    BOTH streams match the stop-the-world reference exactly."""
    cfg, mesh, params = _serve_fixtures()
    prompt_lo, prompt_hi = list(range(4, 12)), list(range(20, 26))
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=1, prefill_chunk=4,
                        paged=True, page_size=8),
            params,
        )
        h_lo = sched.submit(prompt_lo, request_id="lo", max_new=8, priority=0)
        for _ in range(4):
            sched.step()  # lo prefilled and decoding
        h_hi = sched.submit(prompt_hi, request_id="hi", max_new=6, priority=5)
        assert h_hi.result() == _reference_generate(
            cfg, mesh, params, prompt_hi, 6
        )
        sched.drain()
    assert sched.stats["preemptions"] >= 1
    assert sched.stats["resumes"] >= 1
    assert sched.kv_cache_stats()["pressure"]["peak_queue_depth"] >= 1
    assert h_lo.status == "done"
    assert h_lo.tokens == _reference_generate(cfg, mesh, params, prompt_lo, 8)
    # "hi" finished before "lo" despite arriving later: priority worked
    order = [r["id"] for r in sched.completed]
    assert order.index("hi") < order.index("lo")


def test_stream_async_interleaves_two_requests():
    """stream_async: two concurrent consumers over one scheduler, each
    driving shared ticks — both streams complete and match the greedy
    reference."""
    import asyncio

    cfg, mesh, params = _serve_fixtures()
    prompts = {"a": list(range(4, 12)), "b": list(range(20, 27))}
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4), params,
        )
        for rid, p in prompts.items():
            sched.submit(p, request_id=rid, max_new=5)

        async def collect(rid):
            return [t async for t in sched.stream_async(rid)]

        async def main():
            return await asyncio.gather(collect("a"), collect("b"))

        got_a, got_b = asyncio.run(main())
        sched.drain()
    assert got_a == _reference_generate(cfg, mesh, params, prompts["a"], 5)
    assert got_b == _reference_generate(cfg, mesh, params, prompts["b"], 5)


def _stall_request(sched, request_id):
    """Wedge a request: parked with a ready tick the scheduler will never
    reach — the shape of a stalled retry backoff or a lost resume."""
    req = sched._by_id[request_id]
    sched.queue.remove(req)
    req["_status"] = "retrying"
    req["_not_before"] = 10**9
    sched._parked.append(req)


def test_result_and_stream_timeout_raise():
    """``result(timeout=)``/``stream(timeout=)`` bound the scheduler ticks
    spent waiting between tokens: a wedged request raises ``TimeoutError``
    instead of spinning, and the default (no timeout) still raises the
    livelock ``RuntimeError`` eventually rather than hanging."""
    cfg, mesh, params = _serve_fixtures()
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4, paged=True,
                        page_size=8), params,
        )
        handle = sched.submit(list(range(4, 12)), request_id=0, max_new=4)
        _stall_request(sched, 0)
        with pytest.raises(TimeoutError, match="no progress"):
            handle.result(timeout=5)
        with pytest.raises(TimeoutError, match="no progress"):
            next(iter(handle.stream(timeout=3)))
    assert handle.status == "retrying", "timeout must not kill the request"


def test_drain_nonquiescence_raises_with_stats():
    """drain() on a scheduler that cannot reach quiescence raises a
    descriptive ``RuntimeError`` carrying the kv_cache_stats snapshot —
    the bug report IS the error message."""
    cfg, mesh, params = _serve_fixtures()
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4, paged=True,
                        page_size=8), params,
        )
        sched.submit(list(range(4, 12)), request_id=0, max_new=4)
        _stall_request(sched, 0)
        with pytest.raises(RuntimeError) as exc:
            sched.drain()
    msg = str(exc.value)
    assert "no quiescence" in msg and "parked=1" in msg
    assert "kv_cache_stats" in msg and "'recovery'" in msg
