"""Serving correctness: decode path must agree with the full forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.layers.common import init_params
from repro.models import transformer as T
from repro.launch.mesh import make_host_mesh
from repro.serve.serve import BatchScheduler, ServeConfig, make_decode_step, make_prefill_step


@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b", "gemma2-2b", "qwen3-moe-30b-a3b", "zamba2-2.7b",
    "xlstm-350m",
])
def test_decode_matches_forward_logits(arch):
    """Prefill+decode must reproduce the teacher-forced forward logits —
    the strongest end-to-end consistency check for every cache type
    (KV, conv, ssm, mLSTM, sLSTM)."""
    cfg = smoke_config(arch)
    if arch in ("zamba2-2.7b", "xlstm-350m"):
        # chunked-prefill vs stepwise-decode recurrences are mathematically
        # identical but round differently; the recurrent denominators
        # (mLSTM max(|q.n|, exp(-m))) amplify reassociation noise roughly
        # exponentially with depth. Run the cache-logic consistency check
        # in f32 at one pattern repeat — deeper stacks diverge numerically,
        # not logically (see DESIGN.md numerics notes).
        cfg = cfg.replace(compute_dtype_name="float32",
                          param_dtype_name="float32")
    if arch == "xlstm-350m":
        cfg = cfg.replace(repeats=1)
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    Bs, prompt_len, total = 2, 16, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (Bs, total), 4, cfg.vocab)

    # xlstm: jit-vs-eager op fusion perturbs the mLSTM state slightly and
    # its denominator amplifies that; keep both sides in the same
    # compilation mode so the check isolates cache logic.
    jit_ = (lambda f: f) if arch == "xlstm-350m" else jax.jit
    with mesh:
        full_logits, _ = jit_(lambda p, b: T.apply_logits(p, b, cfg))(
            params, {"tokens": toks}
        )
        caches = T.init_cache(cfg, Bs, total + 8)
        _, caches = jit_(make_prefill_step(cfg, mesh))(
            params, {"tokens": toks[:, :prompt_len]}, caches
        )
        decode = jax.jit(make_decode_step(cfg, mesh))
        errs = []
        for i in range(prompt_len, total):
            logits, caches = T.decode_step(
                params, toks[:, i : i + 1], jnp.asarray(i, jnp.int32), cfg, caches
            )
            err = np.max(np.abs(
                np.asarray(logits, np.float32)
                - np.asarray(full_logits[:, i], np.float32)
            ))
            errs.append(err)
    assert max(errs) < 0.1, f"{arch}: decode/forward divergence {max(errs)}"


def test_prefill_last_logits_match_forward():
    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 4, cfg.vocab)
    with mesh:
        full_logits, _ = T.apply_logits(params, {"tokens": toks}, cfg)
        caches = T.init_cache(cfg, 2, 32)
        next_tok, _ = make_prefill_step(cfg, mesh)(params, {"tokens": toks}, caches)
    expected = np.argmax(np.asarray(full_logits[:, -1], np.float32), axis=-1)
    np.testing.assert_array_equal(np.asarray(next_tok), expected)


def test_batch_scheduler_completes_requests():
    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    with mesh:
        sched = BatchScheduler(cfg, mesh, ServeConfig(max_len=64, batch=2), params)
        for rid in range(4):
            sched.submit([1, 2, 3], request_id=rid, max_new=5)
        for _ in range(64):
            sched.step()
            if len(sched.completed) == 4:
                break
    assert len(sched.completed) == 4
    for req in sched.completed:
        assert len(req["generated"]) == 5
        assert all(0 <= t < cfg.vocab_padded for t in req["generated"])


def test_batch_scheduler_batches_token_readback(monkeypatch):
    """Decode steps must NOT pay one host round-trip each: readbacks are
    deferred and flushed in a single device_get at completion boundaries."""
    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    calls = {"n": 0}
    real_get = jax.device_get

    def counting_get(x):
        calls["n"] += 1
        return real_get(x)

    with mesh:
        sched = BatchScheduler(cfg, mesh, ServeConfig(max_len=64, batch=2), params)
        for rid in range(4):
            sched.submit([1, 2, 3], request_id=rid, max_new=6)
        monkeypatch.setattr("repro.serve.serve.jax.device_get", counting_get)
        steps = 0
        while len(sched.completed) < 4 and steps < 64:
            sched.step()
            steps += 1
        sched.drain()
    assert len(sched.completed) == 4
    # 2 waves x 6 decode steps: the old code paid >= 12 transfers; deferred
    # flushing pays one per completion boundary (+ the no-op drain)
    assert steps >= 12
    assert calls["n"] <= 3, f"{calls['n']} readbacks in {steps} steps"
    for req in sched.completed:
        assert len(req["generated"]) == 6
