"""Serving correctness: decode path must agree with the full forward pass."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.layers.common import init_params
from repro.models import transformer as T
from repro.launch.mesh import make_host_mesh
from repro.serve.serve import BatchScheduler, ServeConfig, make_decode_step, make_prefill_step


@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b", "gemma2-2b", "qwen3-moe-30b-a3b", "zamba2-2.7b",
    "xlstm-350m",
])
def test_decode_matches_forward_logits(arch):
    """Prefill+decode must reproduce the teacher-forced forward logits —
    the strongest end-to-end consistency check for every cache type
    (KV, conv, ssm, mLSTM, sLSTM)."""
    cfg = smoke_config(arch)
    if arch in ("zamba2-2.7b", "xlstm-350m"):
        # chunked-prefill vs stepwise-decode recurrences are mathematically
        # identical but round differently; the recurrent denominators
        # (mLSTM max(|q.n|, exp(-m))) amplify reassociation noise roughly
        # exponentially with depth. Run the cache-logic consistency check
        # in f32 at one pattern repeat — deeper stacks diverge numerically,
        # not logically (see DESIGN.md numerics notes).
        cfg = cfg.replace(compute_dtype_name="float32",
                          param_dtype_name="float32")
    if arch == "xlstm-350m":
        cfg = cfg.replace(repeats=1)
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    Bs, prompt_len, total = 2, 16, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (Bs, total), 4, cfg.vocab)

    # xlstm: jit-vs-eager op fusion perturbs the mLSTM state slightly and
    # its denominator amplifies that; keep both sides in the same
    # compilation mode so the check isolates cache logic.
    jit_ = (lambda f: f) if arch == "xlstm-350m" else jax.jit
    with mesh:
        full_logits, _ = jit_(lambda p, b: T.apply_logits(p, b, cfg))(
            params, {"tokens": toks}
        )
        caches = T.init_cache(cfg, Bs, total + 8)
        _, caches = jit_(make_prefill_step(cfg, mesh))(
            params, {"tokens": toks[:, :prompt_len]}, caches
        )
        decode = jax.jit(make_decode_step(cfg, mesh))
        errs = []
        for i in range(prompt_len, total):
            logits, caches = T.decode_step(
                params, toks[:, i : i + 1], jnp.asarray(i, jnp.int32), cfg, caches
            )
            err = np.max(np.abs(
                np.asarray(logits, np.float32)
                - np.asarray(full_logits[:, i], np.float32)
            ))
            errs.append(err)
    assert max(errs) < 0.1, f"{arch}: decode/forward divergence {max(errs)}"


def test_prefill_last_logits_match_forward():
    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 4, cfg.vocab)
    with mesh:
        full_logits, _ = T.apply_logits(params, {"tokens": toks}, cfg)
        caches = T.init_cache(cfg, 2, 32)
        next_tok, _ = make_prefill_step(cfg, mesh)(params, {"tokens": toks}, caches)
    expected = np.argmax(np.asarray(full_logits[:, -1], np.float32), axis=-1)
    np.testing.assert_array_equal(np.asarray(next_tok), expected)


# ---------------------------------------------------------------------------
# BatchScheduler: chunked prefill-on-attach overlapped with in-flight decode
# ---------------------------------------------------------------------------
# f32 so the chunked-prefill-vs-reference and A/B token-identity checks
# isolate scheduler logic from bf16 argmax near-ties. One shared config =
# one shared (decode, prefill) jit pair across every scheduler instance.


@functools.cache
def _serve_fixtures():
    cfg = smoke_config("tinyllama-1.1b").replace(
        compute_dtype_name="float32", param_dtype_name="float32"
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    return cfg, mesh, params


def _run(sched, n_requests, max_ticks=200):
    ticks = 0
    while len(sched.completed) < n_requests and ticks < max_ticks:
        sched.step()
        ticks += 1
    sched.drain()
    return ticks


def _reference_generate(cfg, mesh, params, prompt, max_new, max_len=64):
    """Stop-the-world reference: full one-shot prefill + sequential decode."""
    with mesh:
        caches = T.init_cache(cfg, 1, max_len)
        toks = jnp.asarray([prompt], jnp.int32)
        next_tok, caches = make_prefill_step(cfg, mesh)(
            params, {"tokens": toks}, caches
        )
        out = [int(next_tok[0])]
        pos = len(prompt)
        tok = next_tok.reshape(1, 1)
        while len(out) < max_new:
            logits, caches = T.decode_step(
                params, tok, jnp.asarray(pos, jnp.int32), cfg, caches
            )
            tok = jnp.argmax(logits, axis=-1, keepdims=True).astype(jnp.int32)
            out.append(int(tok[0, 0]))
            pos += 1
    return out


def test_batch_scheduler_completes_requests():
    cfg, mesh, params = _serve_fixtures()
    with mesh:
        sched = BatchScheduler(cfg, mesh, ServeConfig(max_len=64, batch=2), params)
        for rid in range(4):
            sched.submit([1, 2, 3], request_id=rid, max_new=5)
        _run(sched, 4)
    assert len(sched.completed) == 4
    for req in sched.completed:
        assert len(req["generated"]) == 5
        assert all(0 <= t < cfg.vocab_padded for t in req["generated"])


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-2b"])
def test_scheduler_chunked_prefill_matches_reference(arch):
    """Chunked prefill at per-slot offsets + continuous-batching decode must
    reproduce the stop-the-world reference (one-shot prefill + sequential
    decode) token for token — the end-to-end correctness gate for the
    per-slot position vector and the cache-attend prefill path. gemma2 runs
    with a sliding window SMALLER than the prompts so the window actually
    cuts into the cache_attend path at test lengths."""
    if arch == "tinyllama-1.1b":
        cfg, mesh, params = _serve_fixtures()
    else:
        cfg = smoke_config(arch).replace(
            compute_dtype_name="float32", param_dtype_name="float32", window=5
        )
        mesh = make_host_mesh()
        params = init_params(
            T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype
        )
    rng = np.random.default_rng(7)
    prompts = [rng.integers(4, cfg.vocab, size=n).tolist() for n in (3, 9, 14, 6)]
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4), params,
        )
        for rid, p in enumerate(prompts):
            sched.submit(p, request_id=rid, max_new=6)
        _run(sched, len(prompts))
    assert len(sched.completed) == len(prompts)
    for req in sched.completed:
        ref = _reference_generate(cfg, mesh, params, prompts[req["id"]], 6)
        assert req["generated"] == ref, (req["id"], req["generated"], ref)


def test_submit_rejects_nonpositive_max_new():
    """The prefill-completion token is unconditionally the first generated
    token, so a zero (or negative) budget is unsatisfiable — reject it."""
    cfg, mesh, params = _serve_fixtures()
    with mesh:
        sched = BatchScheduler(cfg, mesh, ServeConfig(max_len=64, batch=2), params)
    with pytest.raises(ValueError, match="max_new"):
        sched.submit([1, 2], request_id=0, max_new=0)


def test_attach_during_decode_does_not_change_inflight_outputs():
    """Attaching (and prefilling) request B mid-flight must not perturb
    request A's token stream: the prefill only touches B's cache lines and
    the masked decode write leaves B's lines alone."""
    cfg, mesh, params = _serve_fixtures()
    prompt_a = [5, 6, 7, 8]
    prompt_b = list(range(4, 16))

    def run(with_b):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4), params,
            )
            sched.submit(prompt_a, request_id="a", max_new=10)
            sched.step()
            sched.step()  # A is prefilled and decoding
            if with_b:
                sched.submit(prompt_b, request_id="b", max_new=4)
            _run(sched, 2 if with_b else 1)
        return {req["id"]: req["generated"] for req in sched.completed}

    alone = run(with_b=False)
    together = run(with_b=True)
    assert together["a"] == alone["a"]
    assert together["b"] == _reference_generate(cfg, mesh, params, prompt_b, 4)


def test_per_slot_positions_after_staggered_attach():
    """Slots attached at different times decode at their own positions."""
    cfg, mesh, params = _serve_fixtures()
    prompt_a, prompt_b = list(range(4, 12)), [30, 31, 32]
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=8), params,
        )
        sched.submit(prompt_a, request_id="a", max_new=32)
        sched.step()   # tick 1: prefill A dispatched (1 chunk = whole prompt)
        sched.step()   # tick 2: A decodes its first step
        slot_a = next(i for i, r in enumerate(sched.active)
                      if r is not None and r["id"] == "a")
        assert sched.pos[slot_a] == len(prompt_a) + 1
        sched.submit(prompt_b, request_id="b", max_new=32)
        sched.step()   # tick 3: A decodes; B prefills
        sched.step()   # tick 4: A and B decode together
        slot_b = next(i for i, r in enumerate(sched.active)
                      if r is not None and r["id"] == "b")
        assert slot_b != slot_a
        assert sched.pos[slot_a] == len(prompt_a) + 3
        assert sched.pos[slot_b] == len(prompt_b) + 1
        sched.drain()


def test_eos_retirement_before_max_new():
    """EOS-based early stop: the deferred readback detects the EOS at a
    flush boundary, truncates anything decoded past it, and frees the slot
    before the count budget is reached."""
    cfg, mesh, params = _serve_fixtures()
    prompt = [9, 10, 11, 12, 13]
    free_run = _reference_generate(cfg, mesh, params, prompt, 8)
    eos = free_run[2]
    assert eos not in free_run[:2]  # make the truncation point unambiguous
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, eos_id=eos, eos_check_every=3),
            params,
        )
        sched.submit(prompt, request_id=0, max_new=8)
        ticks = _run(sched, 1)
    (req,) = sched.completed
    assert req["generated"] == free_run[:3]          # ends at the EOS
    assert len(req["generated"]) < 8                 # retired early
    assert ticks < 12  # the slot was freed well before the budget


def test_drain_flushes_partial_prefills():
    """drain() completes in-flight (partial) prefills so a submitted request
    always yields its first token, even if the serve loop stops early."""
    cfg, mesh, params = _serve_fixtures()
    prompt = list(range(4, 16))  # 12 tokens -> 3 chunks of 4
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4), params,
        )
        sched.submit(prompt, request_id=0, max_new=8)
        sched.step()  # one tick: exactly one chunk in
        assert sched._prefills and sched._prefills[0]["done"] == 4
        sched.drain()
        assert not sched._prefills
        (req,) = [r for r in sched.active if r is not None]
        assert req["generated"] == _reference_generate(cfg, mesh, params, prompt, 1)
        slot = sched.active.index(req)
        assert sched.pos[slot] == len(prompt)


def test_overlap_on_off_identical_tokens_and_no_decode_gap():
    """The acceptance check: overlapped chunked prefill produces bitwise
    identical tokens to stop-the-world prefill, and while a prefill is in
    flight every tick still dispatches a decode step (no gap > one tick)."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(4, cfg.vocab, size=n).tolist() for n in (10, 14, 5)]

    def run(overlap):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                            overlap=overlap),
                params,
            )
            sched.submit(prompts[0], request_id=0, max_new=8)
            sched.step()
            sched.step()
            for rid in (1, 2):
                sched.submit(prompts[rid], request_id=rid, max_new=8)
            _run(sched, 3)
        return sched

    overlapped = run(True)
    stop_world = run(False)
    toks = lambda s: {r["id"]: r["generated"] for r in s.completed}
    assert toks(overlapped) == toks(stop_world)
    # requests 1/2 prefilled while request 0 was decoding: those ticks exist
    # and no decode dispatch ever ran after prefill work in its tick
    assert overlapped.stats["overlap_ticks"] > 0
    assert overlapped.stats["decode_after_prefill_ticks"] == 0
    # stop-the-world never overlaps — and its decode dispatches DID wait
    # behind synchronous prefills (the stall the overlap removes)
    assert stop_world.stats["overlap_ticks"] == 0
    assert stop_world.stats["decode_after_prefill_ticks"] > 0


def test_scheduler_chunked_prefill_recurrent_hybrid():
    """The masked state advance (dt-zeroing, conv-state gather, frozen SSM
    state for padding and inactive decode slots) must hold on a hybrid
    mamba+attention stack too: chunked prefill with a ragged final chunk
    matches the one-shot reference, and overlap on/off agree exactly."""
    cfg = smoke_config("zamba2-2.7b").replace(
        compute_dtype_name="float32", param_dtype_name="float32"
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    prompts = [list(range(4, 4 + n)) for n in (7, 10)]  # ragged vs chunk=4

    def run(overlap):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                            overlap=overlap),
                params,
            )
            sched.submit(prompts[0], request_id=0, max_new=5)
            sched.step()  # request 0 mid-prefill / decoding...
            sched.submit(prompts[1], request_id=1, max_new=5)
            _run(sched, 2)
        return {r["id"]: r["generated"] for r in sched.completed}

    overlapped = run(True)
    assert overlapped == run(False)
    for rid, p in enumerate(prompts):
        ref = _reference_generate(cfg, mesh, params, p, 5)
        assert overlapped[rid] == ref, (rid, overlapped[rid], ref)


def test_recurrent_hybrid_slot_reuse_matches_reference():
    """More requests than slots on a hybrid mamba+attention arch: a freed
    slot's recurrent state (SSM/conv) must be restored to fresh before the
    next request prefills into it. Attention KV is masked by cache_len, but
    recurrent carries are not — without the reset the reused slots' tokens
    continue from the retired request's final state."""
    cfg = smoke_config("zamba2-2.7b").replace(
        compute_dtype_name="float32", param_dtype_name="float32"
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    prompts = [list(range(4, 4 + n)) for n in (7, 10, 5, 8)]  # 4 reqs, 2 slots
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4), params,
        )
        for rid, p in enumerate(prompts):
            sched.submit(p, request_id=rid, max_new=5)
        _run(sched, len(prompts))
    assert len(sched.completed) == len(prompts)
    for req in sched.completed:
        ref = _reference_generate(cfg, mesh, params, prompts[req["id"]], 5)
        assert req["generated"] == ref, (req["id"], req["generated"], ref)


def test_slot_reuse_matches_fresh_scheduler_xlstm():
    """Slot reuse on an xLSTM stack: the reset must restore INITIAL carry
    values, not zeros (sLSTM's stabilizer m starts at -1e30). Identity
    check against a fresh scheduler (same jitted steps, so any stale or
    mis-reset state shows up as a token difference)."""
    cfg = smoke_config("xlstm-350m").replace(
        compute_dtype_name="float32", param_dtype_name="float32", repeats=1
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    prompt_a, prompt_b = [5, 6, 7, 8, 9], [20, 21, 22]

    def run(submit_a):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=1, prefill_chunk=4), params,
            )
            if submit_a:
                sched.submit(prompt_a, request_id="a", max_new=4)
            sched.submit(prompt_b, request_id="b", max_new=6)
            _run(sched, 2 if submit_a else 1)
        return {r["id"]: r["generated"] for r in sched.completed}

    reused = run(submit_a=True)       # "b" runs in the slot "a" retired from
    fresh = run(submit_a=False)       # "b" runs in a never-used slot
    assert reused["b"] == fresh["b"], (reused["b"], fresh["b"])


def test_masked_decode_freezes_inactive_slots_mlstm():
    """Batched masked decode on an mLSTM/sLSTM stack with batch != n_heads:
    the per-slot freeze masks must broadcast over the head axis (a (B,) mask
    against (B,h) carries), inactive slots' state stays bitwise frozen, and
    active slots match the unmasked step exactly."""
    cfg = smoke_config("xlstm-350m").replace(
        compute_dtype_name="float32", param_dtype_name="float32", repeats=1
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    Bs, plen = 3, 6  # 3 slots vs n_heads=4: a wrong-axis broadcast cannot hide
    toks = jax.random.randint(jax.random.PRNGKey(2), (Bs, plen), 4, cfg.vocab)
    with mesh:
        caches = T.init_cache(cfg, Bs, 16)
        _, caches = make_prefill_step(cfg, mesh)(params, {"tokens": toks}, caches)
        step_tok = jax.random.randint(jax.random.PRNGKey(3), (Bs, 1), 4, cfg.vocab)
        pos = jnp.full((Bs,), plen, jnp.int32)
        logits_m, caches_m = T.decode_step(
            params, step_tok, pos, cfg, caches,
            active=jnp.asarray([True, False, True]),
        )
        logits_u, caches_u = T.decode_step(params, step_tok, pos, cfg, caches)
    for before, masked, unmasked in zip(
        jax.tree_util.tree_leaves(caches),
        jax.tree_util.tree_leaves(caches_m),
        jax.tree_util.tree_leaves(caches_u),
    ):
        before, masked, unmasked = map(np.asarray, (before, masked, unmasked))
        np.testing.assert_array_equal(  # inactive slot: no state advance
            masked[:, 1], before[:, 1]
        )
        np.testing.assert_array_equal(  # active slots: same as unmasked
            masked[:, [0, 2]], unmasked[:, [0, 2]]
        )
    np.testing.assert_array_equal(
        np.asarray(logits_m)[[0, 2]], np.asarray(logits_u)[[0, 2]]
    )


def test_stale_seed_dropped_on_reattach():
    """A request retiring in the same tick its prefill completes leaves its
    next-token seed queued; if the freed slot is immediately reattached, the
    stale seed must not race the new request's seed in the scatter."""
    cfg, mesh, params = _serve_fixtures()
    with mesh:
        sched = BatchScheduler(cfg, mesh, ServeConfig(max_len=64, batch=1), params)
        sched.submit([5, 6, 7], request_id="a", max_new=1)
        _run(sched, 1)  # retires at its prefill-completion flush
        # empty prompt: the reattached slot seeds directly (no prefill), the
        # exact duplicate-scatter window the stale seed could race
        sched.submit([], request_id="b", max_new=4)
        _run(sched, 2)
        got = {r["id"]: r["generated"] for r in sched.completed}

        fresh = BatchScheduler(cfg, mesh, ServeConfig(max_len=64, batch=1), params)
        fresh.submit([], request_id="b", max_new=4)
        _run(fresh, 1)
    (ref,) = [r["generated"] for r in fresh.completed]
    assert got["b"] == ref, (got["b"], ref)


# ---------------------------------------------------------------------------
# paged KV cache: shared page pool + per-slot block tables
# ---------------------------------------------------------------------------
# NOTE: every scheduler test above already runs the paged layout — it is the
# ServeConfig default. The tests below pin the paged-specific guarantees:
# bitwise paged/dense identity, allocator lifecycle, exhaustion behavior.


def test_paged_matches_dense_tokens_overlap_on_off():
    """The tentpole acceptance criterion: the paged KV cache produces
    bitwise-identical tokens to the dense layout, with overlap on AND off,
    on a staggered multi-request trace with slot reuse."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(11)
    prompts = [rng.integers(4, cfg.vocab, size=n).tolist()
               for n in (10, 17, 5, 8)]  # 4 requests > 2 slots

    def run(paged, overlap):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                            overlap=overlap, paged=paged, page_size=16),
                params,
            )
            sched.submit(prompts[0], request_id=0, max_new=7)
            sched.step()  # request 0 mid-prefill when the rest arrive
            for rid in (1, 2, 3):
                sched.submit(prompts[rid], request_id=rid, max_new=7)
            _run(sched, len(prompts))
        return {r["id"]: r["generated"] for r in sched.completed}

    dense = run(paged=False, overlap=True)
    for overlap in (True, False):
        paged = run(paged=True, overlap=overlap)
        assert paged == dense, (overlap, paged, dense)


@pytest.mark.parametrize("arch", ["gemma2-2b", "zamba2-2.7b"])
def test_paged_scheduler_matches_reference_small_pages(arch):
    """Paged-vs-reference token identity with page_size SMALLER than the
    attention span: gemma2 runs a sliding window (5) that crosses every
    page boundary (page_size 4), zamba2 covers the hybrid mamba+attention
    stack (recurrent state stays dense per slot while attention pages).
    More requests than slots also exercises block free/realloc on reuse."""
    cfg = smoke_config(arch).replace(
        compute_dtype_name="float32", param_dtype_name="float32",
        **({"window": 5} if arch == "gemma2-2b" else {}),
    )
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(4, cfg.vocab, size=n).tolist() for n in (3, 9, 14, 6)]
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                        paged=True, page_size=4),
            params,
        )
        for rid, p in enumerate(prompts):
            sched.submit(p, request_id=rid, max_new=6)
        _run(sched, len(prompts))
    assert len(sched.completed) == len(prompts)
    for req in sched.completed:
        ref = _reference_generate(cfg, mesh, params, prompts[req["id"]], 6)
        assert req["generated"] == ref, (req["id"], req["generated"], ref)


def test_paged_allocator_frees_and_reallocates_on_slot_reuse():
    """Block lifecycle with more requests than slots: pages are allocated
    as prefill/decode write, freed when a request retires, and the freed
    pages back the next request — the pool never leaks and the block
    tables of retired slots are fully cleared."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(4, cfg.vocab, size=n).tolist()
               for n in (20, 9, 18, 5)]  # 4 requests, 2 slots
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            # pool sized so 4 requests can only complete if retirement
            # actually recycles pages: 2 slots x ceil((20+6)/8) = 8 pages
            ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                        paged=True, page_size=8, num_pages=8),
            params,
        )
        for rid, p in enumerate(prompts):
            sched.submit(p, request_id=rid, max_new=6)
        _run(sched, len(prompts))
    assert len(sched.completed) == len(prompts)
    alloc = sched._alloc
    assert alloc.used == 0, "pages leaked past request retirement"
    assert alloc.peak_used > 0
    assert alloc.peak_used <= alloc.num_pages
    assert (sched._tables == -1).all()
    stats = sched.kv_cache_stats()
    assert stats["layout"] == "paged" and stats["pages_in_use"] == 0
    assert stats["peak_used_pages"] == alloc.peak_used
    # and the recycled pool still produced reference tokens
    for req in sched.completed:
        ref = _reference_generate(cfg, mesh, params, prompts[req["id"]], 6)
        assert req["generated"] == ref, (req["id"], req["generated"], ref)


def test_paged_pool_exhaustion_raises_clean_error():
    """A full pool must fail loudly BEFORE handing out any page — never
    remap a neighbor's pages. The neighbor keeps decoding correctly after
    the failed attach is cancelled."""
    cfg, mesh, params = _serve_fixtures()
    prompt_a, prompt_b = [5, 6, 7, 8], list(range(4, 24))  # b needs 3 pages
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                        paged=True, page_size=8, num_pages=2),
            params,
        )
        sched.submit(prompt_a, request_id="a", max_new=4)
        sched.step()  # "a" owns page 0 (prompt) — 1 page left
        sched.submit(prompt_b, request_id="b", max_new=4)
        with pytest.raises(RuntimeError, match="exhausted"):
            _run(sched, 2)
        # the neighbor's pages were never touched: cancel "b" and drain "a"
        slot_b = next(s for s, t in enumerate(sched._prefilling) if t)
        sched._prefills.clear()
        sched._prefilling[slot_b] = None
        sched._release_slot_pages(slot_b)
        _run(sched, 1)
    (req,) = [r for r in sched.completed if r["id"] == "a"]
    # the aborted tick may have queued one decode past the budget before the
    # flush could retire "a" — the stream itself must still match reference
    ref = _reference_generate(cfg, mesh, params, prompt_a, 4)
    assert req["generated"][: len(ref)] == ref


def test_paged_rejects_indivisible_max_len():
    cfg, mesh, params = _serve_fixtures()
    with pytest.raises(ValueError, match="divisible"):
        BatchScheduler(
            cfg, mesh, ServeConfig(max_len=60, batch=2, page_size=16), params
        )


# ---------------------------------------------------------------------------
# sampling: temperature/top-k with per-slot on-device PRNG keys
# ---------------------------------------------------------------------------


def test_sampling_deterministic_and_reset_on_slot_reuse():
    """With greedy=False the decode/prefill-chunk steps sample on device
    from ``fold_in(slot_key, position)`` — stateless, so a request's
    stream depends only on (params, prompt, slot, seed): running it after
    a predecessor retired from the slot must reproduce the fresh-scheduler
    stream exactly."""
    cfg, mesh, params = _serve_fixtures()
    scfg = ServeConfig(max_len=64, batch=1, prefill_chunk=4,
                       greedy=False, temperature=0.8, top_k=20, sample_seed=3)
    prompt_a, prompt_b = [5, 6, 7, 8, 9], [20, 21, 22]

    def run(submit_a):
        with mesh:
            sched = BatchScheduler(cfg, mesh, scfg, params)
            if submit_a:
                sched.submit(prompt_a, request_id="a", max_new=5)
            sched.submit(prompt_b, request_id="b", max_new=8)
            _run(sched, 2 if submit_a else 1)
        return {r["id"]: r["generated"] for r in sched.completed}

    reused = run(submit_a=True)    # "b" samples in the slot "a" retired from
    fresh = run(submit_a=False)    # "b" samples in a never-used slot
    assert reused["b"] == fresh["b"], (reused["b"], fresh["b"])
    # determinism: the same scheduler run twice is bitwise repeatable
    assert run(submit_a=True) == reused
    # sampled ids stay inside the real vocab (padded ids are masked out)
    for toks in reused.values():
        assert all(0 <= t < cfg.vocab for t in toks)


def test_sampling_independent_of_coresident_traffic():
    """A sampled request's stream must not depend on what the OTHER slots
    are doing: attaching it late (after another request decoded for a few
    ticks) or toggling overlap must reproduce the solo stream bit for bit.
    The stateless fold_in(slot_key, position) keying guarantees it — a
    carried-and-split key would advance with every batched decode and
    fail this."""
    cfg, mesh, params = _serve_fixtures()
    prompt_x, prompt_b = list(range(4, 14)), [20, 21, 22]

    def scfg(overlap=True):
        return ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                           greedy=False, temperature=0.8, top_k=20,
                           sample_seed=3, overlap=overlap)

    def stream_of_b(sched, late):
        sched.submit(prompt_x, request_id="x", max_new=10)
        if late:
            sched.step()
            sched.step()  # x decodes alone for a while
        sched.submit(prompt_b, request_id="b", max_new=6)
        _run(sched, 2)
        return {r["id"]: r["generated"] for r in sched.completed}["b"]

    with mesh:
        # solo-ish baseline: b attaches immediately alongside x
        base = stream_of_b(BatchScheduler(cfg, mesh, scfg(), params), late=False)
        late = stream_of_b(BatchScheduler(cfg, mesh, scfg(), params), late=True)
        sw = stream_of_b(BatchScheduler(cfg, mesh, scfg(False), params),
                         late=True)
    assert base == late, (base, late)
    assert late == sw, (late, sw)


def test_sampling_greedy_flag_matches_historical_argmax():
    """greedy=True (the default) must stay bitwise identical to the
    pre-sampling scheduler — the reference generator IS the historical
    argmax path."""
    cfg, mesh, params = _serve_fixtures()
    prompt = [9, 10, 11, 12]
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=2, temperature=0.7, top_k=5),
            params,
        )  # temperature/top_k are inert while greedy=True
        sched.submit(prompt, request_id=0, max_new=6)
        _run(sched, 1)
    (req,) = sched.completed
    assert req["generated"] == _reference_generate(cfg, mesh, params, prompt, 6)


# ---------------------------------------------------------------------------
# cross-request prefix cache: radix trie + copy-on-write pages
# ---------------------------------------------------------------------------
# The guarantee under test everywhere below: prefix sharing is a pure
# memory/compute optimization — generated tokens are bitwise identical with
# the cache on or off, because a shared page holds exactly the K/V the
# request would have prefilled itself.


def _run_shared_prefix(cfg, mesh, params, prompts, *, prefix_cache,
                       page_size=8, num_pages=None, prefill_chunk=4,
                       max_new=6, batch=2, trie_capacity=None):
    """Warm-first schedule: request 0 completes (inserting its prompt pages
    into the trie when sharing is on), then the rest attach against it."""
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=batch, prefill_chunk=prefill_chunk,
                        paged=True, page_size=page_size, num_pages=num_pages,
                        prefix_cache=prefix_cache,
                        prefix_trie_capacity=trie_capacity),
            params,
        )
        sched.submit(prompts[0], request_id=0, max_new=max_new)
        _run(sched, 1)
        for rid, p in enumerate(prompts[1:], start=1):
            sched.submit(p, request_id=rid, max_new=max_new)
        _run(sched, len(prompts))
    return sched


def _tokens(sched):
    return {r["id"]: r["generated"] for r in sched.completed}


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-2b", "zamba2-2.7b"])
def test_prefix_sharing_identical_tokens(arch):
    """Sharing on/off bitwise token identity on a shared-system-prompt
    workload — across a plain KV stack, a sliding window SMALLER than the
    shared prefix (gemma2: the window crosses shared-page boundaries), and
    a hybrid mamba+attention stack (zamba2: attention pages are shared for
    the memory win but no prefill compute is skipped, because the recurrent
    state must still advance over every prompt token)."""
    if arch == "tinyllama-1.1b":
        cfg, mesh, params = _serve_fixtures()
    else:
        cfg = smoke_config(arch).replace(
            compute_dtype_name="float32", param_dtype_name="float32",
            **({"window": 5} if arch == "gemma2-2b" else {}),
        )
        mesh = make_host_mesh()
        params = init_params(
            T.model_params(cfg), jax.random.PRNGKey(0), cfg.param_dtype
        )
    rng = np.random.default_rng(13)
    system = rng.integers(4, cfg.vocab, size=24).tolist()  # 3 pages of 8
    prompts = [system + rng.integers(4, cfg.vocab, size=int(n)).tolist()
               for n in rng.integers(3, 8, size=5)]

    on = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=True)
    off = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=False)
    assert _tokens(on) == _tokens(off)
    pc = on.kv_cache_stats()["prefix_cache"]
    assert pc["hits"] == len(prompts) - 1  # everyone after the warmup hits
    assert pc["pages_saved_by_sharing"] > 0
    if arch == "zamba2-2.7b":
        # hybrid: pages shared (memory), no compute skipped (the recurrent
        # state has no positional mask to fast-forward through)
        assert pc["prefill_tokens_skipped"] == 0
    else:
        assert pc["prefill_tokens_skipped"] > 0
        assert on.stats["prefill_chunks"] < off.stats["prefill_chunks"]
    # strictly fewer live pages at peak, trie pins included
    assert (on.kv_cache_stats()["peak_used_pages"]
            < off.kv_cache_stats()["peak_used_pages"])


def test_prefix_cow_mid_page_divergence():
    """Prompts diverging MID-page: the fully-matched pages are shared
    read-only, the partially-matched page is copy-on-write (fresh page,
    device copy of the donor's rows, divergent tokens prefilled over the
    tail) — and the tokens still match the no-sharing run exactly."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(17)
    common = rng.integers(4, cfg.vocab, size=20).tolist()  # 2.5 pages of 8
    prompts = [common + rng.integers(4, cfg.vocab, size=4).tolist()
               for _ in range(3)]  # diverge at token 20, mid-page 2

    on = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=True)
    off = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=False)
    assert _tokens(on) == _tokens(off)
    pc = on.kv_cache_stats()["prefix_cache"]
    assert pc["cow_copies"] >= 1
    assert pc["hit_tokens"] >= 20  # 2 full pages + 4 donor rows per hit


def test_prefix_refcounts_no_leak_under_churn():
    """Slot-reuse churn with sharing on: after every request retires, the
    only pages still allocated are the trie's own pins (one reference
    each); clear() then returns the pool to empty and the block tables of
    all slots are fully cleared — no leaked references either way."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(19)
    system = rng.integers(4, cfg.vocab, size=16).tolist()
    prompts = [system + rng.integers(4, cfg.vocab, size=int(n)).tolist()
               for n in rng.integers(3, 8, size=8)]  # 8 requests, 2 slots

    sched = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=True)
    assert len(sched.completed) == len(prompts)
    alloc, trie = sched._alloc, sched._prefix
    assert alloc.used == trie.size, "pages leaked past request retirement"
    assert all(c == 1 for c in alloc.refs.values()), (
        "dangling non-trie references after all requests retired"
    )
    assert (sched._tables == -1).all()
    trie.clear()
    assert alloc.used == 0 and trie.size == 0
    assert not alloc.refs


def test_prefix_trie_eviction_under_pool_pressure():
    """A pool too small to hold every retired prompt's pages forces LRU
    trie eviction on attach; the evicted entries' neighbors (still-cached
    prefixes AND in-flight requests) are unharmed — every request still
    matches the no-sharing tokens, and eviction provably happened."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(23)
    # 4 DISTINCT 16-token prompts (2 pages each) + decode growth vs an
    # 8-page pool: the trie cannot keep them all pinned
    prompts = [rng.integers(4, cfg.vocab, size=16).tolist() for _ in range(4)]

    on = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=True,
                            num_pages=8, batch=2)
    off = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=False,
                             num_pages=8, batch=2)
    assert _tokens(on) == _tokens(off)
    pc = on.kv_cache_stats()["prefix_cache"]
    assert pc["evicted_pages"] >= 1
    assert on._alloc.used == on._prefix.size  # pins accounted, nothing leaked


def test_prefix_trie_capacity_lru_trim():
    """prefix_trie_capacity bounds the trie's pinned pages: inserts past
    the cap LRU-trim other paths, size never exceeds the cap, and sharing
    still works for the prefixes that stay resident."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(29)
    system = rng.integers(4, cfg.vocab, size=16).tolist()
    prompts = [system + rng.integers(4, cfg.vocab, size=int(n)).tolist()
               for n in rng.integers(3, 8, size=5)]

    sched = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=True,
                               trie_capacity=2)
    off = _run_shared_prefix(cfg, mesh, params, prompts, prefix_cache=False)
    assert _tokens(sched) == _tokens(off)
    assert sched._prefix.size <= 2
    assert sched.kv_cache_stats()["prefix_cache"]["hits"] > 0


def test_prefix_cache_requires_paged_layout():
    """ServeConfig must reject prefix_cache on the dense layout at
    construction — a shared page cannot be expressed in (batch, max_len)
    buffers, and failing at attach time would be far harder to debug."""
    with pytest.raises(ValueError, match="paged"):
        ServeConfig(max_len=64, batch=2, paged=False, prefix_cache=True)


def test_prefix_sharing_sampled_streams_identical():
    """Sampling composes with sharing: per-slot streams are keyed on
    fold_in(slot_key, position) — a function of WHERE the request decodes,
    not of how the KV for earlier positions got there — so sampled tokens
    are bitwise identical with sharing on or off."""
    cfg, mesh, params = _serve_fixtures()
    rng = np.random.default_rng(31)
    system = rng.integers(4, cfg.vocab, size=16).tolist()
    prompts = [system + rng.integers(4, cfg.vocab, size=int(n)).tolist()
               for n in rng.integers(3, 8, size=4)]

    def run(prefix_cache):
        with mesh:
            sched = BatchScheduler(
                cfg, mesh,
                ServeConfig(max_len=64, batch=2, prefill_chunk=4,
                            paged=True, page_size=8,
                            prefix_cache=prefix_cache,
                            greedy=False, temperature=0.8, top_k=20,
                            sample_seed=3),
                params,
            )
            sched.submit(prompts[0], request_id=0, max_new=6)
            _run(sched, 1)
            for rid, p in enumerate(prompts[1:], start=1):
                sched.submit(p, request_id=rid, max_new=6)
            _run(sched, len(prompts))
        return _tokens(sched)

    assert run(True) == run(False)


def test_batch_scheduler_batches_token_readback(monkeypatch):
    """Decode steps must NOT pay one host round-trip each: readbacks are
    deferred and flushed in a single device_get at completion boundaries."""
    cfg, mesh, params = _serve_fixtures()
    calls = {"n": 0}
    real_get = jax.device_get

    def counting_get(x):
        calls["n"] += 1
        return real_get(x)

    with mesh:
        sched = BatchScheduler(cfg, mesh, ServeConfig(max_len=64, batch=2), params)
        for rid in range(4):
            sched.submit([1, 2, 3], request_id=rid, max_new=6)
        monkeypatch.setattr("repro.serve.serve.jax.device_get", counting_get)
        steps = 0
        while len(sched.completed) < 4 and steps < 64:
            sched.step()
            steps += 1
        sched.drain()
    assert len(sched.completed) == 4
    # 4 requests x 6 tokens: per-step readback would pay >= 20 transfers;
    # deferred flushing pays at most one per request-completion boundary
    # (completions stagger by one tick because prefills serialize at one
    # chunk per tick) + the drain
    assert steps >= 12
    assert calls["n"] <= 5, f"{calls['n']} readbacks in {steps} steps"
    for req in sched.completed:
        assert len(req["generated"]) == 6
