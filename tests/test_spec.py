"""Speculative decoding: drafter purity, batched-verify identity, composition.

The contract under test: speculation is an OPTIMIZATION, never a behavior
change. A draft token is only kept when verification proves it is the token
sequential decode would have produced, so spec on/off must be bitwise
identical — greedy and sampled, across attention/window/recurrent archs,
and composed with preemption-resume and fault-retry — while the page pool
stays leak-free under rejections and mid-accept exhaustion.
"""

import functools

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.layers.common import init_params
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.serve.faults import FaultEvent, FaultInjector
from repro.serve.serve import (
    BatchScheduler,
    ServeConfig,
    _PoolPressure,
    _serve_step_fns,
)
from repro.serve.spec import draft_tokens


# ---------------------------------------------------------------------------
# drafter: pure function of the history, deterministic, bounded
# ---------------------------------------------------------------------------


def test_draft_tokens_deterministic_and_pure():
    h = [1, 2, 3, 1, 2, 3, 1, 2]
    first = draft_tokens(h, 4)
    assert first == draft_tokens(h, 4)  # same history -> same proposal
    assert h == [1, 2, 3, 1, 2, 3, 1, 2]  # input untouched
    first.append(99)  # returned list is a copy, not a view into state
    assert draft_tokens(h, 4) == first[:-1]
    # numpy token histories (what the scheduler holds) work and yield ints
    out = draft_tokens(np.asarray(h, np.int32), 4)
    assert out == first[:-1] and all(type(t) is int for t in out)


def test_draft_tokens_matches_most_recent_occurrence():
    # suffix [1, 2] occurs earlier at index 2 and 5; the most recent
    # earlier occurrence (5) wins, so the proposal is what followed THERE
    h = [7, 8, 1, 2, 9, 1, 2, 3, 1, 2]
    assert draft_tokens(h, 3) == [3, 1, 2]
    assert draft_tokens(h, 1) == [3]  # k caps the proposal


def test_draft_tokens_prefers_longer_suffix():
    # both [2, 3] and the longer [1, 2, 3] recur; the 3-gram match wins
    # even though a 2-gram occurrence is nearer the end
    h = [1, 2, 3, 4, 2, 3, 9, 1, 2, 3]
    assert draft_tokens(h, 2) == [4, 2]


def test_draft_tokens_min_match_and_degenerate_cases():
    h = [1, 2, 3, 4, 2]
    assert draft_tokens(h, 4) == []  # only a 1-gram recurs; min_match=2
    assert draft_tokens(h, 4, min_match=1) == [3, 4, 2]
    assert draft_tokens(h, 0) == []
    assert draft_tokens([5], 4) == []
    assert draft_tokens([], 4) == []


# ---------------------------------------------------------------------------
# shared fixtures (f32: identity checks must isolate scheduler logic from
# bf16 argmax near-ties, same rationale as tests/test_serve.py)
# ---------------------------------------------------------------------------


@functools.cache
def _fixtures(arch="tinyllama-1.1b"):
    over = {"compute_dtype_name": "float32", "param_dtype_name": "float32"}
    if arch == "xlstm-350m":
        over["repeats"] = 1
    if arch == "gemma2-2b":
        # sliding window smaller than the prompt AND the verify chunk so
        # windowed attention genuinely crosses the speculated positions
        over["window"] = 5
    cfg = smoke_config(arch).replace(**over)
    mesh = make_host_mesh()
    params = init_params(T.model_params(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, mesh, params


def _copy_regime(params):
    """Residual-zeroed weights: logits become a pure function of the last
    token, so greedy decode must cycle (pigeonhole) — the deterministic way
    to force real multi-token accepts out of the n-gram drafter."""
    return dict(params, slots=jax.tree_util.tree_map(
        lambda x: x * 0.0, params["slots"]))


def _run_sched(cfg, mesh, params, prompts, *, spec, greedy=True, max_new=6,
               num_pages=32, spec_k=4, injector=None, **over):
    kw = dict(max_len=64, batch=2, prefill_chunk=4, paged=True, page_size=8,
              num_pages=num_pages)
    if not greedy:
        kw.update(greedy=False, temperature=0.8, top_k=20, sample_seed=3)
    if spec:
        kw.update(spec_decode=True, spec_k=spec_k)
    kw.update(over)
    with mesh:
        sched = BatchScheduler(cfg, mesh, ServeConfig(**kw), params,
                               fault_injector=injector)
        for rid, p in enumerate(prompts):
            sched.submit(p, request_id=rid, max_new=max_new)
        sched.drain()
    return sched


def _tokens(sched):
    return {r["id"]: r["generated"] for r in sched.completed}


# a prompt with a repeated 4-gram (the drafter locks on immediately) plus a
# non-repetitive one (the drafter proposes little) — both paths every run
_PROMPTS = [[5, 9, 13, 7] * 3, list(range(20, 28))]


# ---------------------------------------------------------------------------
# _serve_step_fns cache: spec knobs are part of the key, no collisions
# ---------------------------------------------------------------------------


def test_serve_step_fns_keys_on_full_statics_no_collision():
    cfg, mesh, _ = _fixtures()
    kw = dict(max_len=64, batch=2, prefill_chunk=4, paged=True, page_size=8,
              num_pages=8)
    plain = _serve_step_fns(cfg, mesh, ServeConfig(**kw).step_statics())
    assert plain[3] is None  # no verify step without spec_decode
    # an equal config (fresh instance) must hit the same cache entry
    assert _serve_step_fns(cfg, mesh,
                           ServeConfig(**kw).step_statics()) is plain
    spec = _serve_step_fns(
        cfg, mesh, ServeConfig(spec_decode=True, **kw).step_statics())
    assert spec is not plain and spec[3] is not None
    # every spec knob is a distinct key: a collision would hand a spec_k=6
    # scheduler a verify trace shaped for spec_k=4
    for knob in ({"spec_k": 6}, {"spec_min_match": 3}):
        other = _serve_step_fns(
            cfg, mesh,
            ServeConfig(spec_decode=True, **knob, **kw).step_statics())
        assert other is not spec
    # sampling knobs key the verify trace too (greedy argmax vs folded keys)
    sampled = _serve_step_fns(
        cfg, mesh,
        ServeConfig(spec_decode=True, greedy=False, temperature=0.8,
                    top_k=20, **kw).step_statics())
    assert sampled is not spec
    info = _serve_step_fns.cache_info()
    assert info.maxsize >= 32  # room for the repo's A/B patterns


# ---------------------------------------------------------------------------
# spec on/off bitwise identity — the tentpole guarantee
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("greedy", [True, False])
@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b", "gemma2-2b", "zamba2-2.7b", "xlstm-350m",
])
def test_spec_matches_plain_decode(arch, greedy):
    """Speculation on vs off must be bitwise identical per request —
    full attention, windowed attention crossing the verify chunk, and
    recurrent/hybrid stacks (whose verify runs two passes so state
    advances over exactly the accepted tokens) — greedy AND sampled."""
    cfg, mesh, params = _fixtures(arch)
    plain = _run_sched(cfg, mesh, params, _PROMPTS, spec=False, greedy=greedy)
    spec = _run_sched(cfg, mesh, params, _PROMPTS, spec=True, greedy=greedy)
    assert _tokens(spec) == _tokens(plain)
    assert spec.stats["spec_dispatches"] > 0
    assert spec._alloc.used == 0, "pages leaked after drain"


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "zamba2-2.7b"])
def test_spec_multi_token_accept_copy_regime(arch):
    """With residual-zeroed weights greedy decode cycles, the drafter
    locks on, and verification must accept multi-token windows — while
    staying bitwise identical to sequential decode (on recurrent archs
    this is the state-advances-over-every-accepted-token check).

    ``max_new=48``: the cycle (a walk in the last-token map's functional
    graph) is entered around token ~20 on both fixtures, so the drafter
    has real accepting room only past that point."""
    cfg, mesh, params = _fixtures(arch)
    params0 = _copy_regime(params)
    prompt = [[5, 9, 13, 7] * 4]
    plain = _run_sched(cfg, mesh, params0, prompt, spec=False, max_new=48,
                       max_len=128)
    spec = _run_sched(cfg, mesh, params0, prompt, spec=True, max_new=48,
                      max_len=128)
    assert _tokens(spec) == _tokens(plain)
    sp = spec.kv_cache_stats()["speculation"]
    assert sp["accepted"] > 0 and sp["acceptance_rate"] > 0.5, sp
    # multi-token accepts amortize dispatches (> 1 token/dispatch) and
    # cross page boundaries (page_size=8 < the accept windows' span)
    assert sp["tokens_per_dispatch"] > 1.0, sp
    assert spec.stats["decode_steps"] < plain.stats["decode_steps"]
    assert spec._alloc.used == 0


def test_spec_rejections_no_leak_and_identity():
    """Guaranteed rejection, deterministically: probe the copy-regime
    last-token map for the orbit of token 7, then plant a decoy after an
    earlier occurrence of the orbit's first token. The 1-gram drafter
    must propose the decoy and verification must reject it (the model's
    continuation is known and differs) — with tokens still bitwise equal
    to plain decode and the rolled-back pages returned at drain."""
    cfg, mesh, params = _fixtures()
    params0 = _copy_regime(params)
    orbit = _tokens(_run_sched(cfg, mesh, params0, [[4, 7]], spec=False,
                               max_new=4))[0]  # [f(7), f(f(7)), ...]
    decoy = (orbit[1] + 1) % cfg.vocab  # never what the model emits next
    # last prompt token 7 -> first generated token is f(7) = orbit[0];
    # its planted earlier occurrence is followed by the decoy
    prompt = [orbit[0], decoy, 11, 3, 7]
    plain = _run_sched(cfg, mesh, params0, [prompt], spec=False, max_new=8)
    spec = _run_sched(cfg, mesh, params0, [prompt], spec=True, max_new=8,
                      spec_min_match=1)
    assert _tokens(spec) == _tokens(plain)
    assert spec.stats["spec_rejected"] > 0, spec.stats
    assert spec._alloc.used == 0, "rejected speculation leaked pages"


# ---------------------------------------------------------------------------
# composition: preemption-resume and fault-retry stay bitwise-correct
# ---------------------------------------------------------------------------


def test_spec_preempt_resume_identity():
    """A preempted spec request resumes with its token history, so the
    drafter re-derives the same proposals and the replayed generated
    tokens ride the verify path — the tight-pool run must match both the
    ample-pool spec run and plain decode, with nothing leaked."""
    cfg, mesh, params = _fixtures()
    prompts = [list(range(4, 12)), list(range(20, 28))]
    plain = _run_sched(cfg, mesh, params, prompts, spec=False, max_new=8,
                       num_pages=16)
    ample = _run_sched(cfg, mesh, params, prompts, spec=True, max_new=8,
                       num_pages=16)
    tight = _run_sched(cfg, mesh, params, prompts, spec=True, max_new=8,
                       num_pages=3)
    assert tight.stats["preemptions"] > 0, "pressure never materialized"
    assert _tokens(tight) == _tokens(ample) == _tokens(plain)
    assert tight._alloc.used == 0, "pages leaked across preempt/resume"


@pytest.mark.parametrize("greedy", [True, False])
def test_spec_fault_retry_identity(greedy):
    """NaN-poisoned verify dispatches must be invisible in the output:
    the victim retries through recompute-resume (replaying its clean
    history as auto-accepting drafts) and every stream matches the
    fault-free spec run bitwise."""
    cfg, mesh, params = _fixtures()
    events = [FaultEvent(kind="nan", tick=4), FaultEvent(kind="nan", tick=9)]
    base = _run_sched(cfg, mesh, params, _PROMPTS, spec=True, greedy=greedy,
                      max_new=8)
    chaos = _run_sched(cfg, mesh, params, _PROMPTS, spec=True, greedy=greedy,
                       max_new=8, injector=FaultInjector(events=events))
    assert chaos.stats["retries"] >= 1, chaos.kv_cache_stats()["recovery"]
    assert _tokens(chaos) == _tokens(base)
    assert chaos._alloc.used == 0, "pages leaked across fault retry"


# ---------------------------------------------------------------------------
# allocator exhaustion mid-accept: the partial grow must unwind page-by-page
# ---------------------------------------------------------------------------


def test_ensure_pages_unwinds_partial_alloc_on_exhaustion():
    """A multi-page grow (the multi-token-accept shape) that runs the pool
    dry partway must free the pages it already took and restore the block
    table before the pressure propagates — no partial allocation may leak."""
    cfg, mesh, params = _fixtures()
    with mesh:
        sched = BatchScheduler(
            cfg, mesh,
            ServeConfig(max_len=64, batch=1, prefill_chunk=4, paged=True,
                        page_size=8, num_pages=8, preempt_policy="never",
                        spec_decode=True, spec_k=4),
            params,
        )
        sched.submit(list(range(4, 10)), request_id=0, max_new=4)
        for _ in range(10):
            if sched.active[0] is not None:
                break
            sched.step()
        req = sched.active[0]
        assert req is not None
        # hold all but ONE free page: the 2-page grow below succeeds on its
        # first page, then hits exhaustion on the second
        held = sched._alloc.alloc(sched._alloc.free_pages - 1, owner="hold")
        used_before = sched._alloc.used
        pages_before = list(sched._slot_pages[0])
        tables_before = sched._tables.copy()
        grow_to = (len(pages_before) + 2) * sched.scfg.page_size - 1
        with pytest.raises(_PoolPressure):
            sched._ensure_pages(0, grow_to, req)
        assert sched._alloc.used == used_before, "partial grow leaked pages"
        assert sched._slot_pages[0] == pages_before
        np.testing.assert_array_equal(sched._tables, tables_before)
        # the unwound scheduler is still healthy: release the hold and the
        # request must run to completion with the pool fully returned
        sched._alloc.release(held)
        sched.drain()
    assert len(sched.completed) == 1
    assert sched._alloc.used == 0
